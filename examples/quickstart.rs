//! Quickstart — the end-to-end validation driver.
//!
//! Runs the Master/Worker matmul on a real workload (N=256, 4 replicated
//! ranks, compute through the AOT Pallas/XLA artifacts when available),
//! injects the paper's Scenario-50-style fault (an FSC that dirties the
//! last checkpoint), and demonstrates the full SEDAR level-2 story:
//!
//!   detection at VALIDATE → rollback to CK3 → same fault re-detected →
//!   rollback to CK2 → clean re-execution → final result verified against
//!   the sequential oracle,
//!
//! then repeats the run under the level-3 strategy (single validated
//! user-level checkpoint) and under detection-only, and prints the timing/
//! overhead comparison. Run with:
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use sedar::apps::matmul::{phases, MatmulApp};
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};
use sedar::report::Table;
use sedar::runtime::Engine;

fn fsc_injection() -> InjectionSpec {
    // Scenario 50 of the paper's Table 2: corrupt an element of C at the
    // master between GATHER and CK3. CK3 captures the corruption (dirty),
    // so recovery needs two rollbacks.
    InjectionSpec {
        name: "quickstart-fsc-dirty-ck3".into(),
        point: InjectPoint::BeforePhase(phases::CK3),
        rank: 0,
        replica: 1,
        kind: InjectKind::BitFlip {
            var: "C".into(),
            elem: 123,
            bit: 30,
        },
    }
}

fn main() -> sedar::Result<()> {
    let n = 256;
    let nranks = 4;
    let app = Arc::new(MatmulApp::new(n, nranks));
    let artifacts = Engine::default_artifact_dir();
    let use_xla = Engine::artifacts_available(&artifacts);
    println!(
        "quickstart: matmul N={n}, {nranks} replicated ranks, compute = {}",
        if use_xla {
            "AOT Pallas/XLA artifacts"
        } else {
            "rust fallback (run `make artifacts` for the XLA path)"
        }
    );

    let mut table = Table::new(&[
        "strategy",
        "fault",
        "attempts",
        "restarts",
        "detections",
        "result",
        "wall",
    ]);

    let mut run_one = |strategy: Strategy, inject: bool| -> sedar::Result<()> {
        let cfg = RunConfig {
            strategy,
            use_xla,
            artifact_dir: artifacts.clone(),
            run_dir: PathBuf::from(format!(
                "runs/quickstart-{}-{}",
                strategy.label(),
                if inject { "fault" } else { "clean" }
            )),
            echo_trace: inject && strategy == Strategy::SysCkpt,
            ..RunConfig::default()
        };
        let injection = inject.then(fsc_injection);
        if cfg.echo_trace {
            println!("\n--- live trace: {} with injected FSC ---", strategy.label());
        }
        let outcome = SedarRun::new(app.clone(), cfg, injection).run()?;
        if outcome.result_correct != Some(true) {
            return Err(sedar::SedarError::Config(format!(
                "{}: wrong result!",
                strategy.label()
            )));
        }
        table.row(&[
            strategy.label().to_string(),
            if inject { "FSC@CK3" } else { "-" }.to_string(),
            outcome.attempts.to_string(),
            outcome.restarts.to_string(),
            outcome
                .detections
                .iter()
                .map(|d| format!("{}@{}", d.class, d.site))
                .collect::<Vec<_>>()
                .join(" "),
            "correct".to_string(),
            sedar::util::human_duration(outcome.wall),
        ]);
        Ok(())
    };

    for strategy in [
        Strategy::Baseline,
        Strategy::DetectOnly,
        Strategy::SysCkpt,
        Strategy::UserCkpt,
    ] {
        run_one(strategy, false)?;
    }
    for strategy in [Strategy::DetectOnly, Strategy::SysCkpt, Strategy::UserCkpt] {
        run_one(strategy, true)?;
    }

    println!("\n=== quickstart summary ===\n{}", table.markdown());
    println!(
        "note: under sys-ckpt the injected FSC needs 2 rollbacks (dirty CK3 →\n\
         clean CK2), under user-ckpt the corrupted candidate is caught at\n\
         checkpoint validation and a single rollback suffices — exactly the\n\
         §3.2 vs §3.3 trade-off of the paper."
    );
    Ok(())
}
