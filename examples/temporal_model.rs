//! The analytical temporal model, end to end (§3.4, §4.3, §4.4):
//! regenerates Tables 4 and 5 from the paper's Table-3 parameters, prints
//! the §4.4 decision thresholds, and sweeps AET vs MTBE (Equations 9–11)
//! for every strategy — the "figure" of the average-time analysis.
//!
//! ```text
//! cargo run --release --example temporal_model
//! ```

use sedar::model::params::PaperApp;
use sedar::model::{aet, daly_interval, equations::*, tables};
use sedar::report::Table;

fn main() {
    let cols: Vec<(&str, sedar::model::Params)> = PaperApp::ALL
        .iter()
        .map(|a| (a.label(), a.paper_params()))
        .collect();

    println!("=== Table 4 — execution times of all SEDAR strategies [hs] ===\n");
    print!("{}", tables::table4_markdown(&cols));

    println!("\n=== Table 5 — only-detection vs k+1 rollback attempts (Jacobi) ===\n");
    let p = PaperApp::Jacobi.paper_params();
    let t5 = tables::table5(&p, &[0.3, 0.5, 0.8], 4);
    print!("{}", tables::table5_markdown(&t5));

    println!("\n=== §4.4 protection-strategy thresholds (Jacobi parameters) ===\n");
    for (k, meaning) in [
        (0u32, "below this progress, stop-and-relaunch beats any checkpointing"),
        (1, "beyond this, rolling back to the last-but-one checkpoint still wins"),
        (2, "beyond this, even two extra rollbacks beat detection-only"),
    ] {
        println!(
            "  X*(k={k}) = {:5.2} %   — {meaning}",
            tables::threshold_x(&p, k) * 100.0
        );
    }

    println!("\n=== AET vs MTBE (Equations 9–11), Jacobi parameters [hs] ===\n");
    let mut t = Table::new(&["MTBE [h]", "baseline", "detect-only", "sys-ckpt", "user-ckpt"]);
    for mtbe_h in [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 1000.0] {
        let mtbe = mtbe_h * 3600.0;
        t.row(&[
            format!("{mtbe_h}"),
            format!("{:.2}", aet(eq1_baseline_fa(&p), eq2_baseline_fp(&p), p.t_prog, mtbe) / 3600.0),
            format!("{:.2}", aet(eq3_detect_fa(&p), eq4_detect_fp(&p, 0.5), p.t_prog, mtbe) / 3600.0),
            format!("{:.2}", aet(eq5_sys_fa(&p), eq6_sys_fp(&p, 0), p.t_prog, mtbe) / 3600.0),
            format!("{:.2}", aet(eq7_user_fa(&p), eq8_user_fp(&p), p.t_prog, mtbe) / 3600.0),
        ]);
    }
    print!("{}", t.markdown());
    println!(
        "\n(read: as MTBE approaches the job length, checkpointing strategies\n\
         pull far ahead of both the baseline and detection-only — the paper's\n\
         central quantitative claim.)"
    );

    println!("\n=== Daly's optimal checkpoint interval (§4.3 footnote) ===\n");
    for app in PaperApp::ALL {
        let p = app.paper_params();
        for mtbe_h in [5.0, 24.0] {
            let t_opt = daly_interval(p.t_cs, mtbe_h * 3600.0);
            println!(
                "  {:7}  MTBE={mtbe_h:>4.0} h  t_cs={:5.1} s  →  t_opt = {:.2} h",
                app.label(),
                p.t_cs,
                t_opt / 3600.0
            );
        }
    }
}
