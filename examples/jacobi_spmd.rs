//! SPMD Jacobi under SEDAR — the communication-intensive pattern (§4.3).
//!
//! Runs the halo-exchange Jacobi solver under every strategy, injecting a
//! fault into a mid-run iteration, and shows the property the paper
//! emphasizes for SPMD codes: detection latency is *short* (the corrupted
//! block reaches a neighbor exchange within one iteration — TDC at the next
//! halo send), so checkpoint recovery loses very little work.
//!
//! ```text
//! cargo run --release --example jacobi_spmd
//! ```

use std::sync::Arc;

use sedar::apps::spec::AppSpec;
use sedar::apps::JacobiApp;
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::error::SedarError;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};
use sedar::report::Table;
use sedar::runtime::Engine;

fn main() -> sedar::Result<()> {
    let app = Arc::new(JacobiApp::new(128, 4, 24, 8)); // 24 iters, ck every 8
    let artifacts = Engine::default_artifact_dir();
    let use_xla = Engine::artifacts_available(&artifacts);
    println!(
        "jacobi 128×128, 4 ranks, 24 iterations, checkpoint every 8 (xla={use_xla})\n"
    );

    // Corrupt a grid cell of rank 2's replica right before iteration 13
    // (i.e. between CK1 at iter 16? no — after the CK covering iters 0-7;
    // cursor arithmetic below picks the phase by name).
    let inject_phase = app.cursor_of("ITER13");
    let spec = InjectionSpec {
        name: "jacobi-grid-flip".into(),
        point: InjectPoint::BeforePhase(inject_phase),
        rank: 2,
        replica: 1,
        kind: InjectKind::BitFlip {
            var: "grid".into(),
            elem: 40,
            bit: 30,
        },
    };

    let mut table = Table::new(&[
        "strategy", "attempts", "restarts", "detected", "resumes", "wall",
    ]);
    for strategy in [Strategy::DetectOnly, Strategy::SysCkpt, Strategy::UserCkpt] {
        let cfg = RunConfig {
            strategy,
            use_xla,
            run_dir: format!("runs/example-jacobi-{}", strategy.label()).into(),
            ..RunConfig::default()
        };
        let outcome = SedarRun::new(app.clone(), cfg, Some(spec.clone())).run()?;
        if outcome.result_correct != Some(true) {
            return Err(SedarError::Config(format!(
                "{}: wrong result",
                strategy.label()
            )));
        }
        table.row(&[
            strategy.label().to_string(),
            outcome.attempts.to_string(),
            outcome.restarts.to_string(),
            outcome
                .detections
                .iter()
                .map(|d| format!("{}@{}", d.class, d.site))
                .collect::<Vec<_>>()
                .join(" "),
            outcome
                .resume_history
                .iter()
                .map(|r| r.to_string())
                .collect::<Vec<_>>()
                .join(" "),
            sedar::util::human_duration(outcome.wall),
        ]);
    }
    println!("{}", table.markdown());
    println!(
        "the corrupted halo row is caught at the very next ITER13 exchange\n\
         (TDC) — the SPMD pattern's short detection latency keeps k = 0."
    );
    Ok(())
}
