//! Pipelined Smith-Waterman under SEDAR (§4.3's third pattern).
//!
//! Aligns two synthetic DNA sequences across 4 pipeline ranks, injecting a
//! fault into the carried DP frontier mid-pipeline. Shows the pipeline
//! pattern's property: corruption in a band's carried state surfaces as a
//! TDC on the *frontier message* flowing downstream — detection latency is
//! one pipeline hop.
//!
//! ```text
//! cargo run --release --example sw_pipeline
//! ```

use std::sync::Arc;

use sedar::apps::spec::AppSpec;
use sedar::apps::SwApp;
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::error::SedarError;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};
use sedar::report::Table;
use sedar::runtime::Engine;

fn main() -> sedar::Result<()> {
    // 512-symbol sequences, 4 column bands of width 128, 8 row blocks of 64,
    // checkpoint every 2 blocks.
    let app = Arc::new(SwApp::new(512, 4, 64, 2));
    let artifacts = Engine::default_artifact_dir();
    let use_xla = Engine::artifacts_available(&artifacts);
    println!(
        "smith-waterman m=512, 4 pipeline ranks, block_rows=64, ck every 2 blocks (xla={use_xla})\n"
    );
    println!(
        "expected similarity score (sequential oracle): {}\n",
        app.expected_result(RunConfig::default().seed)[0]
    );

    // Corrupt rank 1's carried prev_row before BLOCK5: the corrupted band
    // state propagates into the frontier sent to rank 2 → TDC at BLOCK5.
    let spec = InjectionSpec {
        name: "sw-frontier-flip".into(),
        point: InjectPoint::BeforePhase(app.cursor_of("BLOCK5")),
        rank: 1,
        replica: 1,
        kind: InjectKind::BitFlip {
            // Last column of the band: flows verbatim into the outgoing
            // frontier, so detection at the next hop is guaranteed.
            var: "prev_row".into(),
            elem: 127, // band_width - 1
            bit: 30,
        },
    };

    let mut table = Table::new(&["strategy", "attempts", "restarts", "detected", "wall"]);
    for strategy in [Strategy::DetectOnly, Strategy::SysCkpt, Strategy::UserCkpt] {
        let cfg = RunConfig {
            strategy,
            use_xla,
            run_dir: format!("runs/example-sw-{}", strategy.label()).into(),
            ..RunConfig::default()
        };
        let outcome = SedarRun::new(app.clone(), cfg, Some(spec.clone())).run()?;
        if outcome.result_correct != Some(true) {
            return Err(SedarError::Config(format!(
                "{}: wrong result",
                strategy.label()
            )));
        }
        table.row(&[
            strategy.label().to_string(),
            outcome.attempts.to_string(),
            outcome.restarts.to_string(),
            outcome
                .detections
                .iter()
                .map(|d| format!("{}@{}", d.class, d.site))
                .collect::<Vec<_>>()
                .join(" "),
            sedar::util::human_duration(outcome.wall),
        ]);
    }
    println!("{}", table.markdown());
    Ok(())
}
