//! The 64-scenario injection campaign (§4.1–4.2, Table 2 + Figure 3).
//!
//! Builds the full workfault catalog over the matmul test application,
//! injects every scenario for real under the multiple-system-level-
//! checkpoint strategy, and checks the observed effect, detection point,
//! recovery point and rollback count against the analytical predictions.
//!
//! ```text
//! cargo run --release --example injection_campaign            # all 64
//! cargo run --release --example injection_campaign -- 50      # one, with
//!                                                             # the Figure-3
//!                                                             # style trace
//! ```

use sedar::apps::matmul::MatmulApp;
use sedar::config::RunConfig;
use sedar::workfault;

fn main() -> anyhow::Result<()> {
    let only: Option<u32> = std::env::args().nth(1).and_then(|s| s.parse().ok());
    let app = MatmulApp::new(64, 4);
    let mut cfg = RunConfig::default();
    cfg.run_dir = format!("runs/example-campaign-{}", std::process::id()).into();

    let catalog = workfault::catalog(&app);
    println!("{}", workfault::table2_header());
    let mut passed = 0;
    let mut failed = 0;
    for sc in &catalog {
        if let Some(id) = only {
            if sc.id != id {
                continue;
            }
        }
        let r = workfault::run_scenario(&app, sc, &cfg)?;
        println!("{}  →  {}", sc.row(), if r.pass { "OK" } else { "MISMATCH" });
        for m in &r.mismatches {
            println!("    ! {m}");
        }
        if only.is_some() {
            // The Figure-3 artifact: the full event log of this experiment.
            println!("\n--- execution trace (cf. paper Figure 3) ---");
            println!("{}", r.outcome.trace_dump);
        }
        if r.pass {
            passed += 1
        } else {
            failed += 1
        }
    }
    println!("\ncampaign: {passed} passed, {failed} failed");
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    if failed > 0 {
        anyhow::bail!("{failed} scenario(s) diverged from the prediction");
    }
    Ok(())
}
