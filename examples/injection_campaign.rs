//! The parallel injection campaign (§4.1–4.2, Table 2 + Figure 3).
//!
//! Runs the 64-scenario workfault over the matmul test application under
//! the multiple-system-level-checkpoint strategy through the campaign
//! engine (`sedar::campaign`): a worker pool fans the scenarios out, each
//! in an isolated world, and the aggregated report is checked against the
//! §4.1 prediction oracle. With a scenario id argument, a single scenario
//! runs serially and the Figure-3-style execution trace is printed.
//!
//! ```text
//! cargo run --release --example injection_campaign            # all 64
//! cargo run --release --example injection_campaign -- 50      # one, with
//!                                                             # the Figure-3
//!                                                             # style trace
//! ```

use sedar::campaign::{self, CampaignSpec};
use sedar::config::RunConfig;
use sedar::error::SedarError;
use sedar::workfault;

fn main() -> sedar::Result<()> {
    let only: Option<u32> = std::env::args().nth(1).and_then(|s| s.parse().ok());

    if let Some(id) = only {
        // Single-scenario mode: serial run, full Figure-3 trace.
        let app = campaign::campaign_matmul();
        let cfg = RunConfig {
            run_dir: format!("runs/example-campaign-{}", std::process::id()).into(),
            ..RunConfig::default()
        };
        let sc = workfault::catalog(&app)
            .into_iter()
            .find(|s| s.id == id)
            .ok_or_else(|| SedarError::Config(format!("no scenario {id}")))?;
        println!("{}", workfault::table2_header());
        let r = workfault::run_scenario(&app, &sc, &cfg)?;
        println!("{}  →  {}", sc.row(), if r.pass { "OK" } else { "MISMATCH" });
        for m in &r.mismatches {
            println!("    ! {m}");
        }
        println!("\n--- execution trace (cf. paper Figure 3) ---");
        println!("{}", r.outcome.trace_dump);
        let _ = std::fs::remove_dir_all(&cfg.run_dir);
        if !r.pass {
            return Err(SedarError::Config(
                "scenario diverged from the prediction".into(),
            ));
        }
        return Ok(());
    }

    // Full campaign: matmul × sys-ckpt × all 64 scenarios × both
    // collective implementations (128 worlds), in parallel.
    let mut spec = CampaignSpec::new(0xC0FFEE);
    spec.apply_filter("app=matmul,strategy=sys")?;
    spec.jobs = CampaignSpec::default_jobs();
    spec.echo = true;
    spec.base.run_dir = format!("runs/example-campaign-{}", std::process::id()).into();

    let report = campaign::run_campaign(&spec)?;
    println!("{}", report.deterministic_report());
    println!("\n{}", report.summary_line());
    let _ = std::fs::remove_dir_all(&spec.base.run_dir);
    if !report.verdict() {
        return Err(SedarError::Config(format!(
            "{} scenario(s) diverged from the prediction",
            report.failed()
        )));
    }
    Ok(())
}
