//! Workfault extension: detection latency vs. the SPMD communication
//! pattern (the paper's §5 future-work item, built here).
//!
//! In the Jacobi solver, corruption injected `d` rows away from the
//! nearest *exchanged* block edge contaminates that edge row after exactly
//! `d` sweeps (the 5-point stencil propagates one row per iteration, and
//! the contamination coefficient `(1/4)^d` of a high-exponent bit-flip
//! stays far above the clean signal). The detection point is therefore
//! **predictable**: the halo send of iteration `k + d`; if the run ends
//! first, the corruption surfaces at GATHER (workers transmit their block)
//! or — for the master's own block — at the final VALIDATE.
//!
//! [`predict`] encodes that dataflow argument; [`catalog`] sweeps injection
//! iterations × depths × ranks; `rust/tests/jacobi_latency.rs` injects
//! each scenario for real and checks the prediction, reproducing the
//! "latency of detection depends on the communication pattern"
//! relationship quantitatively.

use std::sync::Arc;

use crate::apps::jacobi::JacobiApp;
use crate::apps::spec::AppSpec;
use crate::config::{RunConfig, Strategy};
use crate::coordinator::{RunOutcome, SedarRun};
use crate::error::{FaultClass, Result};
use crate::inject::{InjectKind, InjectPoint, InjectionSpec};
use crate::recovery::ResumeFrom;

use super::Rec;

/// Predicted detection site for a Jacobi grid corruption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JDetect {
    /// Halo send of this iteration (TDC).
    Iter(usize),
    /// Never reached a halo before the loop ended; the block transmission
    /// at GATHER catches it (TDC) — workers only.
    Gather,
    /// Master-local corruption that never crossed a message: final-result
    /// comparison (FSC).
    Validate,
}

impl JDetect {
    pub fn site(&self) -> String {
        match self {
            JDetect::Iter(i) => format!("ITER{i}"),
            JDetect::Gather => "GATHER".into(),
            JDetect::Validate => "VALIDATE".into(),
        }
    }

    pub fn class(&self) -> FaultClass {
        match self {
            JDetect::Validate => FaultClass::Fsc,
            _ => FaultClass::Tdc,
        }
    }
}

/// One latency scenario: corrupt `grid[row][col]` of `rank`'s replica 1
/// right before iteration `inject_iter`.
#[derive(Debug, Clone)]
pub struct JScenario {
    pub inject_iter: usize,
    pub rank: usize,
    pub row: usize,
    /// Interior column (edge columns are Dirichlet-restored every sweep).
    pub col: usize,
    // --- predictions ---
    pub detect: JDetect,
    pub latency_iters: usize,
    pub n_roll: u32,
    pub p_rec: Rec,
}

/// Rows of `rank`'s block that are actually exchanged, as distances.
fn edge_distance(app: &JacobiApp, rank: usize, row: usize) -> usize {
    let rows = app.rows();
    let last = app.nranks - 1;
    let d_top = row; // distance to the block's first row
    let d_bot = rows - 1 - row;
    match rank {
        0 => d_bot,                   // only the bottom edge is exchanged
        r if r == last => d_top,      // only the top edge
        _ => d_top.min(d_bot),        // both
    }
}

/// The dataflow prediction (see module docs).
pub fn predict(app: &JacobiApp, inject_iter: usize, rank: usize, row: usize) -> JScenario {
    let d = edge_distance(app, rank, row);
    let detect_iter = inject_iter + d;
    let detect = if detect_iter < app.iters {
        JDetect::Iter(detect_iter)
    } else if rank > 0 {
        JDetect::Gather
    } else {
        JDetect::Validate
    };

    // Rollback arithmetic, identical to the matmul oracle: checkpoints
    // stored in [injection, detection] are dirty.
    let inj_phase = app.cursor_of(&format!("ITER{inject_iter}"));
    let det_phase = app.cursor_of(&detect.site());
    let cks = app.ckpt_phases();
    let clean_before_inj = cks.iter().filter(|c| **c < inj_phase).count() as u64;
    let stored_before_det = cks.iter().filter(|c| **c < det_phase).count() as u64;
    let n_roll = (stored_before_det - clean_before_inj + 1) as u32;
    let p_rec = if clean_before_inj > 0 {
        Rec::Ck(clean_before_inj - 1)
    } else {
        Rec::Scratch
    };

    JScenario {
        inject_iter,
        rank,
        row,
        col: app.n / 2,
        detect,
        latency_iters: d,
        n_roll,
        p_rec,
    }
}

/// Sweep of latency scenarios: every rank class (first / middle / last) ×
/// depths from the exchanged edges × two injection iterations.
pub fn catalog(app: &JacobiApp) -> Vec<JScenario> {
    assert!(app.nranks >= 3);
    let rows = app.rows();
    let mut out = Vec::new();
    for &inject_iter in &[0usize, app.ckpt_every + 1] {
        for rank in [0, 1, app.nranks - 1] {
            for row in [0, 1, rows / 2, rows - 2, rows - 1] {
                out.push(predict(app, inject_iter, rank, row));
            }
        }
    }
    out
}

/// Inject one scenario for real (under the multiple-system-level-
/// checkpoint strategy) and check every prediction.
pub fn run_scenario(
    app: &JacobiApp,
    sc: &JScenario,
    base_cfg: &RunConfig,
) -> Result<(RunOutcome, Vec<String>)> {
    let mut cfg = base_cfg.clone();
    cfg.strategy = Strategy::SysCkpt;
    cfg.run_dir = base_cfg.run_dir.join(format!(
        "jl-i{}r{}w{}",
        sc.inject_iter, sc.rank, sc.row
    ));
    let spec = InjectionSpec {
        name: format!("jacobi-lat-i{}-r{}-row{}", sc.inject_iter, sc.rank, sc.row),
        point: InjectPoint::BeforePhase(app.cursor_of(&format!("ITER{}", sc.inject_iter))),
        rank: sc.rank,
        replica: 1,
        kind: InjectKind::BitFlip {
            var: "grid".into(),
            elem: sc.row * app.n + sc.col,
            bit: 30, // exponent bit: the contamination dominates the signal
        },
    };
    let outcome = SedarRun::new(Arc::new(app.clone()), cfg, Some(spec)).run()?;

    let mut mismatches = Vec::new();
    if outcome.result_correct != Some(true) {
        mismatches.push(format!("result: {:?}", outcome.result_correct));
    }
    match outcome.detections.first() {
        None => mismatches.push("nothing detected".into()),
        Some(ev) => {
            if ev.class != sc.detect.class() {
                mismatches.push(format!(
                    "class: predicted {}, got {}",
                    sc.detect.class(),
                    ev.class
                ));
            }
            if ev.site != sc.detect.site() {
                mismatches.push(format!(
                    "site: predicted {}, got {}",
                    sc.detect.site(),
                    ev.site
                ));
            }
        }
    }
    if outcome.restarts != sc.n_roll {
        mismatches.push(format!(
            "N_roll: predicted {}, got {}",
            sc.n_roll, outcome.restarts
        ));
    }
    match (sc.p_rec, outcome.resume_history.last()) {
        (Rec::Ck(k), Some(ResumeFrom::SysCkpt(got))) if *got == k => {}
        (Rec::Scratch, Some(ResumeFrom::Scratch)) => {}
        (want, got) => mismatches.push(format!("P_rec: predicted {want}, got {got:?}")),
    }
    Ok((outcome, mismatches))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> JacobiApp {
        JacobiApp::new(64, 4, 12, 4)
    }

    #[test]
    fn edge_distances_respect_rank_position() {
        let a = app(); // rows = 16
        assert_eq!(edge_distance(&a, 0, 15), 0); // master: bottom edge
        assert_eq!(edge_distance(&a, 0, 0), 15); // master's row 0 never sent
        assert_eq!(edge_distance(&a, 1, 0), 0); // middle: both edges
        assert_eq!(edge_distance(&a, 1, 8), 7);
        assert_eq!(edge_distance(&a, 3, 0), 0); // last: top edge
        assert_eq!(edge_distance(&a, 3, 15), 15);
    }

    #[test]
    fn prediction_latency_is_distance() {
        let a = app();
        let sc = predict(&a, 1, 1, 5); // depth 5 from the top edge
        assert_eq!(sc.latency_iters, 5);
        assert_eq!(sc.detect, JDetect::Iter(6));
        assert_eq!(sc.detect.class(), FaultClass::Tdc);
    }

    #[test]
    fn deep_master_corruption_becomes_fsc() {
        let a = app(); // 12 iters
        // Master row 0, injected at iter 5: needs 15 sweeps → ends first.
        let sc = predict(&a, 5, 0, 0);
        assert_eq!(sc.detect, JDetect::Validate);
        assert_eq!(sc.detect.class(), FaultClass::Fsc);
    }

    #[test]
    fn deep_worker_corruption_caught_at_gather() {
        let a = app();
        let sc = predict(&a, 5, 3, 15); // depth 15, 7 iters left
        assert_eq!(sc.detect, JDetect::Gather);
    }

    #[test]
    fn catalog_covers_all_detection_kinds() {
        let c = catalog(&app());
        assert_eq!(c.len(), 30);
        assert!(c.iter().any(|s| matches!(s.detect, JDetect::Iter(_))));
        assert!(c.iter().any(|s| s.detect == JDetect::Gather));
        assert!(c.iter().any(|s| s.detect == JDetect::Validate));
        // Latency spectrum: immediate (d=0) through deep (d=15).
        assert!(c.iter().any(|s| s.latency_iters == 0));
        assert!(c.iter().any(|s| s.latency_iters >= 15));
    }
}
