//! The workfault: the complete set of 64 representative injection scenarios
//! over the Master/Worker matmul test application (§4.1, Table 2).
//!
//! Each scenario names an injection *window* (the execution interval between
//! two phases), a target process, and a target datum. From the application's
//! dataflow, the **prediction oracle** ([`predict`]) derives — exactly as
//! §4.1 does analytically —
//!
//! * the *effect* class (TDC / FSC / LE / TOE),
//! * the detection point `P_det`,
//! * the recovery point `P_rec` (the nearest *clean* checkpoint), and
//! * `N_roll`, the number of restart attempts Algorithm 1 will need.
//!
//! The campaign runner ([`run_scenario`]) then injects the fault for real
//! and checks observed behavior against the prediction — the paper's
//! empirical validation (§4.2, Figure 3), mechanized for all 64 scenarios
//! (`rust/tests/campaign64.rs`, `benches/table2_scenarios.rs`).

pub mod jacobi;

use std::sync::Arc;

use crate::apps::matmul::{phases, MatmulApp};
use crate::config::{CollectiveImpl, RunConfig, Strategy};
use crate::coordinator::{RunOutcome, SedarRun};
use crate::error::{FaultClass, Result};
use crate::inject::{InjectKind, InjectPoint, InjectionSpec};
use crate::recovery::ResumeFrom;

/// The execution intervals faults are injected into (the paper's `P_inj`
/// column, e.g. "CK0 – SCATTER").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Window {
    /// INIT → CK0 (before the first checkpoint: even CK0 is dirty).
    InitCk0,
    /// CK0 → SCATTER.
    Ck0Scatter,
    /// SCATTER → CK1.
    ScatterCk1,
    /// CK1 → BCAST.
    Ck1Bcast,
    /// BCAST → CK2.
    BcastCk2,
    /// During the MATMUL compute loop (index-corruption TOE scenarios).
    DuringMatmul,
    /// MATMUL → GATHER.
    MatmulGather,
    /// GATHER → CK3.
    GatherCk3,
    /// CK3 → VALIDATE.
    Ck3Validate,
}

impl Window {
    /// The phase cursor the injection fires before (or during).
    pub fn inj_cursor(self) -> u64 {
        match self {
            Window::InitCk0 => phases::CK0,
            Window::Ck0Scatter => phases::SCATTER,
            Window::ScatterCk1 => phases::CK1,
            Window::Ck1Bcast => phases::BCAST,
            Window::BcastCk2 => phases::CK2,
            Window::DuringMatmul => phases::MATMUL,
            Window::MatmulGather => phases::GATHER,
            Window::GatherCk3 => phases::CK3,
            Window::Ck3Validate => phases::VALIDATE,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Window::InitCk0 => "INIT-CK0",
            Window::Ck0Scatter => "CK0-SCATTER",
            Window::ScatterCk1 => "SCATTER-CK1",
            Window::Ck1Bcast => "CK1-BCAST",
            Window::BcastCk2 => "BCAST-CK2",
            Window::DuringMatmul => "MATMUL",
            Window::MatmulGather => "MATMUL-GATHER",
            Window::GatherCk3 => "GATHER-CK3",
            Window::Ck3Validate => "CK3-VALIDATE",
        }
    }

    const DATA_WINDOWS: [Window; 8] = [
        Window::InitCk0,
        Window::Ck0Scatter,
        Window::ScatterCk1,
        Window::Ck1Bcast,
        Window::BcastCk2,
        Window::MatmulGather,
        Window::GatherCk3,
        Window::Ck3Validate,
    ];
}

/// What datum the bit-flip lands in (the paper's `Data` column: A(M), A(W),
/// B, C(M), C(W), i(M), i(W)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataTarget {
    /// Master's full `A`, element inside the master's own chunk rows — the
    /// paper's `A(M)`.
    AMasterPart,
    /// Master's full `A`, element inside a worker's chunk rows — `A(W)`.
    AWorkerPart,
    /// The local `A_chunk` of the target process.
    AChunk,
    /// The `B` matrix of the target process.
    B,
    /// Master's result matrix `C`, element in the master's chunk — `C(M)`.
    CMaster,
    /// The local `C_chunk` of the target process.
    CChunk,
    /// A loop index during MATMUL — `i(M)` / `i(W)` (TOE).
    Index,
}

impl DataTarget {
    pub fn label(self, is_master: bool) -> &'static str {
        match (self, is_master) {
            (DataTarget::AMasterPart, _) => "A(M)",
            (DataTarget::AWorkerPart, _) => "A(W)",
            (DataTarget::AChunk, true) => "Ach(M)",
            (DataTarget::AChunk, false) => "Ach(W)",
            (DataTarget::B, true) => "B(M)",
            (DataTarget::B, false) => "B(W)",
            (DataTarget::CMaster, _) => "C(M)",
            (DataTarget::CChunk, true) => "Cch(M)",
            (DataTarget::CChunk, false) => "Cch(W)",
            (DataTarget::Index, true) => "i(M)",
            (DataTarget::Index, false) => "i(W)",
        }
    }
}

/// Predicted recovery point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rec {
    /// No recovery needed (LE).
    None,
    /// Roll back to checkpoint `k` (the nearest clean one).
    Ck(u64),
    /// Relaunch from the beginning.
    Scratch,
}

impl std::fmt::Display for Rec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rec::None => write!(f, "-"),
            Rec::Ck(k) => write!(f, "CK{k}"),
            Rec::Scratch => write!(f, "start"),
        }
    }
}

/// One catalog entry: the scenario definition plus its §4.1 prediction.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub id: u32,
    pub window: Window,
    /// Injected rank (0 = Master).
    pub rank: usize,
    pub data: DataTarget,
    // ---- predictions (the analytical model of §4.1) ----
    pub effect: FaultClass,
    pub p_det: Option<&'static str>,
    pub p_rec: Rec,
    pub n_roll: u32,
}

impl Scenario {
    pub fn is_master(&self) -> bool {
        self.rank == 0
    }

    /// Table-2-style row.
    pub fn row(&self) -> String {
        format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} |",
            self.id,
            self.window.label(),
            if self.is_master() {
                "Master".to_string()
            } else {
                format!("Worker{}", self.rank)
            },
            self.data.label(self.is_master()),
            self.effect,
            self.p_det.unwrap_or("-"),
            self.p_rec,
            self.n_roll,
        )
    }
}

/// Checkpoint phase cursors of the matmul test app.
const CKS: [u64; 4] = [phases::CK0, phases::CK1, phases::CK2, phases::CK3];

/// The §4.1 prediction oracle: given where a fault lands and what it hits,
/// derive effect, detection point and recovery cost from the dataflow of
/// Algorithm 3.
pub fn predict(
    window: Window,
    rank: usize,
    data: DataTarget,
) -> (FaultClass, Option<&'static str>, Rec, u32) {
    use DataTarget as D;
    use FaultClass as F;
    use Window as W;
    let master = rank == 0;
    let w = window;

    // Step 1: effect + detection phase, from the data's future use.
    let (effect, det): (F, Option<(&'static str, u64)>) = match (data, master) {
        // --- master's full A: used (only) by SCATTER.
        (D::AWorkerPart, true) => match w {
            W::InitCk0 | W::Ck0Scatter => (F::Tdc, Some(("SCATTER", phases::SCATTER))),
            _ => (F::Le, None), // A unused after SCATTER
        },
        (D::AMasterPart, true) => match w {
            // Master's own rows flow A → A_chunk → C_chunk → C, all local.
            W::InitCk0 | W::Ck0Scatter => (F::Fsc, Some(("VALIDATE", phases::VALIDATE))),
            _ => (F::Le, None),
        },
        // --- A_chunk: written at SCATTER, read at MATMUL.
        (D::AChunk, true) => match w {
            W::ScatterCk1 | W::Ck1Bcast | W::BcastCk2 => {
                (F::Fsc, Some(("VALIDATE", phases::VALIDATE)))
            }
            _ => (F::Le, None), // overwritten by SCATTER / unused after MATMUL
        },
        (D::AChunk, false) => match w {
            W::ScatterCk1 | W::Ck1Bcast | W::BcastCk2 => {
                (F::Tdc, Some(("GATHER", phases::GATHER)))
            }
            _ => (F::Le, None),
        },
        // --- B: master's is transmitted at BCAST; workers' is received there.
        (D::B, true) => match w {
            W::InitCk0 | W::Ck0Scatter | W::ScatterCk1 | W::Ck1Bcast => {
                (F::Tdc, Some(("BCAST", phases::BCAST)))
            }
            // Already sent: only the master's own compute uses it now.
            W::BcastCk2 => (F::Fsc, Some(("VALIDATE", phases::VALIDATE))),
            _ => (F::Le, None),
        },
        (D::B, false) => match w {
            W::BcastCk2 => (F::Tdc, Some(("GATHER", phases::GATHER))),
            _ => (F::Le, None), // overwritten by BCAST / unused after MATMUL
        },
        // --- C at the master: every element is (re)written at GATHER.
        (D::CMaster, true) => match w {
            W::GatherCk3 | W::Ck3Validate => (F::Fsc, Some(("VALIDATE", phases::VALIDATE))),
            _ => (F::Le, None),
        },
        // --- C_chunk: written at MATMUL; master's lands in C locally,
        //     workers' is transmitted at GATHER.
        (D::CChunk, true) => match w {
            W::MatmulGather => (F::Fsc, Some(("VALIDATE", phases::VALIDATE))),
            _ => (F::Le, None),
        },
        (D::CChunk, false) => match w {
            W::MatmulGather => (F::Tdc, Some(("GATHER", phases::GATHER))),
            _ => (F::Le, None),
        },
        // --- loop index during MATMUL: one replica redoes work → TOE at
        //     the next rendezvous (GATHER), master and worker alike.
        (D::Index, _) => (F::Toe, Some(("GATHER", phases::GATHER))),
        // Invalid combinations (A on a worker, C on a worker, …).
        (D::AMasterPart, false) | (D::AWorkerPart, false) | (D::CMaster, false) => {
            unreachable!("invalid scenario: {data:?} on worker")
        }
    };

    // Step 2: rollback arithmetic. TOE corrupts no state, so its
    // checkpoints are all clean — the formula still holds because MATMUL
    // and GATHER straddle no checkpoint.
    match det {
        None => (effect, None, Rec::None, 0),
        Some((site, det_cursor)) => {
            let (p_rec, n_roll) = rollback_arith(w, det_cursor);
            (effect, Some(site), p_rec, n_roll)
        }
    }
}

/// The rollback arithmetic shared by both collective modes: a checkpoint
/// stored in [injection, detection] captured the corrupted state → dirty;
/// Algorithm 1 walks back through all dirty ones to the nearest clean one
/// (or scratch).
fn rollback_arith(window: Window, det_cursor: u64) -> (Rec, u32) {
    let inj_cursor = window.inj_cursor();
    let clean_before_inj = CKS.iter().filter(|c| **c < inj_cursor).count() as u64;
    let stored_before_det = CKS.iter().filter(|c| **c < det_cursor).count() as u64;
    let n_roll = (stored_before_det - clean_before_inj + 1) as u32;
    let p_rec = if clean_before_inj > 0 {
        Rec::Ck(clean_before_inj - 1)
    } else {
        Rec::Scratch
    };
    (p_rec, n_roll)
}

/// The §4.2 prediction oracle for **native (optimized) collectives**.
///
/// > "in collective communications, the sender process also participates,
/// > … the corrupted data gets transmitted and hence it is validated. In
/// > this way, only TDC scenarios remain and FSC scenarios should not be
/// > present any longer."
///
/// Under native collectives the root's own contribution crosses the wire
/// and is validated inside the collective, so every FSC whose corrupted
/// datum later feeds a collective's root contribution flips to a TDC at
/// that collective — detected earlier, with a shorter rollback. The only
/// FSC rows that *survive* are corruptions of `C` at the master **after**
/// GATHER: that data is never transmitted again, so the final-result
/// comparison remains the first (and only) detector.
pub fn predict_native(
    window: Window,
    rank: usize,
    data: DataTarget,
) -> (FaultClass, Option<&'static str>, Rec, u32) {
    use DataTarget as D;
    use Window as W;
    let (effect, p_det, p_rec, n_roll) = predict(window, rank, data);
    if effect != FaultClass::Fsc {
        // TDC / LE / TOE coverage is identical in both modes: the flipped
        // window only ever existed for root-local (FSC) corruption.
        return (effect, p_det, p_rec, n_roll);
    }
    let master = rank == 0;
    let det: Option<(&'static str, u64)> = match (data, window) {
        // Master's own rows of A feed the master's own scatter chunk — part
        // of the full scatter payload the native root validates.
        (D::AMasterPart, W::InitCk0 | W::Ck0Scatter) => {
            Some(("SCATTER", phases::SCATTER))
        }
        // Master's A_chunk → C_chunk at MATMUL → the master's own gather
        // contribution, validated by the native gather.
        (D::AChunk, W::ScatterCk1 | W::Ck1Bcast | W::BcastCk2) if master => {
            Some(("GATHER", phases::GATHER))
        }
        // B already broadcast; the master's corrupted copy only feeds its
        // own C_chunk — caught at the native gather.
        (D::B, W::BcastCk2) if master => Some(("GATHER", phases::GATHER)),
        // Master's C_chunk corrupted right before GATHER: its own gather
        // contribution (the ablation test's canonical flip).
        (D::CChunk, W::MatmulGather) if master => Some(("GATHER", phases::GATHER)),
        // C at the master after GATHER is never transmitted again — the
        // residual FSC window native collectives cannot close.
        _ => None,
    };
    match det {
        None => (effect, p_det, p_rec, n_roll),
        Some((site, det_cursor)) => {
            let (p_rec, n_roll) = rollback_arith(window, det_cursor);
            (FaultClass::Tdc, Some(site), p_rec, n_roll)
        }
    }
}

/// A scenario's prediction columns under a given collectives mode: the
/// catalog is authored against the paper's point-to-point implementation;
/// [`predict_native`] rewrites the columns for the optimized one. The
/// campaign shard grades every matmul paper cell against the scenario this
/// returns for the cell's `collectives` axis value.
pub fn scenario_under(collectives: CollectiveImpl, sc: &Scenario) -> Scenario {
    match collectives {
        CollectiveImpl::PointToPoint => sc.clone(),
        CollectiveImpl::Native => {
            let (effect, p_det, p_rec, n_roll) = predict_native(sc.window, sc.rank, sc.data);
            Scenario {
                effect,
                p_det,
                p_rec,
                n_roll,
                ..sc.clone()
            }
        }
    }
}

/// Build the full 64-scenario catalog for a given matmul geometry.
///
/// Composition (matching §4.1's design criteria):
/// * 8 data windows × master targets {A(M), A(W), B, C(M)}   = 32
/// * 8 data windows × worker targets {A_chunk, B, C_chunk}   = 24
/// * master A_chunk in the 3 windows where it is live + one LE window = 4
/// * master C_chunk in {MATMUL→GATHER, GATHER→CK3}           = 2
/// * index corruption during MATMUL on master and on a worker = 2
pub fn catalog(app: &MatmulApp) -> Vec<Scenario> {
    assert!(app.nranks >= 3, "catalog needs at least 2 workers");
    let mut out = Vec::with_capacity(64);
    let mut id = 0;
    let mut push = |window: Window, rank: usize, data: DataTarget| {
        id += 1;
        let (effect, p_det, p_rec, n_roll) = predict(window, rank, data);
        out.push(Scenario {
            id,
            window,
            rank,
            data,
            effect,
            p_det,
            p_rec,
            n_roll,
        });
    };

    for wdw in Window::DATA_WINDOWS {
        for data in [
            DataTarget::AMasterPart,
            DataTarget::AWorkerPart,
            DataTarget::B,
            DataTarget::CMaster,
        ] {
            push(wdw, 0, data);
        }
        // Representative worker, varied across windows.
        let worker = 1 + (wdw.inj_cursor() as usize % (app.nranks - 1));
        for data in [DataTarget::AChunk, DataTarget::B, DataTarget::CChunk] {
            push(wdw, worker, data);
        }
    }
    // Master's A_chunk: its three live windows + one latent window.
    for wdw in [
        Window::ScatterCk1,
        Window::Ck1Bcast,
        Window::BcastCk2,
        Window::MatmulGather,
    ] {
        push(wdw, 0, DataTarget::AChunk);
    }
    // Master's C_chunk: live (FSC) and latent.
    push(Window::MatmulGather, 0, DataTarget::CChunk);
    push(Window::GatherCk3, 0, DataTarget::CChunk);
    // Index corruption (TOE): i(M) and i(W).
    push(Window::DuringMatmul, 0, DataTarget::Index);
    push(Window::DuringMatmul, 1, DataTarget::Index);

    assert_eq!(out.len(), 64, "the workfault must have exactly 64 scenarios");
    out
}

/// Materialize the [`InjectionSpec`] that realizes a scenario on a concrete
/// matmul geometry (element indices are picked inside the right region).
pub fn injection_for(app: &MatmulApp, sc: &Scenario, cfg: &RunConfig) -> InjectionSpec {
    let n = app.n;
    let rows = app.chunk_rows();
    let kind = match sc.data {
        DataTarget::Index => InjectKind::IndexRollback {
            redo_blocks: app.sub_blocks as u64,
            // Comfortably exceed the TOE lapse so the sibling's rendezvous
            // at GATHER expires deterministically.
            extra_delay: cfg.toe_timeout * 3,
        },
        data => {
            let (var, elem) = match data {
                DataTarget::AMasterPart => ("A", (rows / 2) * n + 3),
                // Land in worker 2's chunk of A.
                DataTarget::AWorkerPart => ("A", (2 * rows + 1) * n + 5),
                DataTarget::AChunk => ("A_chunk", n + 2),
                DataTarget::B => ("B", 2 * n + 7),
                DataTarget::CMaster => ("C", (rows / 2) * n + 9),
                DataTarget::CChunk => ("C_chunk", n + 4),
                DataTarget::Index => unreachable!(),
            };
            InjectKind::BitFlip {
                var: var.to_string(),
                elem,
                // A high exponent bit: the corrupted value differs wildly,
                // like the paper's register bit-flips.
                bit: 30,
            }
        }
    };
    let point = match sc.window {
        Window::DuringMatmul => InjectPoint::DuringPhase {
            phase: phases::MATMUL,
            after_subblock: 1,
        },
        w => InjectPoint::BeforePhase(w.inj_cursor()),
    };
    InjectionSpec {
        name: format!("scenario-{}", sc.id),
        point,
        rank: sc.rank,
        replica: 1,
        kind,
    }
}

/// What a campaign run observed, compared against the prediction.
#[derive(Debug)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub outcome: RunOutcome,
    pub pass: bool,
    pub mismatches: Vec<String>,
}

/// Check every §4.1 prediction column against an observed outcome of a run
/// under the multiple-system-level-checkpoint strategy. Returns the list of
/// divergences (empty = the scenario behaved exactly as predicted). Shared
/// by [`run_scenario`] and the parallel campaign shard
/// ([`crate::campaign::shard`]).
pub fn check_prediction(sc: &Scenario, outcome: &RunOutcome) -> Vec<String> {
    let mut mismatches = Vec::new();
    if !outcome.completed {
        mismatches.push("run did not complete".into());
    }
    if outcome.result_correct != Some(true) {
        mismatches.push(format!(
            "final result not correct: {:?}",
            outcome.result_correct
        ));
    }
    if !outcome.injected && sc.effect != FaultClass::Le {
        mismatches.push("injection never fired".into());
    }
    if outcome.restarts != sc.n_roll {
        mismatches.push(format!(
            "N_roll: predicted {}, observed {}",
            sc.n_roll, outcome.restarts
        ));
    }
    match (sc.effect, outcome.detections.first()) {
        (FaultClass::Le, None) => {}
        (FaultClass::Le, Some(ev)) => {
            mismatches.push(format!("predicted LE but detected {} at {}", ev.class, ev.site))
        }
        (want, None) => mismatches.push(format!("predicted {want} but nothing detected")),
        (want, Some(ev)) => {
            if ev.class != want {
                mismatches.push(format!("effect: predicted {want}, observed {}", ev.class));
            }
            if let Some(site) = sc.p_det {
                if ev.site != site {
                    mismatches.push(format!(
                        "P_det: predicted {site}, observed {}",
                        ev.site
                    ));
                }
            }
        }
    }
    // Recovery point: the last resume of the run must match P_rec.
    match (sc.p_rec, outcome.resume_history.last()) {
        (Rec::None, None) => {}
        (Rec::None, Some(r)) => mismatches.push(format!("predicted no rollback, got {r}")),
        (Rec::Ck(k), Some(ResumeFrom::SysCkpt(got))) if *got == k => {}
        (Rec::Scratch, Some(ResumeFrom::Scratch)) => {}
        (want, got) => mismatches.push(format!("P_rec: predicted {want}, observed {got:?}")),
    }
    mismatches
}

/// Run one scenario under the multiple-system-level-checkpoint strategy and
/// check every prediction column (the §4.2 validation, mechanized). The
/// prediction is taken under the config's `collectives` mode, so the same
/// catalog grades both implementations.
pub fn run_scenario(
    app: &MatmulApp,
    sc: &Scenario,
    base_cfg: &RunConfig,
) -> Result<ScenarioResult> {
    let mut cfg = base_cfg.clone();
    cfg.strategy = Strategy::SysCkpt;
    cfg.run_dir = base_cfg.run_dir.join(format!("sc{}", sc.id));
    let spec = injection_for(app, sc, &cfg);
    let effective = scenario_under(cfg.collectives, sc);
    let run = SedarRun::new(Arc::new(app.clone()), cfg, Some(spec));
    let outcome = run.run()?;
    let mismatches = check_prediction(&effective, &outcome);

    Ok(ScenarioResult {
        scenario: sc.clone(),
        pass: mismatches.is_empty(),
        mismatches,
        outcome,
    })
}

/// The Table-2 header used by reports.
pub fn table2_header() -> String {
    "| Scenario | P_inj | Process | Data | Effect | P_det | P_rec | N_roll |\n\
     |---|---|---|---|---|---|---|---|"
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::spec::AppSpec;

    fn app() -> MatmulApp {
        MatmulApp::new(64, 4)
    }

    #[test]
    fn catalog_has_64_scenarios() {
        let c = catalog(&app());
        assert_eq!(c.len(), 64);
        // All four effect classes are represented.
        for class in [
            FaultClass::Tdc,
            FaultClass::Fsc,
            FaultClass::Le,
            FaultClass::Toe,
        ] {
            assert!(
                c.iter().any(|s| s.effect == class),
                "no scenario with effect {class}"
            );
        }
    }

    #[test]
    fn paper_table2_rows_reproduced() {
        // The four representative scenarios the paper details in Table 2.
        // Scenario 2: CK0–SCATTER, Master, A(W) → TDC @SCATTER, CK0, 1 roll.
        let (e, d, r, n) = predict(Window::Ck0Scatter, 0, DataTarget::AWorkerPart);
        assert_eq!(
            (e, d, r, n),
            (FaultClass::Tdc, Some("SCATTER"), Rec::Ck(0), 1)
        );
        // Scenario 29: BCAST–CK2, Worker, C(W) → LE.
        let (e, d, r, n) = predict(Window::BcastCk2, 2, DataTarget::CChunk);
        assert_eq!((e, d, r, n), (FaultClass::Le, None, Rec::None, 0));
        // Scenario 50: GATHER–CK3, Master, C(M) → FSC @VALIDATE, CK2, 2.
        let (e, d, r, n) = predict(Window::GatherCk3, 0, DataTarget::CMaster);
        assert_eq!(
            (e, d, r, n),
            (FaultClass::Fsc, Some("VALIDATE"), Rec::Ck(2), 2)
        );
        // Scenario 59: MATMUL, Worker, i(W) → TOE @GATHER, CK2, 1.
        let (e, d, r, n) = predict(Window::DuringMatmul, 1, DataTarget::Index);
        assert_eq!(
            (e, d, r, n),
            (FaultClass::Toe, Some("GATHER"), Rec::Ck(2), 1)
        );
    }

    #[test]
    fn pre_ck0_faults_force_scratch() {
        let (e, _, r, n) = predict(Window::InitCk0, 0, DataTarget::AWorkerPart);
        assert_eq!(e, FaultClass::Tdc);
        assert_eq!(r, Rec::Scratch);
        assert_eq!(n, 2); // try CK0 (dirty), then scratch
    }

    #[test]
    fn deep_fsc_walks_whole_chain() {
        // A(M) corrupted before CK0: every checkpoint is dirty; the walk
        // goes CK3 → CK2 → CK1 → CK0 → scratch = 5 attempts.
        let (e, d, r, n) = predict(Window::InitCk0, 0, DataTarget::AMasterPart);
        assert_eq!(e, FaultClass::Fsc);
        assert_eq!(d, Some("VALIDATE"));
        assert_eq!(r, Rec::Scratch);
        assert_eq!(n, 5);
    }

    #[test]
    fn injections_target_valid_vars() {
        let app = app();
        let cfg = RunConfig::for_tests("wf-spec");
        for sc in catalog(&app) {
            let spec = injection_for(&app, &sc, &cfg);
            if let InjectKind::BitFlip { var, elem, .. } = &spec.kind {
                let store = app.init_store(sc.rank, 1);
                let v = store.get(var).expect("target var exists on that rank");
                assert!(
                    *elem < v.numel(),
                    "scenario {}: elem {} out of range for {var}",
                    sc.id,
                    elem
                );
            }
        }
    }

    #[test]
    fn native_oracle_flips_root_fsc_to_tdc() {
        use FaultClass as F;
        // AMasterPart before CK0: under p2p a deep FSC (5 rolls), under
        // native the scatter payload carries the master's own chunk → TDC
        // at SCATTER, same shape as the AWorkerPart row.
        let (e, d, r, n) = predict_native(Window::InitCk0, 0, DataTarget::AMasterPart);
        assert_eq!((e, d, r, n), (F::Tdc, Some("SCATTER"), Rec::Scratch, 2));
        assert_eq!(
            predict_native(Window::InitCk0, 0, DataTarget::AMasterPart),
            predict(Window::InitCk0, 0, DataTarget::AWorkerPart),
            "native AMasterPart must grade like the transmitted twin row"
        );
        // Master's A_chunk after SCATTER feeds its own gather contribution.
        let (e, d, r, n) = predict_native(Window::ScatterCk1, 0, DataTarget::AChunk);
        assert_eq!((e, d, r, n), (F::Tdc, Some("GATHER"), Rec::Ck(0), 3));
        // Master's C_chunk right before GATHER: the ablation pair — TDC at
        // GATHER with a single clean rollback.
        let (e, d, r, n) = predict_native(Window::MatmulGather, 0, DataTarget::CChunk);
        assert_eq!((e, d, r, n), (F::Tdc, Some("GATHER"), Rec::Ck(2), 1));
        // C(M) after GATHER is never transmitted again: the FSC survives.
        let (e, d, ..) = predict_native(Window::GatherCk3, 0, DataTarget::CMaster);
        assert_eq!((e, d), (F::Fsc, Some("VALIDATE")));
        // TDC / LE / TOE rows are mode-invariant.
        for (w, rank, data) in [
            (Window::Ck0Scatter, 0, DataTarget::AWorkerPart),
            (Window::BcastCk2, 2, DataTarget::CChunk),
            (Window::DuringMatmul, 1, DataTarget::Index),
        ] {
            assert_eq!(predict_native(w, rank, data), predict(w, rank, data));
        }
    }

    #[test]
    fn scenario_under_is_identity_for_p2p() {
        let app = app();
        for sc in catalog(&app) {
            let p2p = scenario_under(CollectiveImpl::PointToPoint, &sc);
            assert_eq!(p2p.effect, sc.effect);
            assert_eq!(p2p.p_det, sc.p_det);
            assert_eq!(p2p.n_roll, sc.n_roll);
            let native = scenario_under(CollectiveImpl::Native, &sc);
            // §4.2's claim, mechanized: native coverage never loses a
            // detection, and no FSC-at-a-collective remains.
            if sc.effect == FaultClass::Fsc {
                assert!(
                    native.effect == FaultClass::Tdc || native.p_det == Some("VALIDATE"),
                    "sc{}: native left an FSC detected away from VALIDATE",
                    sc.id
                );
            } else {
                assert_eq!(native.effect, sc.effect, "sc{}", sc.id);
            }
        }
    }

    #[test]
    fn rows_render() {
        let c = catalog(&app());
        let row = c[1].row();
        assert!(row.starts_with("| 2 |"));
        assert!(table2_header().contains("P_rec"));
    }
}
