//! Run configuration: strategy selection, timing knobs, directories.
//!
//! A [`RunConfig`] fully determines a SEDAR run (together with an app spec
//! and an optional injection). Configs can be parsed from a simple
//! `key = value` file (see [`RunConfig::from_kv`]) and overridden from the
//! CLI; no external config-format crate exists in the offline set, and the
//! paper's artifact would have used environment variables anyway.

use std::path::PathBuf;
use std::time::Duration;

use crate::checkpoint::snapshot::Codec;
use crate::detect::ValidationMode;
use crate::error::{Result, SedarError};
use crate::faultnet::NetFaultMode;
use crate::util::clock::ClockMode;

/// The protection strategy — the three SEDAR levels plus the paper's
/// baseline (§3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Two independent instances + final comparison (+ third run & vote on
    /// mismatch). The reference point of Equations 1–2.
    Baseline,
    /// SEDAR level 1: detection with notification & safe stop (Equations 3–4).
    DetectOnly,
    /// SEDAR level 2: recovery from multiple system-level checkpoints
    /// (Equations 5–6, Algorithm 1).
    SysCkpt,
    /// SEDAR level 3: recovery from a single validated application-level
    /// checkpoint (Equations 7–8, Algorithm 2).
    UserCkpt,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "baseline" => Strategy::Baseline,
            "detect" | "detect-only" | "detectonly" => Strategy::DetectOnly,
            "sys" | "sysckpt" | "sys-ckpt" | "multiple" => Strategy::SysCkpt,
            "user" | "userckpt" | "user-ckpt" | "single" => Strategy::UserCkpt,
            other => {
                return Err(SedarError::Config(format!(
                    "unknown strategy '{other}' (baseline|detect|sysckpt|userckpt)"
                )))
            }
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Baseline => "baseline",
            Strategy::DetectOnly => "detect-only",
            Strategy::SysCkpt => "sys-ckpt",
            Strategy::UserCkpt => "user-ckpt",
        }
    }
}

/// How SEDAR's communication wrappers implement collectives (§4.2: the
/// functional validation uses point-to-point; optimized native collectives
/// exist for the temporal evaluation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CollectiveImpl {
    /// Compose scatter/gather/bcast from validated point-to-point sends.
    /// More comparison points ⇒ FSC scenarios become visible (§4.2).
    PointToPoint,
    /// Validate once, then use the substrate's native collective. The
    /// sender's own contribution crosses the wire too, so root-local
    /// corruption is validated *at the collective* — the FSC window closes
    /// at scatter/gather roots (§4.2).
    Native,
}

impl CollectiveImpl {
    /// The single parser behind the config key and the campaign filter —
    /// one set of accepted spellings.
    pub fn parse(s: &str) -> Result<CollectiveImpl> {
        Ok(match s {
            "p2p" | "point-to-point" => CollectiveImpl::PointToPoint,
            "native" | "optimized" => CollectiveImpl::Native,
            other => {
                return Err(SedarError::Config(format!(
                    "unknown collectives '{other}' (p2p|native)"
                )))
            }
        })
    }

    /// Short label for report rows and filters.
    pub fn label(self) -> &'static str {
        match self {
            CollectiveImpl::PointToPoint => "p2p",
            CollectiveImpl::Native => "native",
        }
    }
}

/// Full configuration of one SEDAR run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Protection strategy.
    pub strategy: Strategy,
    /// Message-validation mode (full contents vs SHA-256 digests).
    pub validation: ValidationMode,
    /// Collective implementation.
    pub collectives: CollectiveImpl,
    /// Deterministic network-fault family perturbing vmpi deliveries
    /// (`none` = no fault layer installed).
    pub netfault: NetFaultMode,
    /// Clock the run's world lives on: `Wall` (real time; interactive and
    /// bench default) or `Virtual` (logical ticks, quiescence-driven;
    /// campaign default). Timeouts below are *modeled time* — under `Wall`
    /// a `Duration` is real time, under `Virtual` it is the identical count
    /// of 1 ns ticks (`util::clock::Clock::ticks` is the one conversion
    /// point), so a given timeout means the same amount of modeled time in
    /// both modes.
    pub clock: ClockMode,
    /// Replica-rendezvous lapse after which a missing sibling is a TOE.
    pub toe_timeout: Duration,
    /// Rendezvous lapse for slow sites (checkpoint writes).
    pub ckpt_timeout: Duration,
    /// Working directory of the run (checkpoints, latches, counters, trace).
    pub run_dir: PathBuf,
    /// Snapshot codec.
    pub codec: Codec,
    /// Use the AOT XLA artifacts for compute (vs the pure-rust fallback).
    pub use_xla: bool,
    /// Artifact directory (only consulted when `use_xla`).
    pub artifact_dir: PathBuf,
    /// Workload seed (matrix / sequence generation).
    pub seed: u64,
    /// Safety bound on recovery attempts (Algorithm 1 loop).
    pub max_attempts: u32,
    /// Echo the event trace to stderr as it happens.
    pub echo_trace: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            strategy: Strategy::SysCkpt,
            validation: ValidationMode::Full,
            collectives: CollectiveImpl::PointToPoint,
            netfault: NetFaultMode::None,
            clock: ClockMode::Wall,
            toe_timeout: Duration::from_millis(1500),
            ckpt_timeout: Duration::from_secs(60),
            run_dir: PathBuf::from("runs/default"),
            codec: Codec::Raw,
            use_xla: false,
            artifact_dir: PathBuf::from("artifacts"),
            seed: 0xC0FFEE,
            max_attempts: 32,
            echo_trace: false,
        }
    }
}

impl RunConfig {
    /// A config suitable for fast unit/integration tests: tight timeouts,
    /// raw snapshots, unique run dir under the system temp dir.
    pub fn for_tests(tag: &str) -> RunConfig {
        let run_dir = std::env::temp_dir().join(format!(
            "sedar-test-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&run_dir);
        RunConfig {
            toe_timeout: Duration::from_millis(400),
            ckpt_timeout: Duration::from_secs(20),
            run_dir,
            codec: Codec::Raw,
            ..RunConfig::default()
        }
    }

    /// Apply one `key = value` assignment via the [`KEYS`] registry.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match KEYS.iter().find(|k| k.name == key) {
            Some(k) => (k.set)(self, value),
            None => Err(SedarError::Config(format!(
                "unknown config key '{key}' (valid: {})",
                Self::key_listing()
            ))),
        }
    }

    /// Every settable key with its value kind, e.g. `seed <count>` — used
    /// in the unknown-key error and by `--help` style listings.
    pub fn key_listing() -> String {
        KEYS.iter()
            .map(|k| format!("{} <{}>", k.name, k.kind))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Parse a `key = value` config file body (`#` comments, blank lines ok).
    pub fn from_kv(body: &str) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        for (lineno, line) in body.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                SedarError::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            cfg.set(k.trim(), v.trim())?;
        }
        Ok(cfg)
    }
}

/// One settable config key: name, value kind (for self-describing error
/// listings) and setter. A new key — like the virtual-clock additions — is
/// one entry here; `set`, `from_kv` and the unknown-key message all follow.
struct KeyDef {
    name: &'static str,
    /// Value kind shown in listings: `choice`, `duration-ms`, `ticks`,
    /// `count`, `flag` or `path`.
    kind: &'static str,
    set: fn(&mut RunConfig, &str) -> Result<()>,
}

const KEYS: &[KeyDef] = &[
    KeyDef {
        name: "strategy",
        kind: "choice",
        set: |c, v| {
            c.strategy = Strategy::parse(v)?;
            Ok(())
        },
    },
    KeyDef {
        name: "validation",
        kind: "choice",
        set: |c, v| {
            c.validation = ValidationMode::parse(v)?;
            Ok(())
        },
    },
    KeyDef {
        name: "collectives",
        kind: "choice",
        set: |c, v| {
            c.collectives = CollectiveImpl::parse(v)?;
            Ok(())
        },
    },
    KeyDef {
        name: "netfault",
        kind: "choice",
        set: |c, v| {
            c.netfault = NetFaultMode::parse(v)?;
            Ok(())
        },
    },
    KeyDef {
        name: "clock",
        kind: "choice",
        set: |c, v| {
            c.clock = ClockMode::parse(v)?;
            Ok(())
        },
    },
    KeyDef {
        name: "toe_timeout_ms",
        kind: "duration-ms",
        set: |c, v| {
            c.toe_timeout = Duration::from_millis(parse_num("toe_timeout_ms", v)?);
            Ok(())
        },
    },
    KeyDef {
        // Tick-denominated twin of `toe_timeout_ms` (1 tick = 1 ns): lets
        // virtual-clock configs state lapses in the clock's own unit.
        name: "toe_timeout_ticks",
        kind: "ticks",
        set: |c, v| {
            c.toe_timeout = Duration::from_nanos(parse_num("toe_timeout_ticks", v)?);
            Ok(())
        },
    },
    KeyDef {
        name: "ckpt_timeout_ms",
        kind: "duration-ms",
        set: |c, v| {
            c.ckpt_timeout = Duration::from_millis(parse_num("ckpt_timeout_ms", v)?);
            Ok(())
        },
    },
    KeyDef {
        name: "ckpt_timeout_ticks",
        kind: "ticks",
        set: |c, v| {
            c.ckpt_timeout = Duration::from_nanos(parse_num("ckpt_timeout_ticks", v)?);
            Ok(())
        },
    },
    KeyDef {
        name: "run_dir",
        kind: "path",
        set: |c, v| {
            c.run_dir = PathBuf::from(v);
            Ok(())
        },
    },
    KeyDef {
        name: "codec",
        kind: "choice",
        set: |c, v| {
            c.codec = parse_codec(v)?;
            Ok(())
        },
    },
    KeyDef {
        name: "use_xla",
        kind: "flag",
        set: |c, v| {
            c.use_xla = parse_bool("use_xla", v)?;
            Ok(())
        },
    },
    KeyDef {
        name: "artifact_dir",
        kind: "path",
        set: |c, v| {
            c.artifact_dir = PathBuf::from(v);
            Ok(())
        },
    },
    KeyDef {
        name: "seed",
        kind: "count",
        set: |c, v| {
            c.seed = parse_num("seed", v)?;
            Ok(())
        },
    },
    KeyDef {
        name: "max_attempts",
        kind: "count",
        set: |c, v| {
            c.max_attempts = parse_num("max_attempts", v)? as u32;
            Ok(())
        },
    },
    KeyDef {
        name: "echo_trace",
        kind: "flag",
        set: |c, v| {
            c.echo_trace = parse_bool("echo_trace", v)?;
            Ok(())
        },
    },
];

fn parse_codec(value: &str) -> Result<Codec> {
    match value {
        "raw" => Ok(Codec::Raw),
        s if s.starts_with("deflate") => {
            let lvl = s
                .strip_prefix("deflate")
                .unwrap()
                .trim_matches(|c| c == '(' || c == ')')
                .parse()
                .unwrap_or(1);
            Ok(Codec::Deflate(lvl))
        }
        other => Err(SedarError::Config(format!(
            "unknown codec '{other}' (raw|deflateN)"
        ))),
    }
}

fn parse_num(key: &str, value: &str) -> Result<u64> {
    value
        .parse()
        .map_err(|e| SedarError::Config(format!("{key}: {e}")))
}

fn parse_bool(key: &str, value: &str) -> Result<bool> {
    match value {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        other => Err(SedarError::Config(format!("{key}: bad bool '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_strategies() {
        assert_eq!(Strategy::parse("baseline").unwrap(), Strategy::Baseline);
        assert_eq!(Strategy::parse("detect").unwrap(), Strategy::DetectOnly);
        assert_eq!(Strategy::parse("sysckpt").unwrap(), Strategy::SysCkpt);
        assert_eq!(Strategy::parse("user").unwrap(), Strategy::UserCkpt);
        assert!(Strategy::parse("magic").is_err());
    }

    #[test]
    fn kv_file_roundtrip() {
        let cfg = RunConfig::from_kv(
            "# comment\n\
             strategy = userckpt\n\
             validation = sha256\n\
             toe_timeout_ms = 250\n\
             seed = 99\n\
             collectives = native\n\
             codec = deflate(6)\n",
        )
        .unwrap();
        assert_eq!(cfg.strategy, Strategy::UserCkpt);
        assert_eq!(cfg.validation, ValidationMode::Sha256);
        assert_eq!(cfg.toe_timeout, Duration::from_millis(250));
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.collectives, CollectiveImpl::Native);
        assert_eq!(cfg.codec, Codec::Deflate(6));
    }

    #[test]
    fn netfault_key_parses_every_mode() {
        assert_eq!(RunConfig::default().netfault, NetFaultMode::None);
        for mode in NetFaultMode::ALL {
            let cfg =
                RunConfig::from_kv(&format!("netfault = {}", mode.label())).unwrap();
            assert_eq!(cfg.netfault, mode);
        }
        assert!(RunConfig::from_kv("netfault = cosmic").is_err());
    }

    #[test]
    fn kv_rejects_unknown_keys_and_bad_lines() {
        assert!(RunConfig::from_kv("nope = 1").is_err());
        assert!(RunConfig::from_kv("strategy").is_err());
        assert!(RunConfig::from_kv("use_xla = maybe").is_err());
    }

    #[test]
    fn unknown_key_error_lists_the_registry() {
        let err = RunConfig::from_kv("nope = 1").unwrap_err().to_string();
        for name in [
            "strategy",
            "clock",
            "netfault",
            "toe_timeout_ms",
            "toe_timeout_ticks",
        ] {
            assert!(err.contains(name), "'{name}' missing from: {err}");
        }
    }

    #[test]
    fn clock_and_tick_keys_parse() {
        let cfg = RunConfig::from_kv(
            "clock = virtual\n\
             toe_timeout_ticks = 2000000\n\
             ckpt_timeout_ticks = 5000000000\n",
        )
        .unwrap();
        assert_eq!(cfg.clock, ClockMode::Virtual);
        assert_eq!(cfg.toe_timeout, Duration::from_millis(2));
        assert_eq!(cfg.ckpt_timeout, Duration::from_secs(5));
        assert!(RunConfig::from_kv("clock = sundial").is_err());
    }

    #[test]
    fn ms_and_tick_spellings_agree() {
        // 1 tick = 1 ns: the two spellings of the same lapse must coincide.
        let a = RunConfig::from_kv("toe_timeout_ms = 250").unwrap();
        let b = RunConfig::from_kv("toe_timeout_ticks = 250000000").unwrap();
        assert_eq!(a.toe_timeout, b.toe_timeout);
    }
}
