//! The application state: a store of named, typed, shaped buffers.
//!
//! Everything an application rank knows lives in a [`VarStore`]: input
//! matrices, result blocks, sequence buffers, progress counters. This is the
//! unit of capture for *system-level* checkpoints (the whole store of both
//! replicas) and — filtered through the app's *significant variables* list —
//! for user-level checkpoints. It is also the surface the fault injector
//! mutates.
//!
//! The binary serialization is a simple self-describing little-endian format
//! (magic + version + sorted var records); framing, compression and CRC live
//! one level up in [`crate::checkpoint::snapshot`].

use std::collections::BTreeMap;

use crate::error::{Result, SedarError};
use crate::util::bytes::SharedBuf;

/// Element type of a variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    F64,
    I64,
    U8,
}

impl DType {
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
            DType::I64 => 8,
            DType::U8 => 1,
        }
    }

    fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
            DType::I64 => 2,
            DType::U8 => 3,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => DType::F32,
            1 => DType::F64,
            2 => DType::I64,
            3 => DType::U8,
            _ => {
                return Err(SedarError::Checkpoint(format!(
                    "unknown dtype tag {t}"
                )))
            }
        })
    }
}

/// Typed storage over a shared, word-aligned byte buffer
/// ([`crate::util::bytes::SharedBuf`]).
///
/// Cloning a `Buf` is a reference-count bump — a broadcast payload, a
/// mailbox envelope and the sender's store variable are all views of one
/// allocation. Mutation (`bytes_mut`, `as_*_mut`) is copy-on-write, so
/// holders of a shared buffer never observe each other's writes. The
/// storage is 8-byte aligned by construction, so the typed views are plain
/// pointer casts — byte views for hashing, comparison and injection are
/// the same bytes, produced for free.
#[derive(Clone, PartialEq)]
pub struct Buf {
    dtype: DType,
    data: SharedBuf,
}

impl Buf {
    pub fn f32(v: &[f32]) -> Buf {
        Buf {
            dtype: DType::F32,
            data: SharedBuf::from_bytes(raw_bytes(v)),
        }
    }

    pub fn f64(v: &[f64]) -> Buf {
        Buf {
            dtype: DType::F64,
            data: SharedBuf::from_bytes(raw_bytes(v)),
        }
    }

    pub fn i64(v: &[i64]) -> Buf {
        Buf {
            dtype: DType::I64,
            data: SharedBuf::from_bytes(raw_bytes(v)),
        }
    }

    pub fn u8(v: &[u8]) -> Buf {
        Buf {
            dtype: DType::U8,
            data: SharedBuf::from_bytes(v),
        }
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len() / self.dtype.size_of()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    /// Immutable little-endian byte view of the raw buffer contents
    /// (x86-64/aarch64 are little-endian, so the view *is* the serialized
    /// form).
    pub fn bytes(&self) -> &[u8] {
        self.data.as_bytes()
    }

    /// Mutable byte view (the fault injector's entry point). Copy-on-write:
    /// a shared buffer is privatized before the first write.
    pub fn bytes_mut(&mut self) -> &mut [u8] {
        self.data.make_mut()
    }

    /// Zero-copy handle to the underlying shared storage (for crossing a
    /// channel without touching the payload bytes).
    pub fn share(&self) -> SharedBuf {
        self.data.clone()
    }

    /// Do two buffers view one allocation? (What the zero-copy broadcast
    /// and send tests assert on.)
    pub fn shares_allocation(&self, other: &Buf) -> bool {
        SharedBuf::ptr_eq(&self.data, &other.data)
    }

    /// Zero-copy element-range view: a `Buf` windowing
    /// `start..start + len` (in elements) of this buffer's allocation.
    /// No payload bytes move — the view is a reference bump — and the
    /// element granularity keeps the typed casts aligned (the storage
    /// base is 8-byte aligned, so an element-multiple byte offset is
    /// aligned for that element type). Copy-on-write still applies:
    /// mutating the view detaches it; the parent never changes.
    pub fn view(&self, start: usize, len: usize) -> Result<Buf> {
        if start.checked_add(len).is_none_or(|end| end > self.len()) {
            return Err(SedarError::Vmpi(format!(
                "view {start}..{} exceeds {} element buffer",
                start.saturating_add(len),
                self.len()
            )));
        }
        let esz = self.dtype.size_of();
        Ok(Buf {
            dtype: self.dtype,
            data: self.data.view(start * esz, len * esz),
        })
    }

    fn expect(&self, want: DType) -> Result<()> {
        if self.dtype == want {
            Ok(())
        } else {
            Err(SedarError::Vmpi(format!(
                "expected {want:?} buffer, found {:?}",
                self.dtype
            )))
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        self.expect(DType::F32)?;
        let b = self.data.as_bytes();
        // Safety: the storage base is 8-byte aligned and view offsets are
        // element multiples, so the pointer is f32-aligned; length is a
        // multiple of 4 by construction (`from_bytes` validates, typed
        // constructors and `view` trivially).
        Ok(unsafe { std::slice::from_raw_parts(b.as_ptr().cast::<f32>(), b.len() / 4) })
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        self.expect(DType::F64)?;
        let b = self.data.as_bytes();
        // Safety: as for `as_f32`, with 8-byte elements.
        Ok(unsafe { std::slice::from_raw_parts(b.as_ptr().cast::<f64>(), b.len() / 8) })
    }

    pub fn as_i64(&self) -> Result<&[i64]> {
        self.expect(DType::I64)?;
        let b = self.data.as_bytes();
        // Safety: as for `as_f32`, with 8-byte elements.
        Ok(unsafe { std::slice::from_raw_parts(b.as_ptr().cast::<i64>(), b.len() / 8) })
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        self.expect(DType::U8)?;
        Ok(self.data.as_bytes())
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        self.expect(DType::F32)?;
        let b = self.data.make_mut();
        let n = b.len() / 4;
        // Safety: as for `as_f32`, plus exclusivity via `make_mut`.
        Ok(unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr().cast::<f32>(), n) })
    }

    pub fn as_i64_mut(&mut self) -> Result<&mut [i64]> {
        self.expect(DType::I64)?;
        let b = self.data.make_mut();
        let n = b.len() / 8;
        // Safety: as for `as_i64`, plus exclusivity via `make_mut`.
        Ok(unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr().cast::<i64>(), n) })
    }

    /// Reconstruct a typed buffer from its byte view.
    pub fn from_bytes(dtype: DType, bytes: &[u8]) -> Result<Buf> {
        let esz = dtype.size_of();
        if bytes.len() % esz != 0 {
            return Err(SedarError::Checkpoint(format!(
                "byte length {} not a multiple of element size {esz}",
                bytes.len()
            )));
        }
        Ok(Buf {
            dtype,
            data: SharedBuf::from_bytes(bytes),
        })
    }
}

impl std::fmt::Debug for Buf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Buf<{:?}>[{} el, {} B, rc {}]",
            self.dtype,
            self.len(),
            self.byte_len(),
            self.data.refcount()
        )
    }
}

/// Little-endian byte view of a typed slice (alignment only ever narrows).
fn raw_bytes<T>(v: &[T]) -> &[u8] {
    // Safety: any initialized T is a valid sequence of bytes; u8 has no
    // alignment requirement.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

/// A named, shaped buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct Var {
    pub shape: Vec<usize>,
    pub buf: Buf,
}

impl Var {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Var {
            shape: shape.to_vec(),
            buf: Buf::f32(&data),
        }
    }

    pub fn i64_scalar(v: i64) -> Self {
        Var {
            shape: vec![],
            buf: Buf::i64(&[v]),
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// The full state of one replica of one rank: named variables, ordered
/// deterministically (BTreeMap) so serialization and hashing are stable.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VarStore {
    vars: BTreeMap<String, Var>,
}

impl VarStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, var: Var) {
        self.vars.insert(name.to_string(), var);
    }

    pub fn get(&self, name: &str) -> Result<&Var> {
        self.vars
            .get(name)
            .ok_or_else(|| SedarError::Vmpi(format!("no variable '{name}' in store")))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Var> {
        self.vars
            .get_mut(name)
            .ok_or_else(|| SedarError::Vmpi(format!("no variable '{name}' in store")))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.vars.contains_key(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<Var> {
        self.vars.remove(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.vars.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.vars.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vars.is_empty()
    }

    /// Total payload bytes across all variables (the "W" column of Table 3).
    pub fn byte_len(&self) -> usize {
        self.vars.values().map(|v| v.buf.byte_len()).sum()
    }

    /// Convenience typed accessors -------------------------------------

    pub fn f32(&self, name: &str) -> Result<&[f32]> {
        self.get(name)?.buf.as_f32()
    }

    pub fn f32_mut(&mut self, name: &str) -> Result<&mut [f32]> {
        self.get_mut(name)?.buf.as_f32_mut()
    }

    pub fn scalar_i64(&self, name: &str) -> Result<i64> {
        Ok(self.get(name)?.buf.as_i64()?[0])
    }

    pub fn set_scalar_i64(&mut self, name: &str, v: i64) -> Result<()> {
        self.get_mut(name)?.buf.as_i64_mut()?[0] = v;
        Ok(())
    }

    /// Serialization ----------------------------------------------------

    /// Serialize the whole store (or, with `filter`, a subset of variables —
    /// the user-level checkpoint path) to a self-describing byte string.
    pub fn serialize_filtered(&self, filter: Option<&[&str]>) -> Vec<u8> {
        let selected: Vec<(&String, &Var)> = match filter {
            None => self.vars.iter().collect(),
            Some(names) => {
                // Keep deterministic (sorted) order regardless of filter order.
                self.vars
                    .iter()
                    .filter(|(k, _)| names.contains(&k.as_str()))
                    .collect()
            }
        };
        let mut out = Vec::with_capacity(64 + self.byte_len());
        out.extend_from_slice(b"SDRV");
        out.extend_from_slice(&1u32.to_le_bytes()); // version
        out.extend_from_slice(&(selected.len() as u32).to_le_bytes());
        for (name, var) in selected {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            out.extend_from_slice(nb);
            out.push(var.buf.dtype().tag());
            out.extend_from_slice(&(var.shape.len() as u32).to_le_bytes());
            for d in &var.shape {
                out.extend_from_slice(&(*d as u64).to_le_bytes());
            }
            let bytes = var.buf.bytes();
            out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            out.extend_from_slice(bytes);
        }
        out
    }

    pub fn serialize(&self) -> Vec<u8> {
        self.serialize_filtered(None)
    }

    pub fn deserialize(data: &[u8]) -> Result<VarStore> {
        let mut c = Cursor { data, pos: 0 };
        let magic = c.take(4)?;
        if magic != b"SDRV" {
            return Err(SedarError::Checkpoint("bad VarStore magic".into()));
        }
        let version = c.u32()?;
        if version != 1 {
            return Err(SedarError::Checkpoint(format!(
                "unsupported VarStore version {version}"
            )));
        }
        let count = c.u32()? as usize;
        let mut store = VarStore::new();
        for _ in 0..count {
            let name_len = c.u32()? as usize;
            let name = String::from_utf8(c.take(name_len)?.to_vec())
                .map_err(|e| SedarError::Checkpoint(format!("bad var name: {e}")))?;
            let dtype = DType::from_tag(c.u8()?)?;
            let ndim = c.u32()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u64()? as usize);
            }
            let byte_len = c.u64()? as usize;
            let raw = c.take(byte_len)?;
            let buf = Buf::from_bytes(dtype, raw)?;
            store.insert(&name, Var { shape, buf });
        }
        Ok(store)
    }
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            return Err(SedarError::Checkpoint("truncated VarStore".into()));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_store() -> VarStore {
        let mut s = VarStore::new();
        s.insert("A", Var::f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        s.insert("count", Var::i64_scalar(42));
        s.insert(
            "raw",
            Var {
                shape: vec![4],
                buf: Buf::u8(&[9, 8, 7, 6]),
            },
        );
        s
    }

    #[test]
    fn roundtrip_serialize() {
        let s = sample_store();
        let bytes = s.serialize();
        let s2 = VarStore::deserialize(&bytes).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn filtered_serialize_keeps_subset() {
        let s = sample_store();
        let bytes = s.serialize_filtered(Some(&["A"]));
        let s2 = VarStore::deserialize(&bytes).unwrap();
        assert_eq!(s2.len(), 1);
        assert_eq!(s2.f32("A").unwrap(), s.f32("A").unwrap());
    }

    #[test]
    fn byte_view_matches_values() {
        let v = Buf::f32(&[1.0f32]);
        assert_eq!(v.bytes(), 1.0f32.to_le_bytes());
    }

    #[test]
    fn bit_flip_via_bytes_mut_changes_value() {
        let mut b = Buf::f32(&[1.0f32, 2.0]);
        crate::util::flip_bit(b.bytes_mut(), 7, 7); // sign bit of second elt
        assert_eq!(b.as_f32().unwrap()[1], -2.0);
        assert_eq!(b.as_f32().unwrap()[0], 1.0);
    }

    #[test]
    fn clone_is_zero_copy_until_written() {
        let a = Var::f32(&[3], vec![1.0, 2.0, 3.0]);
        let b = a.clone();
        assert!(b.buf.shares_allocation(&a.buf), "clone must share the allocation");
        // Copy-on-write: mutating the clone detaches it, the original is
        // untouched (replica isolation through shared payloads).
        let mut c = a.clone();
        c.buf.as_f32_mut().unwrap()[0] = -1.0;
        assert!(!c.buf.shares_allocation(&a.buf));
        assert_eq!(a.buf.as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(c.buf.as_f32().unwrap(), &[-1.0, 2.0, 3.0]);
    }

    #[test]
    fn buf_views_are_typed_zero_copy_windows() {
        let v = Var::f32(&[2, 4], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let row = v.buf.view(4, 4).unwrap();
        assert!(row.shares_allocation(&v.buf), "a view must not copy");
        assert_eq!(row.as_f32().unwrap(), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(row.len(), 4);
        // Odd element offsets stay aligned for the element type.
        assert_eq!(v.buf.view(1, 2).unwrap().as_f32().unwrap(), &[1.0, 2.0]);
        // Copy-on-write: mutating the view never reaches the parent.
        let mut row = row;
        row.as_f32_mut().unwrap()[0] = 99.0;
        assert!(!row.shares_allocation(&v.buf));
        assert_eq!(v.buf.as_f32().unwrap()[4], 4.0);
        // Bounds are element-granular and checked.
        assert!(v.buf.view(6, 4).is_err());
        assert!(v.buf.view(usize::MAX, 2).is_err());
    }

    #[test]
    fn typed_views_cover_all_dtypes() {
        assert_eq!(Buf::f64(&[1.5, -2.5]).as_f64().unwrap(), &[1.5, -2.5]);
        assert_eq!(Buf::i64(&[7, -9]).as_i64().unwrap(), &[7, -9]);
        assert_eq!(Buf::u8(&[1, 2, 3]).as_u8().unwrap(), &[1, 2, 3]);
        // Wrong-dtype access is an error, not a cast.
        assert!(Buf::u8(&[1, 2, 3, 4]).as_f32().is_err());
        assert!(Buf::f32(&[1.0]).as_i64().is_err());
    }

    #[test]
    fn from_bytes_validates_and_aligns() {
        let b = Buf::from_bytes(DType::F32, &1.25f32.to_le_bytes()).unwrap();
        assert_eq!(b.as_f32().unwrap(), &[1.25]);
        assert_eq!(b.as_f32().unwrap().as_ptr() as usize % 4, 0);
        assert!(Buf::from_bytes(DType::F32, &[0u8; 6]).is_err());
        assert!(Buf::from_bytes(DType::I64, &[0u8; 12]).is_err());
    }

    #[test]
    fn deserialize_rejects_garbage() {
        assert!(VarStore::deserialize(b"nope").is_err());
        let s = sample_store();
        let mut bytes = s.serialize();
        bytes.truncate(bytes.len() - 3);
        assert!(VarStore::deserialize(&bytes).is_err());
    }

    #[test]
    fn scalar_helpers() {
        let mut s = sample_store();
        assert_eq!(s.scalar_i64("count").unwrap(), 42);
        s.set_scalar_i64("count", 7).unwrap();
        assert_eq!(s.scalar_i64("count").unwrap(), 7);
    }

    #[test]
    fn store_byte_len_sums() {
        let s = sample_store();
        assert_eq!(s.byte_len(), 6 * 4 + 8 + 4);
    }
}
