//! Controlled fault injection (§4.2 of the paper).
//!
//! The paper emulates a transient bit-flip in a processor register by
//! changing the value of a variable **in only one of the replicated
//! threads**, in a single place of the execution, *from inside the code of
//! the application*. An external file (`injected.txt`) latches the
//! injection so that re-executions after rollback do not re-inject — the
//! latch must live outside the checkpointed state.
//!
//! We reproduce the method exactly: an [`InjectionSpec`] names the execution
//! point, the target rank/replica, the variable, element and bit; the
//! [`Injector`] applies it at most once per experiment, guarded by a
//! file-backed [`Latch`].
//!
//! Two injection kinds exist:
//!
//! * [`InjectKind::BitFlip`] — corrupt one bit of one element (SDC-type
//!   faults: TDC / FSC / LE depending on the data's future use);
//! * [`InjectKind::IndexRollback`] — corrupt a loop index during the compute
//!   phase so one replica redoes part of its work and arrives late at the
//!   next synchronization (the paper's TOE scenarios, e.g. Scenario 59).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::error::Result;
use crate::state::VarStore;

/// Where in the execution the injection fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectPoint {
    /// Immediately before the phase with this cursor starts (the paper's
    /// "between X and Y" windows: the injection point between SCATTER and
    /// CK1 is `BeforePhase(cursor_of(CK1))`).
    BeforePhase(u64),
    /// During the compute phase, after sub-block `after_subblock` completes
    /// (index-variable corruption, TOE scenarios).
    DuringPhase { phase: u64, after_subblock: u64 },
}

/// What the injection does.
#[derive(Debug, Clone)]
pub enum InjectKind {
    /// Flip `bit` of element `elem` of variable `var`.
    BitFlip { var: String, elem: usize, bit: u8 },
    /// Reset the compute sub-block loop index so the replica redoes
    /// `redo_blocks` sub-blocks and additionally sleeps `extra_delay`
    /// (guaranteeing the sibling's rendezvous lapse expires → TOE).
    IndexRollback {
        redo_blocks: u64,
        extra_delay: Duration,
    },
}

/// A single controlled fault.
#[derive(Debug, Clone)]
pub struct InjectionSpec {
    /// Human-readable name, e.g. `"scenario-50"`.
    pub name: String,
    pub point: InjectPoint,
    /// Target rank.
    pub rank: usize,
    /// Target replica (the paper always injects into one replica; we default
    /// to replica 1 so replica 0 — the one that talks to the network — holds
    /// the correct data, but either works).
    pub replica: usize,
    pub kind: InjectKind,
}

/// File-backed one-shot latch — the paper's `injected.txt`. The file content
/// is `0` before injection and `1` after; it is intentionally **external**
/// to the application state so checkpoints/rollbacks do not reset it.
pub struct Latch {
    path: Option<PathBuf>,
    fired: AtomicBool,
}

impl Latch {
    /// A latch persisted at `path` (created holding `0` if absent).
    pub fn file_backed(path: &Path) -> Result<Latch> {
        let fired = if path.exists() {
            std::fs::read_to_string(path)?.trim() == "1"
        } else {
            std::fs::write(path, "0")?;
            false
        };
        Ok(Latch {
            path: Some(path.to_path_buf()),
            fired: AtomicBool::new(fired),
        })
    }

    /// An in-memory latch (unit tests).
    pub fn in_memory() -> Latch {
        Latch {
            path: None,
            fired: AtomicBool::new(false),
        }
    }

    /// Attempt to fire. Returns `true` exactly once.
    pub fn fire(&self) -> bool {
        if self.fired.swap(true, Ordering::SeqCst) {
            return false;
        }
        if let Some(p) = &self.path {
            // Best-effort persistence; the in-memory flag is authoritative
            // within the process (matches the paper's single-experiment use).
            let _ = std::fs::write(p, "1");
        }
        true
    }

    pub fn fired(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }
}

/// A record of an injection that actually happened (for traces/reports).
#[derive(Debug, Clone)]
pub struct InjectionRecord {
    pub name: String,
    pub rank: usize,
    pub replica: usize,
    pub description: String,
}

/// One armed fault: a spec plus its once-only latch.
struct Slot {
    spec: InjectionSpec,
    latch: Latch,
}

/// The injector a run carries. Usually holds one fault (the paper's single-
/// fault experiments); multiple slots model the §3.2/§4.2 multi-fault
/// discussion (independent faults, each with its own external latch).
pub struct Injector {
    slots: Vec<Slot>,
    records: Mutex<Vec<InjectionRecord>>,
}

impl Injector {
    /// A fault-free run.
    pub fn none() -> Injector {
        Injector {
            slots: Vec::new(),
            records: Mutex::new(Vec::new()),
        }
    }

    pub fn new(spec: InjectionSpec, latch: Latch) -> Injector {
        Injector {
            slots: vec![Slot { spec, latch }],
            records: Mutex::new(Vec::new()),
        }
    }

    /// Multiple independent faults, each with its own latch.
    pub fn multi(specs: Vec<(InjectionSpec, Latch)>) -> Injector {
        Injector {
            slots: specs
                .into_iter()
                .map(|(spec, latch)| Slot { spec, latch })
                .collect(),
            records: Mutex::new(Vec::new()),
        }
    }

    pub fn specs(&self) -> Vec<&InjectionSpec> {
        self.slots.iter().map(|s| &s.spec).collect()
    }

    /// Did every armed injection happen (in this or a previous execution)?
    pub fn injected(&self) -> bool {
        !self.slots.is_empty() && self.slots.iter().all(|s| s.latch.fired())
    }

    /// The records of injections performed *in this process*.
    pub fn records(&self) -> Vec<InjectionRecord> {
        self.records.lock().unwrap().clone()
    }

    /// Called by the replica driver at `BeforePhase(cursor)` points.
    /// Applies every matching un-fired bit-flip to `store`; returns the
    /// records of injections performed now.
    pub fn maybe_inject_at_phase(
        &self,
        cursor: u64,
        rank: usize,
        replica: usize,
        store: &mut VarStore,
    ) -> Vec<InjectionRecord> {
        let mut fired = Vec::new();
        for slot in &self.slots {
            let spec = &slot.spec;
            if spec.rank != rank || spec.replica != replica {
                continue;
            }
            let InjectPoint::BeforePhase(p) = spec.point else {
                continue;
            };
            if p != cursor {
                continue;
            }
            let InjectKind::BitFlip { var, elem, bit } = &spec.kind else {
                continue;
            };
            // Match found — fire the latch (once, across re-executions).
            if !slot.latch.fire() {
                continue;
            }
            let v = store
                .get_mut(var)
                .unwrap_or_else(|_| panic!("injection target var '{var}' missing"));
            let esz = v.buf.dtype().size_of();
            let byte_idx = *elem * esz; // flip within the element's first byte + bit
            crate::util::flip_bit(
                v.buf.bytes_mut(),
                byte_idx + (*bit as usize / 8),
                bit % 8,
            );
            let rec = InjectionRecord {
                name: spec.name.clone(),
                rank,
                replica,
                description: format!(
                    "bit-flip: var={var} elem={elem} bit={bit} at cursor {cursor}"
                ),
            };
            self.records.lock().unwrap().push(rec.clone());
            fired.push(rec);
        }
        fired
    }

    /// Called by compute loops after each sub-block. Returns
    /// `Some((redo_blocks, extra_delay))` at most once per slot if this is
    /// the index-corruption point for (rank, replica).
    pub fn maybe_index_rollback(
        &self,
        phase: u64,
        subblock: u64,
        rank: usize,
        replica: usize,
    ) -> Option<(u64, Duration)> {
        for slot in &self.slots {
            let spec = &slot.spec;
            if spec.rank != rank || spec.replica != replica {
                continue;
            }
            let InjectPoint::DuringPhase {
                phase: p,
                after_subblock,
            } = spec.point
            else {
                continue;
            };
            if p != phase || after_subblock != subblock {
                continue;
            }
            let InjectKind::IndexRollback {
                redo_blocks,
                extra_delay,
            } = &spec.kind
            else {
                continue;
            };
            if !slot.latch.fire() {
                continue;
            }
            let rec = InjectionRecord {
                name: spec.name.clone(),
                rank,
                replica,
                description: format!(
                    "index-rollback: phase={phase} subblock={subblock} redo={redo_blocks}"
                ),
            };
            self.records.lock().unwrap().push(rec.clone());
            return Some((*redo_blocks, *extra_delay));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::Var;

    fn store_with_a() -> VarStore {
        let mut s = VarStore::new();
        s.insert("A", Var::f32(&[4], vec![1.0, 2.0, 3.0, 4.0]));
        s
    }

    fn flip_spec(point: InjectPoint) -> InjectionSpec {
        InjectionSpec {
            name: "t".into(),
            point,
            rank: 1,
            replica: 1,
            kind: InjectKind::BitFlip {
                var: "A".into(),
                elem: 2,
                bit: 31, // sign bit of the f32
            },
        }
    }

    #[test]
    fn injects_once_at_matching_point() {
        let inj = Injector::new(flip_spec(InjectPoint::BeforePhase(3)), Latch::in_memory());
        let mut s = store_with_a();
        // Wrong cursor / rank / replica: no-ops.
        assert!(inj.maybe_inject_at_phase(2, 1, 1, &mut s).is_empty());
        assert!(inj.maybe_inject_at_phase(3, 0, 1, &mut s).is_empty());
        assert!(inj.maybe_inject_at_phase(3, 1, 0, &mut s).is_empty());
        assert_eq!(s.f32("A").unwrap()[2], 3.0);
        // Match: flips the sign bit of A[2].
        assert!(!inj.maybe_inject_at_phase(3, 1, 1, &mut s).is_empty());
        assert_eq!(s.f32("A").unwrap()[2], -3.0);
        // Latched: second pass does nothing (the re-execution case).
        assert!(inj.maybe_inject_at_phase(3, 1, 1, &mut s).is_empty());
        assert_eq!(s.f32("A").unwrap()[2], -3.0);
        assert!(inj.injected());
    }

    #[test]
    fn multi_injector_fires_each_slot_once() {
        let mut spec2 = flip_spec(InjectPoint::BeforePhase(3));
        spec2.kind = InjectKind::BitFlip {
            var: "A".into(),
            elem: 0,
            bit: 31,
        };
        let inj = Injector::multi(vec![
            (flip_spec(InjectPoint::BeforePhase(3)), Latch::in_memory()),
            (spec2, Latch::in_memory()),
        ]);
        let mut s = store_with_a();
        let fired = inj.maybe_inject_at_phase(3, 1, 1, &mut s);
        assert_eq!(fired.len(), 2);
        assert_eq!(s.f32("A").unwrap()[0], -1.0);
        assert_eq!(s.f32("A").unwrap()[2], -3.0);
        assert!(inj.injected());
        assert_eq!(inj.records().len(), 2);
    }

    #[test]
    fn file_latch_survives_reload() {
        let dir = std::env::temp_dir().join(format!("sedar-latch-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("injected.txt");
        let _ = std::fs::remove_file(&path);
        {
            let l = Latch::file_backed(&path).unwrap();
            assert!(!l.fired());
            assert!(l.fire());
            assert!(!l.fire());
        }
        // "Restart": a new latch over the same file sees the fired state.
        let l2 = Latch::file_backed(&path).unwrap();
        assert!(l2.fired());
        assert!(!l2.fire());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn index_rollback_matches_subblock() {
        let spec = InjectionSpec {
            name: "toe".into(),
            point: InjectPoint::DuringPhase {
                phase: 6,
                after_subblock: 2,
            },
            rank: 2,
            replica: 1,
            kind: InjectKind::IndexRollback {
                redo_blocks: 2,
                extra_delay: Duration::from_millis(50),
            },
        };
        let inj = Injector::new(spec, Latch::in_memory());
        assert!(inj.maybe_index_rollback(6, 1, 2, 1).is_none());
        assert!(inj.maybe_index_rollback(6, 2, 0, 1).is_none());
        let (redo, delay) = inj.maybe_index_rollback(6, 2, 2, 1).unwrap();
        assert_eq!(redo, 2);
        assert_eq!(delay, Duration::from_millis(50));
        // once only
        assert!(inj.maybe_index_rollback(6, 2, 2, 1).is_none());
    }

    #[test]
    fn none_injector_is_inert() {
        let inj = Injector::none();
        let mut s = store_with_a();
        assert!(inj.maybe_inject_at_phase(0, 0, 0, &mut s).is_empty());
        assert!(!inj.injected());
    }
}
