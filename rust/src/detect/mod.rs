//! The detection engine (§3.1 of the paper).
//!
//! Detection rests on three comparison surfaces between the two replicas of
//! each rank:
//!
//! 1. **pre-send message contents** — catches TDC before it propagates;
//! 2. **final results** — catches FSC that propagated only locally;
//! 3. **synchronization timeouts** — catches TOE (a replica that never
//!    reaches the rendezvous within the configured lapse).
//!
//! The [`Detector`] is the run-global sink for detection events: the first
//! report wins, the network(s) are aborted so every rank safe-stops, and the
//! coordinator reads the event after joining the rank threads.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{FaultClass, SedarError};
use crate::vmpi::Network;

/// How replica buffers are validated against each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValidationMode {
    /// Byte-exact comparison of the full contents (the paper's message
    /// validation: "compares the entire contents of the messages").
    Full,
    /// SHA-256 digest comparison (the paper's hash-based validation used for
    /// application-level checkpoints, and RedMPI-style message hashing).
    Sha256,
}

impl ValidationMode {
    /// The single parser behind the config key and the campaign filter —
    /// one set of accepted spellings.
    pub fn parse(s: &str) -> crate::error::Result<ValidationMode> {
        Ok(match s {
            "full" => ValidationMode::Full,
            "sha256" | "hash" => ValidationMode::Sha256,
            other => {
                return Err(SedarError::Config(format!(
                    "unknown validation '{other}' (full|sha256)"
                )))
            }
        })
    }

    /// Short label for report rows and filters.
    pub fn label(self) -> &'static str {
        match self {
            ValidationMode::Full => "full",
            ValidationMode::Sha256 => "sha256",
        }
    }
}

/// Fast byte-equality: compares 8 bytes at a time, then the tail.
/// This is the detection hot path — see `benches/micro_hotpath.rs`.
pub fn buffers_equal(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let n = a.len();
    let words = n / 8;
    // Unaligned 8-byte loads are fine on x86-64/aarch64.
    unsafe {
        let pa = a.as_ptr() as *const u64;
        let pb = b.as_ptr() as *const u64;
        for i in 0..words {
            if pa.add(i).read_unaligned() != pb.add(i).read_unaligned() {
                return false;
            }
        }
    }
    a[words * 8..] == b[words * 8..]
}

/// SHA-256 digest of a buffer (user-level checkpoint validation). The
/// implementation is the crate's own ([`crate::util::sha256`]) — the
/// offline dependency set has no hashing crate.
pub fn sha256(bytes: &[u8]) -> [u8; 32] {
    crate::util::sha256::sha256(bytes)
}

/// The comparison token two replicas exchange: either the full buffer or its
/// digest, per [`ValidationMode`].
pub fn comparison_token(mode: ValidationMode, bytes: &[u8]) -> Vec<u8> {
    match mode {
        ValidationMode::Full => bytes.to_vec(),
        ValidationMode::Sha256 => sha256(bytes).to_vec(),
    }
}

/// A recorded detection.
#[derive(Debug, Clone)]
pub struct DetectionEvent {
    pub class: FaultClass,
    pub rank: usize,
    /// Where it was detected, e.g. `"SCATTER"`, `"VALIDATE"`, `"CK2"`.
    pub site: String,
    /// Phase cursor of the detecting rank at detection time.
    pub cursor: u64,
}

/// Comparison-volume counters (feed the overhead analysis of Table 3).
#[derive(Debug, Default)]
pub struct DetectStats {
    pub comparisons: AtomicU64,
    pub bytes_compared: AtomicU64,
    pub sync_events: AtomicU64,
}

/// Run-global detection sink. First event wins; reporting aborts the
/// attached network(s) so every rank unwinds with [`SedarError::Aborted`].
pub struct Detector {
    event: Mutex<Option<DetectionEvent>>,
    networks: Mutex<Vec<Arc<Network>>>,
    abort: Arc<AtomicBool>,
    pub stats: DetectStats,
}

impl Default for Detector {
    fn default() -> Self {
        Self::new()
    }
}

impl Detector {
    pub fn new() -> Self {
        Detector {
            event: Mutex::new(None),
            networks: Mutex::new(Vec::new()),
            abort: Arc::new(AtomicBool::new(false)),
            stats: DetectStats::default(),
        }
    }

    /// Networks to tear down on detection.
    pub fn attach_network(&self, net: Arc<Network>) {
        self.networks.lock().unwrap().push(net);
    }

    /// The shared abort flag replica rendezvous loops poll while waiting.
    pub fn abort_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.abort)
    }

    pub fn is_aborted(&self) -> bool {
        self.abort.load(Ordering::SeqCst)
    }

    /// Record a detection (first wins), trigger the safe-stop, and return
    /// the error the detecting replica should unwind with.
    pub fn report(&self, class: FaultClass, rank: usize, site: &str, cursor: u64) -> SedarError {
        {
            let mut ev = self.event.lock().unwrap();
            if ev.is_none() {
                *ev = Some(DetectionEvent {
                    class,
                    rank,
                    site: site.to_string(),
                    cursor,
                });
            }
        }
        self.abort.store(true, Ordering::SeqCst);
        for net in self.networks.lock().unwrap().iter() {
            net.abort();
        }
        SedarError::FaultDetected {
            class,
            rank,
            site: site.to_string(),
        }
    }

    /// Tear the run down *without* recording a detection event — used when a
    /// replica hits an infrastructure error (I/O, runtime) and the other
    /// ranks must be unblocked so the error can propagate out of the join.
    pub fn hard_abort(&self) {
        self.abort.store(true, Ordering::SeqCst);
        for net in self.networks.lock().unwrap().iter() {
            net.abort();
        }
    }

    /// The recorded event, if any.
    pub fn event(&self) -> Option<DetectionEvent> {
        self.event.lock().unwrap().clone()
    }

    pub fn detected(&self) -> bool {
        self.event.lock().unwrap().is_some()
    }

    /// Account one comparison of `bytes` bytes.
    pub fn note_comparison(&self, bytes: usize) {
        self.stats.comparisons.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_compared
            .fetch_add(bytes as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_buffers_compare_equal() {
        let a = vec![7u8; 1025];
        let b = a.clone();
        assert!(buffers_equal(&a, &b));
    }

    #[test]
    fn detects_single_bit_difference_everywhere() {
        let a = vec![0u8; 131];
        for i in 0..a.len() {
            for bit in [0u8, 3, 7] {
                let mut b = a.clone();
                b[i] ^= 1 << bit;
                assert!(!buffers_equal(&a, &b), "missed flip at byte {i} bit {bit}");
            }
        }
    }

    #[test]
    fn length_mismatch_is_unequal() {
        assert!(!buffers_equal(&[1, 2, 3], &[1, 2]));
    }

    #[test]
    fn sha256_known_vector() {
        // SHA-256 of the empty string.
        assert_eq!(
            crate::util::hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn token_modes() {
        let data = vec![1u8, 2, 3];
        assert_eq!(comparison_token(ValidationMode::Full, &data), data);
        assert_eq!(comparison_token(ValidationMode::Sha256, &data).len(), 32);
    }

    #[test]
    fn first_report_wins() {
        let d = Detector::new();
        let e1 = d.report(FaultClass::Tdc, 1, "SCATTER", 2);
        assert!(matches!(e1, SedarError::FaultDetected { .. }));
        let _e2 = d.report(FaultClass::Fsc, 0, "VALIDATE", 9);
        let ev = d.event().unwrap();
        assert_eq!(ev.class, FaultClass::Tdc);
        assert_eq!(ev.site, "SCATTER");
        assert!(d.is_aborted());
    }

    #[test]
    fn report_aborts_attached_network() {
        let d = Detector::new();
        let net = Network::new(2);
        d.attach_network(Arc::clone(&net));
        let _ = d.report(FaultClass::Toe, 0, "GATHER", 5);
        assert!(net.is_aborted());
    }
}
