//! The detection engine (§3.1 of the paper).
//!
//! Detection rests on three comparison surfaces between the two replicas of
//! each rank:
//!
//! 1. **pre-send message contents** — catches TDC before it propagates;
//! 2. **final results** — catches FSC that propagated only locally;
//! 3. **synchronization timeouts** — catches TOE (a replica that never
//!    reaches the rendezvous within the configured lapse).
//!
//! The [`Detector`] is the run-global sink for detection events: the first
//! report wins, the network(s) are aborted so every rank safe-stops, and the
//! coordinator reads the event after joining the rank threads.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{FaultClass, SedarError};
use crate::vmpi::Network;

/// How replica buffers are validated against each other.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValidationMode {
    /// Byte-exact comparison of the full contents (the paper's message
    /// validation: "compares the entire contents of the messages").
    Full,
    /// SHA-256 digest comparison (the paper's hash-based validation used for
    /// application-level checkpoints, and RedMPI-style message hashing).
    Sha256,
}

impl ValidationMode {
    /// The single parser behind the config key and the campaign filter —
    /// one set of accepted spellings.
    pub fn parse(s: &str) -> crate::error::Result<ValidationMode> {
        Ok(match s {
            "full" => ValidationMode::Full,
            "sha256" | "hash" => ValidationMode::Sha256,
            other => {
                return Err(SedarError::Config(format!(
                    "unknown validation '{other}' (full|sha256)"
                )))
            }
        })
    }

    /// Short label for report rows and filters.
    pub fn label(self) -> &'static str {
        match self {
            ValidationMode::Full => "full",
            ValidationMode::Sha256 => "sha256",
        }
    }
}

/// Fast byte-equality: compares 8 bytes at a time, then the tail.
/// This is the detection hot path — see `benches/micro_hotpath.rs`.
pub fn buffers_equal(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let n = a.len();
    let words = n / 8;
    // Unaligned 8-byte loads are fine on x86-64/aarch64.
    unsafe {
        let pa = a.as_ptr() as *const u64;
        let pb = b.as_ptr() as *const u64;
        for i in 0..words {
            if pa.add(i).read_unaligned() != pb.add(i).read_unaligned() {
                return false;
            }
        }
    }
    a[words * 8..] == b[words * 8..]
}

/// SHA-256 digest of a buffer (user-level checkpoint validation). The
/// implementation is the crate's own ([`crate::util::sha256`]) — the
/// offline dependency set has no hashing crate.
pub fn sha256(bytes: &[u8]) -> [u8; 32] {
    crate::util::sha256::sha256(bytes)
}

/// The comparison token a replica contributes at a validation rendezvous —
/// **borrowing**: `Full` is a zero-copy view of the outgoing buffer (the
/// paper's full-contents message validation allocates nothing on the send
/// path), `Digest` is 32 stack bytes computed from it. Bytes are only
/// materialized when a token must actually cross a channel
/// ([`Token::to_wire`]) — and for `Digest` that is 32 bytes regardless of
/// payload size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Token<'a> {
    Full(&'a [u8]),
    Digest([u8; 32]),
}

impl<'a> Token<'a> {
    pub fn new(mode: ValidationMode, bytes: &'a [u8]) -> Token<'a> {
        match mode {
            ValidationMode::Full => Token::Full(bytes),
            ValidationMode::Sha256 => Token::Digest(sha256(bytes)),
        }
    }

    /// The bytes a peer compares against.
    pub fn as_bytes(&self) -> &[u8] {
        match *self {
            Token::Full(b) => b,
            Token::Digest(ref d) => &d[..],
        }
    }

    pub fn len(&self) -> usize {
        self.as_bytes().len()
    }

    pub fn is_empty(&self) -> bool {
        self.as_bytes().is_empty()
    }

    /// Owned wire form for crossing a channel — the only place this type
    /// copies anything.
    pub fn to_wire(&self) -> Vec<u8> {
        self.as_bytes().to_vec()
    }

    /// Compare against a peer token's wire form.
    pub fn matches(&self, peer: &[u8]) -> bool {
        buffers_equal(self.as_bytes(), peer)
    }
}

/// A recorded detection.
#[derive(Debug, Clone)]
pub struct DetectionEvent {
    pub class: FaultClass,
    pub rank: usize,
    /// Where it was detected, e.g. `"SCATTER"`, `"VALIDATE"`, `"CK2"`.
    pub site: String,
    /// Phase cursor of the detecting rank at detection time.
    pub cursor: u64,
}

/// Comparison-volume counters (feed the overhead analysis of Table 3).
#[derive(Debug, Default)]
pub struct DetectStats {
    pub comparisons: AtomicU64,
    pub bytes_compared: AtomicU64,
    pub sync_events: AtomicU64,
}

/// Run-global detection sink. First event wins; reporting aborts the
/// attached network(s) so every rank unwinds with [`SedarError::Aborted`].
pub struct Detector {
    event: Mutex<Option<DetectionEvent>>,
    networks: Mutex<Vec<Arc<Network>>>,
    abort: Arc<AtomicBool>,
    pub stats: DetectStats,
}

impl Default for Detector {
    fn default() -> Self {
        Self::new()
    }
}

impl Detector {
    pub fn new() -> Self {
        Detector {
            event: Mutex::new(None),
            networks: Mutex::new(Vec::new()),
            abort: Arc::new(AtomicBool::new(false)),
            stats: DetectStats::default(),
        }
    }

    /// Networks to tear down on detection.
    pub fn attach_network(&self, net: Arc<Network>) {
        self.networks.lock().unwrap().push(net);
    }

    /// The shared abort flag replica rendezvous loops poll while waiting.
    pub fn abort_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.abort)
    }

    pub fn is_aborted(&self) -> bool {
        self.abort.load(Ordering::SeqCst)
    }

    /// Record a detection (first wins), trigger the safe-stop, and return
    /// the error the detecting replica should unwind with.
    pub fn report(&self, class: FaultClass, rank: usize, site: &str, cursor: u64) -> SedarError {
        {
            let mut ev = self.event.lock().unwrap();
            if ev.is_none() {
                *ev = Some(DetectionEvent {
                    class,
                    rank,
                    site: site.to_string(),
                    cursor,
                });
            }
        }
        self.abort.store(true, Ordering::SeqCst);
        for net in self.networks.lock().unwrap().iter() {
            net.abort();
        }
        SedarError::FaultDetected {
            class,
            rank,
            site: site.to_string(),
        }
    }

    /// Tear the run down *without* recording a detection event — used when a
    /// replica hits an infrastructure error (I/O, runtime) and the other
    /// ranks must be unblocked so the error can propagate out of the join.
    pub fn hard_abort(&self) {
        self.abort.store(true, Ordering::SeqCst);
        for net in self.networks.lock().unwrap().iter() {
            net.abort();
        }
    }

    /// The recorded event, if any.
    pub fn event(&self) -> Option<DetectionEvent> {
        self.event.lock().unwrap().clone()
    }

    pub fn detected(&self) -> bool {
        self.event.lock().unwrap().is_some()
    }

    /// Account one comparison of `bytes` bytes.
    pub fn note_comparison(&self, bytes: usize) {
        self.stats.comparisons.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_compared
            .fetch_add(bytes as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_buffers_compare_equal() {
        let a = vec![7u8; 1025];
        let b = a.clone();
        assert!(buffers_equal(&a, &b));
    }

    #[test]
    fn detects_single_bit_difference_everywhere() {
        let a = vec![0u8; 131];
        for i in 0..a.len() {
            for bit in [0u8, 3, 7] {
                let mut b = a.clone();
                b[i] ^= 1 << bit;
                assert!(!buffers_equal(&a, &b), "missed flip at byte {i} bit {bit}");
            }
        }
    }

    #[test]
    fn length_mismatch_is_unequal() {
        assert!(!buffers_equal(&[1, 2, 3], &[1, 2]));
    }

    #[test]
    fn sha256_known_vector() {
        // SHA-256 of the empty string.
        assert_eq!(
            crate::util::hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn token_modes() {
        let data = vec![1u8, 2, 3];
        let full = Token::new(ValidationMode::Full, &data);
        assert!(matches!(full, Token::Full(_)), "full token must borrow");
        assert_eq!(full.as_bytes(), &data[..]);
        assert_eq!(full.as_bytes().as_ptr(), data.as_ptr(), "no copy");
        let dig = Token::new(ValidationMode::Sha256, &data);
        assert_eq!(dig.len(), 32);
        assert!(dig.matches(&Token::new(ValidationMode::Sha256, &data).to_wire()));
        assert!(!dig.matches(&Token::new(ValidationMode::Sha256, b"other").to_wire()));
        assert!(full.matches(&data));
        assert!(!full.matches(&[1, 2]));
    }

    // ---- buffers_equal boundary coverage: the function reads 8-byte words
    // with `read_unaligned`, so lengths straddling the word boundary and
    // misaligned slice starts are exactly where a bug would hide.

    #[test]
    fn boundary_lengths_across_the_word_edge() {
        for n in 0..=16usize {
            let a: Vec<u8> = (0..n as u8).collect();
            assert!(buffers_equal(&a, &a.clone()), "equal len {n}");
            for i in 0..n {
                for bit in 0..8u8 {
                    let mut b = a.clone();
                    b[i] ^= 1 << bit;
                    assert!(
                        !buffers_equal(&a, &b),
                        "missed flip at len {n} byte {i} bit {bit}"
                    );
                }
            }
        }
    }

    #[test]
    fn equal_prefix_differing_tail() {
        // Whole words equal; the difference lives only in the sub-word tail.
        for n in [9usize, 15, 17, 31, 63, 65, 127] {
            let a = vec![0xA5u8; n];
            let tail_start = n - (n % 8).max(1);
            for i in [tail_start, n - 1] {
                let mut b = a.clone();
                b[i] ^= 0x01;
                assert!(!buffers_equal(&a, &b), "missed tail flip at len {n} byte {i}");
            }
        }
    }

    #[test]
    fn misaligned_slices_compare_correctly() {
        // Every start-offset combination: contents of base[o..o+64] differ
        // between offsets (strictly increasing bytes), so equality must hold
        // exactly when the offsets match — whatever the alignment.
        let base: Vec<u8> = (0..200u8).collect();
        for off_a in 0..8usize {
            let a = &base[off_a..off_a + 64];
            for off_b in 0..8usize {
                let b = &base[off_b..off_b + 64];
                assert_eq!(
                    buffers_equal(a, b),
                    off_a == off_b,
                    "offsets {off_a}/{off_b}"
                );
            }
            // A misaligned view equals its aligned copy.
            let copy = a.to_vec();
            assert!(buffers_equal(a, &copy));
        }
    }

    #[test]
    fn agrees_with_slice_eq_on_random_cases() {
        use crate::util::prng::SplitMix64;
        let mut rng = SplitMix64::new(3);
        for _ in 0..500 {
            let n = (rng.next_u64() % 40) as usize;
            let a: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            let mut b = a.clone();
            if n > 0 && rng.next_u64() % 2 == 0 {
                let i = (rng.next_u64() as usize) % n;
                b[i] ^= 1 << (rng.next_u64() % 8);
            }
            assert_eq!(buffers_equal(&a, &b), a == b);
        }
    }

    #[test]
    fn first_report_wins() {
        let d = Detector::new();
        let e1 = d.report(FaultClass::Tdc, 1, "SCATTER", 2);
        assert!(matches!(e1, SedarError::FaultDetected { .. }));
        let _e2 = d.report(FaultClass::Fsc, 0, "VALIDATE", 9);
        let ev = d.event().unwrap();
        assert_eq!(ev.class, FaultClass::Tdc);
        assert_eq!(ev.site, "SCATTER");
        assert!(d.is_aborted());
    }

    #[test]
    fn report_aborts_attached_network() {
        let d = Detector::new();
        let net = Network::new(2);
        d.attach_network(Arc::clone(&net));
        let _ = d.report(FaultClass::Toe, 0, "GATHER", 5);
        assert!(net.is_aborted());
    }
}
