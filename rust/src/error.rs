//! Error taxonomy of the SEDAR runtime.
//!
//! The important distinction is between *infrastructure* errors (I/O,
//! malformed artifacts, …) and the two control-flow signals that drive
//! SEDAR's detection protocol:
//!
//! * [`SedarError::FaultDetected`] — a replica divergence (or timeout) was
//!   observed; the run must safe-stop and, depending on the strategy, a
//!   recovery is attempted.
//! * [`SedarError::Aborted`] — another rank already reported a fault and the
//!   coordinator tore the network down; blocked operations unwind with this.

/// The four transient-fault effect classes of the paper (§2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultClass {
    /// Transmitted Data Corruption: corrupt data *about to be sent* was
    /// caught by the pre-send replica comparison.
    Tdc,
    /// Final Status Corruption: corruption of non-communicated data, caught
    /// by the final-result comparison.
    Fsc,
    /// Latent Error: the corrupted data was never used again; harmless.
    Le,
    /// Time-Out Error: one replica failed to reach the synchronization point
    /// within the configured lapse.
    Toe,
    /// A corrupted *user-level checkpoint* (Algorithm 2 hash mismatch). Not a
    /// separate class in the paper's taxonomy but a distinct detection site.
    CkptCorrupt,
}

impl std::fmt::Display for FaultClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            FaultClass::Tdc => "TDC",
            FaultClass::Fsc => "FSC",
            FaultClass::Le => "LE",
            FaultClass::Toe => "TOE",
            FaultClass::CkptCorrupt => "CKPT-CORRUPT",
        };
        write!(f, "{s}")
    }
}

/// Everything that can go wrong inside a SEDAR run.
///
/// Display / Error / From are hand-implemented: the crate builds with zero
/// external dependencies so the offline toolchain needs no registry.
#[derive(Debug)]
pub enum SedarError {
    /// A replica divergence / timeout was detected at `site` by `rank`.
    FaultDetected {
        class: FaultClass,
        rank: usize,
        site: String,
    },

    /// The run was torn down because some (other) rank detected a fault.
    Aborted,

    /// Message-passing substrate failure (mismatched shapes, bad peer, …).
    Vmpi(String),

    /// A delivered message failed its transport integrity check (payload
    /// CRC stamped at send does not match the received bytes). Typed so
    /// the replica layer can classify it as a TDC at the receiving
    /// validation point instead of a hard infrastructure error.
    NetCorrupt {
        src: usize,
        dst: usize,
        tag: u32,
        seq: u64,
    },

    /// Checkpoint storage / framing failure.
    Checkpoint(String),

    /// XLA/PJRT runtime failure.
    Runtime(String),

    /// Configuration / CLI error.
    Config(String),

    /// Filesystem / OS failure.
    Io(std::io::Error),
}

impl std::fmt::Display for SedarError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SedarError::FaultDetected { class, rank, site } => {
                write!(f, "fault detected: {class} at {site} (rank {rank})")
            }
            SedarError::Aborted => write!(f, "run aborted (fault detected elsewhere)"),
            SedarError::Vmpi(m) => write!(f, "vmpi: {m}"),
            SedarError::NetCorrupt { src, dst, tag, seq } => write!(
                f,
                "vmpi: corrupt message payload src={src} dst={dst} tag={tag} \
                 seq={seq} (transport CRC mismatch)"
            ),
            SedarError::Checkpoint(m) => write!(f, "checkpoint: {m}"),
            SedarError::Runtime(m) => write!(f, "runtime: {m}"),
            SedarError::Config(m) => write!(f, "config: {m}"),
            SedarError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for SedarError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SedarError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SedarError {
    fn from(e: std::io::Error) -> Self {
        SedarError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, SedarError>;

impl SedarError {
    /// True if this error is one of the two detection-protocol signals (as
    /// opposed to an infrastructure failure).
    pub fn is_fault_signal(&self) -> bool {
        matches!(
            self,
            SedarError::FaultDetected { .. } | SedarError::Aborted
        )
    }
}
