//! System-level checkpoint chain — the DMTCP substitute (§3.2).
//!
//! DMTCP gives SEDAR three properties, all reproduced here:
//!
//! 1. **whole-state capture**: a checkpoint of a rank contains *everything*,
//!    i.e. the full [`crate::state::VarStore`] of **both** replica threads
//!    plus the phase cursor. Crucially this is *unvalidated*: if a replica
//!    was already corrupted, the corruption is faithfully captured (a
//!    "dirty" checkpoint) and will re-manifest after restart — exactly the
//!    behavior Algorithm 1's multi-rollback exists to handle.
//! 2. **a numbered chain**: checkpoints are identified by their position in
//!    program order (`ck0, ck1, …`); none are deleted, because validity is
//!    unknowable at save time.
//! 3. **restart scripts**: [`SystemChain::read`] + the coordinator's rank
//!    relaunch reproduce `dmtcp_restart` from checkpoint *k*; re-executions
//!    overwrite later checkpoints as they pass them again (§4.2: "the
//!    wrong-restart checkpoint has to be erased and stored again in
//!    re-execution").

use std::path::{Path, PathBuf};

use crate::error::{Result, SedarError};
use crate::state::VarStore;

use super::snapshot::{read_frame, write_frame, Codec};

/// Whole-state snapshot of one rank: both replicas + the phase cursor.
#[derive(Debug, Clone, PartialEq)]
pub struct RankSnapshot {
    pub cursor: u64,
    /// `stores[r]` is replica r's full variable store.
    pub stores: [VarStore; 2],
}

impl RankSnapshot {
    pub fn serialize(&self) -> Vec<u8> {
        Self::serialize_parts(
            self.cursor,
            &self.stores[0].serialize(),
            &self.stores[1].serialize(),
        )
    }

    /// Assemble the snapshot payload from already-serialized stores —
    /// the hot checkpoint path uses this to avoid cloning both replicas'
    /// buffers just to re-serialize them (perf change P4, EXPERIMENTS.md
    /// §Perf).
    pub fn serialize_parts(cursor: u64, s0: &[u8], s1: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + s0.len() + s1.len());
        out.extend_from_slice(&cursor.to_le_bytes());
        out.extend_from_slice(&(s0.len() as u64).to_le_bytes());
        out.extend_from_slice(s0);
        out.extend_from_slice(&(s1.len() as u64).to_le_bytes());
        out.extend_from_slice(s1);
        out
    }

    pub fn deserialize(data: &[u8]) -> Result<RankSnapshot> {
        let need = |cond: bool| {
            if cond {
                Ok(())
            } else {
                Err(SedarError::Checkpoint("truncated RankSnapshot".into()))
            }
        };
        need(data.len() >= 16)?;
        let cursor = u64::from_le_bytes(data[0..8].try_into().unwrap());
        let l0 = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
        need(data.len() >= 16 + l0 + 8)?;
        let s0 = VarStore::deserialize(&data[16..16 + l0])?;
        let off = 16 + l0;
        let l1 = u64::from_le_bytes(data[off..off + 8].try_into().unwrap()) as usize;
        need(data.len() >= off + 8 + l1)?;
        let s1 = VarStore::deserialize(&data[off + 8..off + 8 + l1])?;
        Ok(RankSnapshot {
            cursor,
            stores: [s0, s1],
        })
    }

    /// Total application bytes captured (the "W"-driven `t_cs` cost driver).
    pub fn byte_len(&self) -> usize {
        self.stores[0].byte_len() + self.stores[1].byte_len()
    }
}

/// The on-disk chain of coordinated checkpoints for one run.
///
/// Layout: `dir/ck<NO>_rank<R>.bin` + `dir/chain.idx` holding the count of
/// complete checkpoints as ASCII (the `get_ckpt_count()` of Algorithm 1).
pub struct SystemChain {
    dir: PathBuf,
    nranks: usize,
    codec: Codec,
}

impl SystemChain {
    pub fn create(dir: &Path, nranks: usize, codec: Codec) -> Result<SystemChain> {
        std::fs::create_dir_all(dir)?;
        let chain = SystemChain {
            dir: dir.to_path_buf(),
            nranks,
            codec,
        };
        if !chain.idx_path().exists() {
            chain.set_count(0)?;
        }
        Ok(chain)
    }

    /// Open an existing chain (restart path).
    pub fn open(dir: &Path, nranks: usize, codec: Codec) -> Result<SystemChain> {
        if !dir.join("chain.idx").exists() {
            return Err(SedarError::Checkpoint(format!(
                "no chain at {}",
                dir.display()
            )));
        }
        Ok(SystemChain {
            dir: dir.to_path_buf(),
            nranks,
            codec,
        })
    }

    fn idx_path(&self) -> PathBuf {
        self.dir.join("chain.idx")
    }

    fn ck_path(&self, no: u64, rank: usize) -> PathBuf {
        self.dir.join(format!("ck{no}_rank{rank}.bin"))
    }

    /// `get_ckpt_count()` of Algorithm 1: number of complete checkpoints.
    pub fn count(&self) -> Result<u64> {
        let s = std::fs::read_to_string(self.idx_path())?;
        s.trim()
            .parse()
            .map_err(|e| SedarError::Checkpoint(format!("bad chain.idx: {e}")))
    }

    fn set_count(&self, n: u64) -> Result<()> {
        std::fs::write(self.idx_path(), format!("{n}\n"))?;
        Ok(())
    }

    /// Store rank `rank`'s snapshot for checkpoint `no` (overwrites a
    /// previous incarnation from a rolled-back execution).
    pub fn write(&self, no: u64, rank: usize, snap: &RankSnapshot) -> Result<()> {
        self.write_payload(no, rank, &snap.serialize())
    }

    /// Store a pre-assembled snapshot payload (see
    /// [`RankSnapshot::serialize_parts`]).
    pub fn write_payload(&self, no: u64, rank: usize, payload: &[u8]) -> Result<()> {
        write_frame(&self.ck_path(no, rank), payload, self.codec)
    }

    /// Mark checkpoint `no` complete (all ranks stored). Called once per
    /// checkpoint by the master's leading replica, after a barrier.
    pub fn commit(&self, no: u64) -> Result<()> {
        let count = self.count()?;
        if no + 1 > count {
            self.set_count(no + 1)?;
        }
        Ok(())
    }

    /// Load rank `rank`'s snapshot of checkpoint `no`.
    pub fn read(&self, no: u64, rank: usize) -> Result<RankSnapshot> {
        let payload = read_frame(&self.ck_path(no, rank))?;
        RankSnapshot::deserialize(&payload)
    }

    /// Logical truncation after a rollback to checkpoint `no`: the chain
    /// count becomes `no + 1`. Files beyond it stay on disk and are
    /// overwritten as the re-execution passes their phase points again.
    pub fn truncate(&self, keep: u64) -> Result<()> {
        let count = self.count()?;
        if keep < count {
            self.set_count(keep)?;
        }
        Ok(())
    }

    /// Total bytes currently on disk for the chain (storage-cost metric of
    /// §3.2's "amount of required storage" limitation).
    pub fn disk_bytes(&self) -> Result<u64> {
        let mut total = 0;
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            if entry
                .file_name()
                .to_string_lossy()
                .starts_with("ck")
            {
                total += entry.metadata()?.len();
            }
        }
        Ok(total)
    }

    pub fn nranks(&self) -> usize {
        self.nranks
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::{Var, VarStore};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "sedar-chain-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn snap(cursor: u64, seed: f32) -> RankSnapshot {
        let mut s0 = VarStore::new();
        s0.insert("x", Var::f32(&[3], vec![seed, seed + 1.0, seed + 2.0]));
        let mut s1 = s0.clone();
        s1.insert("extra", Var::i64_scalar(9));
        RankSnapshot {
            cursor,
            stores: [s0, s1],
        }
    }

    #[test]
    fn snapshot_roundtrip() {
        let s = snap(5, 1.0);
        let d = RankSnapshot::deserialize(&s.serialize()).unwrap();
        assert_eq!(s, d);
    }

    #[test]
    fn chain_count_and_commit() {
        let dir = tmpdir("count");
        let c = SystemChain::create(&dir, 2, Codec::Raw).unwrap();
        assert_eq!(c.count().unwrap(), 0);
        for rank in 0..2 {
            c.write(0, rank, &snap(2, rank as f32)).unwrap();
        }
        c.commit(0).unwrap();
        assert_eq!(c.count().unwrap(), 1);
        for rank in 0..2 {
            c.write(1, rank, &snap(4, rank as f32)).unwrap();
        }
        c.commit(1).unwrap();
        assert_eq!(c.count().unwrap(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn restart_reads_what_was_written() {
        let dir = tmpdir("rw");
        let c = SystemChain::create(&dir, 1, Codec::Deflate(1)).unwrap();
        let s = snap(7, 3.0);
        c.write(0, 0, &s).unwrap();
        c.commit(0).unwrap();
        // Re-open (the dmtcp_restart path).
        let c2 = SystemChain::open(&dir, 1, Codec::Deflate(1)).unwrap();
        assert_eq!(c2.read(0, 0).unwrap(), s);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncate_resets_count_and_overwrite_works() {
        let dir = tmpdir("trunc");
        let c = SystemChain::create(&dir, 1, Codec::Raw).unwrap();
        for no in 0..4u64 {
            c.write(no, 0, &snap(no, no as f32)).unwrap();
            c.commit(no).unwrap();
        }
        assert_eq!(c.count().unwrap(), 4);
        c.truncate(2).unwrap(); // rollback to ck1 → count 2
        assert_eq!(c.count().unwrap(), 2);
        // Re-execution overwrites ck2 with new content and recommits.
        c.write(2, 0, &snap(2, 99.0)).unwrap();
        c.commit(2).unwrap();
        assert_eq!(c.count().unwrap(), 3);
        assert_eq!(c.read(2, 0).unwrap().stores[0].f32("x").unwrap()[0], 99.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dirty_checkpoint_captures_divergence() {
        // The defining property vs user-level checkpoints: divergent replica
        // stores are captured as-is and come back divergent.
        let dir = tmpdir("dirty");
        let c = SystemChain::create(&dir, 1, Codec::Raw).unwrap();
        let mut s = snap(3, 1.0);
        s.stores[1].f32_mut("x").unwrap()[0] = -1.0; // replica 1 corrupted
        c.write(0, 0, &s).unwrap();
        c.commit(0).unwrap();
        let back = c.read(0, 0).unwrap();
        assert_ne!(
            back.stores[0].f32("x").unwrap()[0],
            back.stores[1].f32("x").unwrap()[0]
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_checkpoint_errors() {
        let dir = tmpdir("missing");
        let c = SystemChain::create(&dir, 1, Codec::Raw).unwrap();
        assert!(c.read(0, 0).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
