//! User-level (application-level) checkpointing — §3.3, Algorithm 2.
//!
//! Each replica dumps only the application's *significant variables*. The
//! two dumps are hash-compared (SHA-256) **at creation time**, reusing the
//! message-validation machinery:
//!
//! * hashes match ⇒ the replicas were still in agreement, the checkpoint is
//!   **valid**, and the previous one can be discarded — storage holds a
//!   single valid checkpoint at any time;
//! * hashes differ ⇒ a fault occurred within the last checkpoint interval;
//!   the candidate is **corrupted**, is discarded, and execution restarts
//!   from the previous (valid) checkpoint. Detection latency is therefore
//!   confined within one checkpoint interval and at most one rollback is
//!   ever needed (Equation 8's `(1/2)·t_i` re-execution term).
//!
//! Restoring a user-level checkpoint loads the *single validated copy* into
//! **both** replicas, which also wipes out any latent replica divergence —
//! unlike system-level restore, which faithfully reproduces it.

use std::path::{Path, PathBuf};

use crate::error::{Result, SedarError};
use crate::state::VarStore;

use super::snapshot::{self, read_frame, write_frame, Codec};

/// The payload of a user-level checkpoint: the phase cursor + the filtered
/// (significant-variables-only) store.
#[derive(Debug, Clone, PartialEq)]
pub struct UserSnapshot {
    pub cursor: u64,
    pub store: VarStore,
}

impl UserSnapshot {
    pub fn serialize(&self) -> Vec<u8> {
        Self::serialize_parts(self.cursor, &self.store.serialize())
    }

    /// Assemble the payload from an already-serialized (filtered) store —
    /// the checkpoint hot path avoids a deserialize→reserialize round trip
    /// (perf change P5, EXPERIMENTS.md §Perf).
    pub fn serialize_parts(cursor: u64, store_bytes: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + store_bytes.len());
        out.extend_from_slice(&cursor.to_le_bytes());
        out.extend_from_slice(store_bytes);
        out
    }

    pub fn deserialize(data: &[u8]) -> Result<UserSnapshot> {
        if data.len() < 8 {
            return Err(SedarError::Checkpoint("truncated UserSnapshot".into()));
        }
        let cursor = u64::from_le_bytes(data[0..8].try_into().unwrap());
        let store = VarStore::deserialize(&data[8..])?;
        Ok(UserSnapshot { cursor, store })
    }
}

/// Storage manager for the single-valid-checkpoint scheme.
///
/// Layout: `dir/uck<NO>_rank<R>.bin` + `dir/ulatest.idx` holding the number
/// of the latest *valid* checkpoint (or `-1`).
pub struct UserChain {
    dir: PathBuf,
    nranks: usize,
    codec: Codec,
}

impl UserChain {
    pub fn create(dir: &Path, nranks: usize, codec: Codec) -> Result<UserChain> {
        std::fs::create_dir_all(dir)?;
        let c = UserChain {
            dir: dir.to_path_buf(),
            nranks,
            codec,
        };
        if !c.idx_path().exists() {
            c.set_latest(None)?;
        }
        Ok(c)
    }

    pub fn open(dir: &Path, nranks: usize, codec: Codec) -> Result<UserChain> {
        if !dir.join("ulatest.idx").exists() {
            return Err(SedarError::Checkpoint(format!(
                "no user chain at {}",
                dir.display()
            )));
        }
        Ok(UserChain {
            dir: dir.to_path_buf(),
            nranks,
            codec,
        })
    }

    fn idx_path(&self) -> PathBuf {
        self.dir.join("ulatest.idx")
    }

    fn uck_path(&self, no: u64, rank: usize) -> PathBuf {
        self.dir.join(format!("uck{no}_rank{rank}.bin"))
    }

    /// Number of the latest valid checkpoint.
    pub fn latest(&self) -> Result<Option<u64>> {
        let s = std::fs::read_to_string(self.idx_path())?;
        let v: i64 = s
            .trim()
            .parse()
            .map_err(|e| SedarError::Checkpoint(format!("bad ulatest.idx: {e}")))?;
        Ok(if v < 0 { None } else { Some(v as u64) })
    }

    fn set_latest(&self, no: Option<u64>) -> Result<()> {
        let v = no.map(|n| n as i64).unwrap_or(-1);
        std::fs::write(self.idx_path(), format!("{v}\n"))?;
        Ok(())
    }

    /// Store rank `rank`'s validated snapshot for checkpoint `no`.
    pub fn write_valid(&self, no: u64, rank: usize, snap: &UserSnapshot) -> Result<()> {
        self.write_valid_payload(no, rank, &snap.serialize())
    }

    /// Store a pre-assembled payload (see [`UserSnapshot::serialize_parts`]).
    pub fn write_valid_payload(&self, no: u64, rank: usize, payload: &[u8]) -> Result<()> {
        write_frame(&self.uck_path(no, rank), payload, self.codec)
    }

    /// The chain's frame codec (the replica layer gates the fused encode
    /// on it: only cheap codecs may run before the digest rendezvous).
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Single-pass candidate encode (Algorithm 2's hot path): one scan over
    /// the payload yields both the ready-to-store frame bytes and
    /// SHA-256(payload) — the digest the replicas cross-validate *before*
    /// deciding whether the frame may be stored. Pair with
    /// [`UserChain::write_valid_frame`] once the verdict is in.
    pub fn encode_valid(&self, payload: &[u8]) -> (Vec<u8>, [u8; 32]) {
        let (frame, sha) = snapshot::encode_frame(payload, self.codec, true);
        (frame, sha.expect("sha requested from encode_frame"))
    }

    /// Store a frame produced by [`UserChain::encode_valid`].
    pub fn write_valid_frame(&self, no: u64, rank: usize, frame: &[u8]) -> Result<()> {
        snapshot::write_encoded(&self.uck_path(no, rank), frame)
    }

    /// Promote checkpoint `no` to "the" valid checkpoint and discard the
    /// previous one (Algorithm 2 line 25: `remove_usr_ckpt(n-1)`).
    pub fn commit_valid(&self, no: u64) -> Result<()> {
        let prev = self.latest()?;
        self.set_latest(Some(no))?;
        if let Some(p) = prev {
            if p != no {
                for rank in 0..self.nranks {
                    let _ = std::fs::remove_file(self.uck_path(p, rank));
                }
            }
        }
        Ok(())
    }

    /// Remove the candidate files of a *corrupted* checkpoint (Algorithm 2
    /// line 28: `remove_usr_ckpt(n)`). The latest-valid pointer is untouched.
    pub fn discard(&self, no: u64) -> Result<()> {
        for rank in 0..self.nranks {
            let _ = std::fs::remove_file(self.uck_path(no, rank));
        }
        Ok(())
    }

    /// Load rank `rank`'s copy of checkpoint `no`.
    pub fn read(&self, no: u64, rank: usize) -> Result<UserSnapshot> {
        let payload = read_frame(&self.uck_path(no, rank))?;
        UserSnapshot::deserialize(&payload)
    }

    /// Bytes on disk — should stay O(one checkpoint), the §3.3 storage win.
    pub fn disk_bytes(&self) -> Result<u64> {
        let mut total = 0;
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            if entry.file_name().to_string_lossy().starts_with("uck") {
                total += entry.metadata()?.len();
            }
        }
        Ok(total)
    }

    pub fn nranks(&self) -> usize {
        self.nranks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::Var;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "sedar-uchain-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn usnap(cursor: u64, v: f32) -> UserSnapshot {
        let mut s = VarStore::new();
        s.insert("C", Var::f32(&[2], vec![v, v * 2.0]));
        UserSnapshot { cursor, store: s }
    }

    #[test]
    fn snapshot_roundtrip() {
        let s = usnap(11, 5.0);
        assert_eq!(UserSnapshot::deserialize(&s.serialize()).unwrap(), s);
    }

    #[test]
    fn single_valid_checkpoint_retained() {
        let dir = tmpdir("single");
        let c = UserChain::create(&dir, 2, Codec::Raw).unwrap();
        assert_eq!(c.latest().unwrap(), None);

        for rank in 0..2 {
            c.write_valid(0, rank, &usnap(2, 1.0)).unwrap();
        }
        c.commit_valid(0).unwrap();
        assert_eq!(c.latest().unwrap(), Some(0));

        for rank in 0..2 {
            c.write_valid(1, rank, &usnap(4, 2.0)).unwrap();
        }
        c.commit_valid(1).unwrap();
        assert_eq!(c.latest().unwrap(), Some(1));

        // The previous checkpoint's files are gone: single-valid invariant.
        assert!(c.read(0, 0).is_err());
        assert!(c.read(1, 0).is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn discard_keeps_previous_valid() {
        let dir = tmpdir("discard");
        let c = UserChain::create(&dir, 1, Codec::Raw).unwrap();
        c.write_valid(0, 0, &usnap(2, 1.0)).unwrap();
        c.commit_valid(0).unwrap();
        // Candidate 1 turns out corrupted: discard it.
        c.write_valid(1, 0, &usnap(4, 2.0)).unwrap();
        c.discard(1).unwrap();
        assert_eq!(c.latest().unwrap(), Some(0));
        assert!(c.read(1, 0).is_err());
        assert_eq!(c.read(0, 0).unwrap(), usnap(2, 1.0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn encode_valid_frame_equals_write_valid_payload() {
        // The fused encode+store path must leave on-disk bytes identical to
        // the two-pass write (the campaign's byte-identical-report invariant
        // reaches through checkpoint files via recovery timings, so the
        // formats must never fork).
        for codec in [Codec::Raw, Codec::Deflate(1)] {
            let dir = tmpdir(match codec {
                Codec::Raw => "fuseraw",
                _ => "fusedefl",
            });
            let c = UserChain::create(&dir, 1, codec).unwrap();
            let payload = usnap(6, 3.5).serialize();
            c.write_valid_payload(7, 0, &payload).unwrap();
            let legacy = std::fs::read(c.uck_path(7, 0)).unwrap();
            let (frame, sha) = c.encode_valid(&payload);
            assert_eq!(frame, legacy);
            assert_eq!(sha, crate::util::sha256::sha256(&payload));
            c.write_valid_frame(8, 0, &frame).unwrap();
            assert_eq!(std::fs::read(c.uck_path(8, 0)).unwrap(), legacy);
            assert_eq!(c.read(8, 0).unwrap(), usnap(6, 3.5));
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }

    #[test]
    fn reopen_preserves_latest() {
        let dir = tmpdir("reopen");
        {
            let c = UserChain::create(&dir, 1, Codec::Deflate(1)).unwrap();
            c.write_valid(3, 0, &usnap(8, 7.0)).unwrap();
            c.commit_valid(3).unwrap();
        }
        let c = UserChain::open(&dir, 1, Codec::Deflate(1)).unwrap();
        assert_eq!(c.latest().unwrap(), Some(3));
        assert_eq!(c.read(3, 0).unwrap().cursor, 8);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_usage_stays_single_checkpoint() {
        let dir = tmpdir("disk");
        let c = UserChain::create(&dir, 1, Codec::Raw).unwrap();
        c.write_valid(0, 0, &usnap(2, 1.0)).unwrap();
        c.commit_valid(0).unwrap();
        let one = c.disk_bytes().unwrap();
        for no in 1..6u64 {
            c.write_valid(no, 0, &usnap(no * 2, no as f32)).unwrap();
            c.commit_valid(no).unwrap();
        }
        assert_eq!(c.disk_bytes().unwrap(), one);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
