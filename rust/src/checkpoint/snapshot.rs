//! On-disk frame format shared by both checkpoint kinds.
//!
//! ```text
//! +--------+---------+-------+----------------+----------------+---------+
//! | "SDCK" | version | flags | payload CRC32  | payload length | body    |
//! | 4 B    | u32     | u32   | u32            | u64            | ...     |
//! +--------+---------+-------+----------------+----------------+---------+
//! ```
//!
//! `flags & 1` ⇒ body is compressed with the crate's own LZSS codec
//! ([`crate::util::codec`] — the offline dependency set has no compression
//! crate, so "deflate" here names the policy knob, not RFC 1951). The CRC
//! is over the *uncompressed* payload, so storage corruption is always
//! detected at restart time — distinct from SEDAR's *silent* checkpoint
//! corruption, which is corrupt-but-consistent data faithfully captured
//! from a faulty replica (the frame CRC is valid in that case; only the
//! replica-vs-replica comparison can catch it, which is the whole point of
//! §3.3).
//!
//! Checkpoint payloads keep this SDCK frame; the fleet's durable state
//! moved to the write-ahead log ([`crate::fleet::wal`]), whose records ride
//! the shared length+CRC framing in [`crate::util::frame`].
//!
//! Writes are **single-pass**: [`encode_frame`] emits the body while
//! folding CRC-32 (and, for validated user checkpoints, SHA-256 of the
//! payload) over the same scan, instead of the historical
//! hash-then-compress-then-concatenate triple walk. The frame bytes are
//! unchanged — only the number of passes over the payload is.

use std::path::Path;

use crate::error::{Result, SedarError};
use crate::util::codec::{compress_fused, copy_fused, crc32, decompress, PassState};

const MAGIC: &[u8; 4] = b"SDCK";
const VERSION: u32 = 1;
const FLAG_DEFLATE: u32 = 1;

/// Compression policy for snapshot bodies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// No compression — the perf-pass default: checkpoint bodies here are
    /// dominated by f32 matrices with random mantissas, where DEFLATE(1)
    /// costs 6–7× the write time for <5 % size reduction (measured in
    /// EXPERIMENTS.md §Perf). Use [`Codec::Deflate`] for workloads with
    /// compressible state (sparse/integer-heavy).
    Raw,
    /// Compress at the given effort level (1–9; the name predates the
    /// zero-dep LZSS codec that now backs it).
    Deflate(u32),
}

impl Default for Codec {
    fn default() -> Self {
        Codec::Raw
    }
}

/// Encode `payload` into a complete frame byte-string — in **one pass**
/// over the payload. The body (raw copy or LZSS) is emitted straight into
/// the frame buffer while CRC-32 (and, when `want_sha`, SHA-256 of the
/// *payload* — Algorithm 2's checkpoint hash) fold over the same scan; the
/// CRC header field is patched in afterwards. Output is byte-identical to
/// the historical header + separate-CRC-pass + separate-compress-pass
/// assembly (asserted by `fused_frame_matches_legacy_assembly` below).
pub fn encode_frame(payload: &[u8], codec: Codec, want_sha: bool) -> (Vec<u8>, Option<[u8; 32]>) {
    let (flags, cap_hint) = match codec {
        Codec::Raw => (0u32, payload.len()),
        Codec::Deflate(_) => (FLAG_DEFLATE, payload.len() / 2 + 16),
    };
    let mut out = Vec::with_capacity(24 + cap_hint);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // CRC, patched below
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());

    let mut pass = PassState::new(want_sha);
    match codec {
        Codec::Raw => copy_fused(payload, &mut out, &mut pass),
        Codec::Deflate(level) => compress_fused(payload, level, &mut out, &mut pass),
    }
    out[12..16].copy_from_slice(&pass.crc32().to_le_bytes());
    (out, pass.sha256())
}

/// Serialize `payload` into a frame at `path` (atomic: write + rename;
/// single-pass encode — see [`encode_frame`]).
pub fn write_frame(path: &Path, payload: &[u8], codec: Codec) -> Result<()> {
    let (frame, _) = encode_frame(payload, codec, false);
    write_encoded(path, &frame)
}

/// Atomically store an already-encoded frame (from [`encode_frame`]).
pub fn write_encoded(path: &Path, frame: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, frame)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read and verify a frame; returns the uncompressed payload.
pub fn read_frame(path: &Path) -> Result<Vec<u8>> {
    let data = std::fs::read(path)?;
    if data.len() < 24 || &data[0..4] != MAGIC {
        return Err(SedarError::Checkpoint(format!(
            "{}: not a snapshot frame",
            path.display()
        )));
    }
    let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(SedarError::Checkpoint(format!(
            "{}: unsupported frame version {version}",
            path.display()
        )));
    }
    let flags = u32::from_le_bytes(data[8..12].try_into().unwrap());
    let crc = u32::from_le_bytes(data[12..16].try_into().unwrap());
    let len = u64::from_le_bytes(data[16..24].try_into().unwrap()) as usize;
    let body = &data[24..];
    let payload = if flags & FLAG_DEFLATE != 0 {
        // A corrupt length field must fail cleanly, not allocate the moon:
        // the LZSS stream expands at most ~86× (one 3-byte token → 258
        // bytes), so anything beyond that bound is not a valid frame.
        if len > body.len().saturating_mul(128) + 1024 {
            return Err(SedarError::Checkpoint(format!(
                "{}: implausible payload length {len} for {}-byte body",
                path.display(),
                body.len()
            )));
        }
        decompress(body, len)
            .map_err(|e| SedarError::Checkpoint(format!("{}: {e}", path.display())))?
    } else {
        body.to_vec()
    };
    if payload.len() != len {
        return Err(SedarError::Checkpoint(format!(
            "{}: length mismatch ({} != {len})",
            path.display(),
            payload.len()
        )));
    }
    let actual_crc = crc32(&payload);
    if actual_crc != crc {
        return Err(SedarError::Checkpoint(format!(
            "{}: CRC mismatch (storage corruption)",
            path.display()
        )));
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::codec::compress;
    use crate::util::prng::SplitMix64;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("sedar-snap-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_raw() {
        let d = tmpdir("raw");
        let p = d.join("f.bin");
        let payload = b"hello snapshot".to_vec();
        write_frame(&p, &payload, Codec::Raw).unwrap();
        assert_eq!(read_frame(&p).unwrap(), payload);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn roundtrip_deflate() {
        let d = tmpdir("defl");
        let p = d.join("f.bin");
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        write_frame(&p, &payload, Codec::Deflate(6)).unwrap();
        // Compressible payload: frame should be smaller than the raw body.
        assert!(std::fs::metadata(&p).unwrap().len() < payload.len() as u64);
        assert_eq!(read_frame(&p).unwrap(), payload);
        std::fs::remove_dir_all(&d).unwrap();
    }

    /// The single-pass fusion must not change a single frame byte: assemble
    /// the frame the historical way (separate CRC pass, separate compress
    /// pass, then concatenate) and compare.
    #[test]
    fn fused_frame_matches_legacy_assembly() {
        let mut rng = SplitMix64::new(21);
        let mut payloads: Vec<Vec<u8>> = vec![
            vec![],
            b"short".to_vec(),
            (0..100_000u32).map(|i| (i % 251) as u8).collect(),
        ];
        payloads.push((0..50_000).map(|_| rng.next_u64() as u8).collect());
        for payload in &payloads {
            for codec in [Codec::Raw, Codec::Deflate(1), Codec::Deflate(6)] {
                let (frame, sha) = encode_frame(payload, codec, true);
                let (flags, body) = match codec {
                    Codec::Raw => (0u32, payload.clone()),
                    Codec::Deflate(level) => (FLAG_DEFLATE, compress(payload, level)),
                };
                let mut legacy = Vec::with_capacity(24 + body.len());
                legacy.extend_from_slice(MAGIC);
                legacy.extend_from_slice(&VERSION.to_le_bytes());
                legacy.extend_from_slice(&flags.to_le_bytes());
                legacy.extend_from_slice(&crc32(payload).to_le_bytes());
                legacy.extend_from_slice(&(payload.len() as u64).to_le_bytes());
                legacy.extend_from_slice(&body);
                assert_eq!(frame, legacy, "codec {codec:?}, len {}", payload.len());
                // The fused digest is the payload hash, not the body hash.
                assert_eq!(sha.unwrap(), crate::util::sha256::sha256(payload));
            }
        }
    }

    #[test]
    fn encoded_frame_write_roundtrips() {
        let d = tmpdir("digest");
        let p = d.join("f.bin");
        let payload: Vec<u8> = (0..10_000u32).map(|i| (i % 13) as u8).collect();
        let (frame, sha) = encode_frame(&payload, Codec::Deflate(1), true);
        write_encoded(&p, &frame).unwrap();
        assert_eq!(sha.unwrap(), crate::util::sha256::sha256(&payload));
        assert_eq!(read_frame(&p).unwrap(), payload);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn detects_storage_corruption() {
        let d = tmpdir("crc");
        let p = d.join("f.bin");
        write_frame(&p, b"payload-payload-payload", Codec::Raw).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xff;
        std::fs::write(&p, &raw).unwrap();
        assert!(read_frame(&p).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn rejects_non_frames() {
        let d = tmpdir("junk");
        let p = d.join("f.bin");
        std::fs::write(&p, b"garbage").unwrap();
        assert!(read_frame(&p).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }
}
