//! On-disk frame format shared by both checkpoint kinds.
//!
//! ```text
//! +--------+---------+-------+----------------+----------------+---------+
//! | "SDCK" | version | flags | payload CRC32  | payload length | body    |
//! | 4 B    | u32     | u32   | u32            | u64            | ...     |
//! +--------+---------+-------+----------------+----------------+---------+
//! ```
//!
//! `flags & 1` ⇒ body is compressed with the crate's own LZSS codec
//! ([`crate::util::codec`] — the offline dependency set has no compression
//! crate, so "deflate" here names the policy knob, not RFC 1951). The CRC
//! is over the *uncompressed* payload, so storage corruption is always
//! detected at restart time — distinct from SEDAR's *silent* checkpoint
//! corruption, which is corrupt-but-consistent data faithfully captured
//! from a faulty replica (the frame CRC is valid in that case; only the
//! replica-vs-replica comparison can catch it, which is the whole point of
//! §3.3).
//!
//! Beyond checkpoints, the same frame wraps the fleet's durable shard
//! artifacts ([`crate::fleet::artifact`]) — one codec guards every byte the
//! system persists.

use std::path::Path;

use crate::error::{Result, SedarError};
use crate::util::codec::{compress, crc32, decompress};

const MAGIC: &[u8; 4] = b"SDCK";
const VERSION: u32 = 1;
const FLAG_DEFLATE: u32 = 1;

/// Compression policy for snapshot bodies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// No compression — the perf-pass default: checkpoint bodies here are
    /// dominated by f32 matrices with random mantissas, where DEFLATE(1)
    /// costs 6–7× the write time for <5 % size reduction (measured in
    /// EXPERIMENTS.md §Perf). Use [`Codec::Deflate`] for workloads with
    /// compressible state (sparse/integer-heavy).
    Raw,
    /// Compress at the given effort level (1–9; the name predates the
    /// zero-dep LZSS codec that now backs it).
    Deflate(u32),
}

impl Default for Codec {
    fn default() -> Self {
        Codec::Raw
    }
}

/// Serialize `payload` into a frame at `path` (atomic: write + rename).
pub fn write_frame(path: &Path, payload: &[u8], codec: Codec) -> Result<()> {
    let crc = crc32(payload);
    let (flags, body) = match codec {
        Codec::Raw => (0u32, payload.to_vec()),
        Codec::Deflate(level) => (FLAG_DEFLATE, compress(payload, level)),
    };
    let mut out = Vec::with_capacity(24 + body.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&body);

    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &out)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read and verify a frame; returns the uncompressed payload.
pub fn read_frame(path: &Path) -> Result<Vec<u8>> {
    let data = std::fs::read(path)?;
    if data.len() < 24 || &data[0..4] != MAGIC {
        return Err(SedarError::Checkpoint(format!(
            "{}: not a snapshot frame",
            path.display()
        )));
    }
    let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(SedarError::Checkpoint(format!(
            "{}: unsupported frame version {version}",
            path.display()
        )));
    }
    let flags = u32::from_le_bytes(data[8..12].try_into().unwrap());
    let crc = u32::from_le_bytes(data[12..16].try_into().unwrap());
    let len = u64::from_le_bytes(data[16..24].try_into().unwrap()) as usize;
    let body = &data[24..];
    let payload = if flags & FLAG_DEFLATE != 0 {
        // A corrupt length field must fail cleanly, not allocate the moon:
        // the LZSS stream expands at most ~86× (one 3-byte token → 258
        // bytes), so anything beyond that bound is not a valid frame.
        if len > body.len().saturating_mul(128) + 1024 {
            return Err(SedarError::Checkpoint(format!(
                "{}: implausible payload length {len} for {}-byte body",
                path.display(),
                body.len()
            )));
        }
        decompress(body, len)
            .map_err(|e| SedarError::Checkpoint(format!("{}: {e}", path.display())))?
    } else {
        body.to_vec()
    };
    if payload.len() != len {
        return Err(SedarError::Checkpoint(format!(
            "{}: length mismatch ({} != {len})",
            path.display(),
            payload.len()
        )));
    }
    let actual_crc = crc32(&payload);
    if actual_crc != crc {
        return Err(SedarError::Checkpoint(format!(
            "{}: CRC mismatch (storage corruption)",
            path.display()
        )));
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("sedar-snap-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_raw() {
        let d = tmpdir("raw");
        let p = d.join("f.bin");
        let payload = b"hello snapshot".to_vec();
        write_frame(&p, &payload, Codec::Raw).unwrap();
        assert_eq!(read_frame(&p).unwrap(), payload);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn roundtrip_deflate() {
        let d = tmpdir("defl");
        let p = d.join("f.bin");
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        write_frame(&p, &payload, Codec::Deflate(6)).unwrap();
        // Compressible payload: frame should be smaller than the raw body.
        assert!(std::fs::metadata(&p).unwrap().len() < payload.len() as u64);
        assert_eq!(read_frame(&p).unwrap(), payload);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn detects_storage_corruption() {
        let d = tmpdir("crc");
        let p = d.join("f.bin");
        write_frame(&p, b"payload-payload-payload", Codec::Raw).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xff;
        std::fs::write(&p, &raw).unwrap();
        assert!(read_frame(&p).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn rejects_non_frames() {
        let d = tmpdir("junk");
        let p = d.join("f.bin");
        std::fs::write(&p, b"garbage").unwrap();
        assert!(read_frame(&p).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }
}
