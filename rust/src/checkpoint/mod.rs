//! The two checkpointing substrates of SEDAR.
//!
//! * [`system`] — DMTCP-equivalent: a **chain** of coordinated, whole-state,
//!   *unvalidated* checkpoints (§3.2). Because a checkpoint may capture
//!   already-corrupted replica state ("dirty" checkpoints), none can be
//!   deleted and recovery may need to walk several steps back (Algorithm 1).
//! * [`user`] — application-level: per-replica dumps of the app's
//!   *significant variables*, cross-validated by SHA-256 between the two
//!   replicas at creation time (§3.3, Algorithm 2). A checkpoint that
//!   validates proves the replicas were still in agreement, so the previous
//!   checkpoint can be discarded — a **single** valid checkpoint exists at
//!   any time and at most one rollback is ever needed.
//! * [`snapshot`] — the shared on-disk framing (magic/version/CRC32/deflate).

pub mod snapshot;
pub mod system;
pub mod user;

pub use system::{RankSnapshot, SystemChain};
pub use user::UserChain;
