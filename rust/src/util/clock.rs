//! Logical time for SEDAR worlds.
//!
//! Every timeout-facing decision in the runtime (TOE rendezvous lapses,
//! checkpoint watchdogs, injected delays) goes through a [`Clock`] handle
//! instead of `std::time` directly. Two implementations share one API:
//!
//! * [`ClockMode::Wall`] — real time. `now()` is nanoseconds since the clock
//!   was created and waits park on a condvar with a real deadline. This is
//!   the default for interactive and bench runs.
//! * [`ClockMode::Virtual`] — a per-world shared logical clock. Time never
//!   flows on its own: whenever **every registered participant** of the world
//!   is blocked in a clock wait **and no notified waiter still has its
//!   wakeup in flight**, the clock jumps to the earliest pending deadline
//!   (quiescence-driven advance). The in-flight condition keeps the advance
//!   schedule-independent: a producer that notifies and immediately blocks
//!   cannot drag time forward before the notified consumer has re-checked
//!   its condition. An idle world costs nothing and a timeout verdict
//!   becomes a deterministic function of the dependency structure, not of
//!   scheduler load.
//!
//! One tick is one nanosecond of modeled time, so `Duration` values convert
//! exactly in both directions ([`Clock::ticks`] is the single conversion
//! point). Under `Wall` the two notions coincide; under `Virtual` a
//! "2000 ms" `toe_timeout` means 2×10⁹ ticks of logical time that elapse
//! instantly in wall terms once the world quiesces.
//!
//! ## Waiter protocol (lost-wakeup free)
//!
//! Producers call [`Clock::notify`] after publishing state (a mailbox push,
//! a pair-cell push, an abort flag). Consumers capture a generation with
//! [`Clock::subscribe`] **before** re-checking their condition, then call
//! [`Clock::wait`]; if the generation moved in between, the wait returns
//! [`Wait::Notified`] immediately. This is exactly the condvar
//! generation-counter idiom, centralized so the virtual clock can observe
//! "every thread is blocked" without cooperation from call sites.
//!
//! Hot producer/consumer pairs (a mailbox, a pair cell) run the same
//! protocol over a [`WaitPoint`] from [`Clock::wait_point`]: under `Wall`
//! the point has its own lock and condvar so a send wakes only its
//! receiver, while under `Virtual` it aliases the world clock so
//! quiescence detection still sees every waiter. The broadcast
//! [`Clock::notify`] reaches both the world channel and every point —
//! that is what lets one abort wake every blocked thread.
//!
//! ## Participants
//!
//! The virtual advance rule needs to know how many threads belong to the
//! world: register them with [`Clock::join_n`] *before* spawning (so a
//! not-yet-scheduled thread can never be mistaken for a blocked one) and
//! claim one [`ClockGuard`] per thread, which leaves on drop — including
//! during panic unwind, so a crashed replica cannot freeze the world's time.
//! If the world quiesces with no pending deadline at all, no event can ever
//! wake it; the clock poisons itself and every waiter unwinds with
//! [`Wait::Poisoned`] instead of deadlocking the process.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::error::{Result, SedarError};

/// Logical time stamp: nanoseconds of modeled time since the clock epoch.
pub type Tick = u64;

/// Which clock implementation a run uses. Campaigns default to `Virtual`;
/// interactive/bench runs default to `Wall`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    Wall,
    Virtual,
}

impl ClockMode {
    pub fn parse(s: &str) -> Result<ClockMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "wall" => Ok(ClockMode::Wall),
            "virtual" => Ok(ClockMode::Virtual),
            other => Err(SedarError::Config(format!(
                "unknown clock mode '{other}' (expected wall|virtual)"
            ))),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ClockMode::Wall => "wall",
            ClockMode::Virtual => "virtual",
        }
    }
}

/// Outcome of a [`Clock::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wait {
    /// The generation moved: re-check your condition.
    Notified,
    /// The deadline passed (really, or by virtual advance). Reported even if
    /// the generation moved too — callers re-check their condition once
    /// before treating it as a timeout (the just-in-time-arrival pattern).
    TimedOut,
    /// Virtual only: the world quiesced with no pending deadline — a true
    /// deadlock. Unwind with an error instead of hanging.
    Poisoned,
}

struct WallPoint {
    gen: Mutex<u64>,
    cv: Condvar,
}

struct WallInner {
    epoch: Instant,
    gen: Mutex<u64>,
    cv: Condvar,
    /// Targeted wakeup channels handed out by [`Clock::wait_point`]. The
    /// broadcast [`Clock::notify`] (abort/safe-stop) must reach them all.
    points: Mutex<Vec<Weak<WallPoint>>>,
}

#[derive(Default)]
struct VirtState {
    now: Tick,
    gen: u64,
    /// Threads registered via `join_n` and not yet departed.
    participants: usize,
    /// Threads currently parked inside `wait`.
    blocked: usize,
    /// Blocked waiters whose captured generation predates `gen`: they have a
    /// wakeup in flight and must re-check their condition before the world
    /// can be considered quiescent. Advancing time while `stale > 0` would
    /// jump past work a notified-but-not-yet-scheduled thread is about to do,
    /// making virtual timestamps depend on OS scheduling.
    stale: usize,
    /// Pending deadlines (tick → number of waiters registered on it).
    deadlines: BTreeMap<Tick, usize>,
    poisoned: bool,
}

impl VirtState {
    /// Every generation bump makes every currently-parked waiter stale: they
    /// all captured an older generation (a thread between `subscribe` and
    /// `wait` is caught by the pre-block generation check instead and never
    /// parks).
    fn bump_gen(&mut self) {
        self.gen += 1;
        self.stale = self.blocked;
    }
}

struct VirtInner {
    state: Mutex<VirtState>,
    cv: Condvar,
}

enum Inner {
    Wall(WallInner),
    Virtual(VirtInner),
}

/// Cheap-to-clone handle on a world's clock.
pub struct Clock(Arc<Inner>);

impl Clone for Clock {
    fn clone(&self) -> Clock {
        Clock(Arc::clone(&self.0))
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Clock({})", self.mode().label())
    }
}

impl Clock {
    pub fn new(mode: ClockMode) -> Clock {
        match mode {
            ClockMode::Wall => Clock::wall(),
            ClockMode::Virtual => Clock::virtual_clock(),
        }
    }

    /// Real time; `now()` starts at 0 at construction.
    pub fn wall() -> Clock {
        Clock(Arc::new(Inner::Wall(WallInner {
            epoch: Instant::now(),
            gen: Mutex::new(0),
            cv: Condvar::new(),
            points: Mutex::new(Vec::new()),
        })))
    }

    /// Logical time; `now()` starts at 0 and advances only at quiescence.
    pub fn virtual_clock() -> Clock {
        Clock(Arc::new(Inner::Virtual(VirtInner {
            state: Mutex::new(VirtState::default()),
            cv: Condvar::new(),
        })))
    }

    pub fn mode(&self) -> ClockMode {
        match &*self.0 {
            Inner::Wall(_) => ClockMode::Wall,
            Inner::Virtual(_) => ClockMode::Virtual,
        }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(&*self.0, Inner::Virtual(_))
    }

    /// The single `Duration` → tick conversion point: 1 tick = 1 ns.
    pub fn ticks(d: Duration) -> Tick {
        u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
    }

    /// Current time in ticks since the clock epoch.
    pub fn now(&self) -> Tick {
        match &*self.0 {
            Inner::Wall(w) => Self::wall_now(w),
            Inner::Virtual(v) => v.state.lock().unwrap().now,
        }
    }

    fn wall_now(w: &WallInner) -> Tick {
        u64::try_from(w.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }

    /// Absolute deadline `d` from now, saturating.
    pub fn deadline_after(&self, d: Duration) -> Tick {
        self.now().saturating_add(Self::ticks(d))
    }

    /// Elapsed modeled time since an earlier [`Clock::now`] reading.
    pub fn since(&self, t0: Tick) -> Duration {
        Duration::from_nanos(self.now().saturating_sub(t0))
    }

    // ------------------------------------------------------------------
    // Producer / consumer protocol
    // ------------------------------------------------------------------

    /// Capture the current generation. Call **before** checking the
    /// condition you intend to wait on.
    pub fn subscribe(&self) -> u64 {
        match &*self.0 {
            Inner::Wall(w) => *w.gen.lock().unwrap(),
            Inner::Virtual(v) => v.state.lock().unwrap().gen,
        }
    }

    /// Publish: bump the generation and wake every waiter. Producers call
    /// this after making state observable (push + unlock, abort store, ...).
    pub fn notify(&self) {
        match &*self.0 {
            Inner::Wall(w) => {
                *w.gen.lock().unwrap() += 1;
                w.cv.notify_all();
                // Broadcast must also reach every targeted wait point, so an
                // abort wakes receivers parked on their own mailbox channel.
                let points: Vec<Arc<WallPoint>> = {
                    let mut pts = w.points.lock().unwrap();
                    pts.retain(|p| p.strong_count() > 0);
                    pts.iter().filter_map(Weak::upgrade).collect()
                };
                for p in points {
                    *p.gen.lock().unwrap() += 1;
                    p.cv.notify_all();
                }
            }
            Inner::Virtual(v) => {
                v.state.lock().unwrap().bump_gen();
                v.cv.notify_all();
            }
        }
    }

    /// Park until the generation moves past `gen` or `deadline` (absolute
    /// ticks) passes. `None` waits indefinitely — under `Virtual` that is
    /// only safe if some other participant holds a deadline or will produce
    /// an event; a fully-quiescent deadline-free world poisons instead.
    pub fn wait(&self, gen: u64, deadline: Option<Tick>) -> Wait {
        match &*self.0 {
            Inner::Wall(w) => Self::wall_wait(w, gen, deadline),
            Inner::Virtual(v) => Self::virtual_wait(v, gen, deadline),
        }
    }

    fn wall_wait(w: &WallInner, gen: u64, deadline: Option<Tick>) -> Wait {
        Self::wall_wait_on(w, &w.gen, &w.cv, gen, deadline)
    }

    fn wall_wait_on(
        w: &WallInner,
        genm: &Mutex<u64>,
        cv: &Condvar,
        gen: u64,
        deadline: Option<Tick>,
    ) -> Wait {
        let mut g = genm.lock().unwrap();
        loop {
            if *g != gen {
                return Wait::Notified;
            }
            match deadline {
                None => {
                    g = cv.wait(g).unwrap();
                }
                Some(d) => {
                    let now = Self::wall_now(w);
                    if now >= d {
                        return Wait::TimedOut;
                    }
                    let dur = Duration::from_nanos(d - now);
                    let (guard, _res) = cv.wait_timeout(g, dur).unwrap();
                    g = guard;
                }
            }
        }
    }

    fn virtual_wait(v: &VirtInner, gen: u64, deadline: Option<Tick>) -> Wait {
        let mut st = v.state.lock().unwrap();
        if st.poisoned {
            return Wait::Poisoned;
        }
        if st.gen != gen {
            return Wait::Notified;
        }
        if let Some(d) = deadline {
            if st.now >= d {
                return Wait::TimedOut;
            }
            *st.deadlines.entry(d).or_insert(0) += 1;
        }
        st.blocked += 1;
        let out = loop {
            if st.poisoned {
                break Wait::Poisoned;
            }
            // Deadline before generation: a quiescence advance bumps the
            // generation as part of moving `now`, so a waiter whose own
            // deadline was just reached must still report `TimedOut`, not
            // `Notified` (callers re-check their condition on `TimedOut`
            // anyway, so a racing notify is never lost).
            if let Some(d) = deadline {
                if st.now >= d {
                    break Wait::TimedOut;
                }
            }
            if st.gen != gen {
                break Wait::Notified;
            }
            // Quiescence: every registered participant is parked here (>=
            // covers unregistered standalone waiters, e.g. unit tests) and
            // none of them has an unprocessed wakeup in flight.
            if st.blocked >= st.participants && st.stale == 0 {
                match st.deadlines.keys().next().copied() {
                    Some(d) => {
                        if d > st.now {
                            st.now = d;
                        }
                        // The advance is itself an event: bump + broadcast so
                        // every waiter (this one included) re-evaluates.
                        st.bump_gen();
                        v.cv.notify_all();
                        continue;
                    }
                    None => {
                        st.poisoned = true;
                        v.cv.notify_all();
                        break Wait::Poisoned;
                    }
                }
            }
            st = v.cv.wait(st).unwrap();
        };
        st.blocked -= 1;
        if st.gen != gen {
            // This waiter was one of the stale ones; its re-check is done.
            st.stale = st.stale.saturating_sub(1);
        }
        if let Some(d) = deadline {
            if let Some(c) = st.deadlines.get_mut(&d) {
                *c -= 1;
                if *c == 0 {
                    st.deadlines.remove(&d);
                }
            }
        }
        out
    }

    /// Block until modeled time reaches `deadline` (absolute ticks).
    pub fn wait_until(&self, deadline: Tick) {
        loop {
            let gen = self.subscribe();
            if self.now() >= deadline {
                return;
            }
            match self.wait(gen, Some(deadline)) {
                Wait::Notified => continue,
                Wait::TimedOut | Wait::Poisoned => return,
            }
        }
    }

    /// Sleep for `d` of modeled time (instantaneous in wall terms under
    /// `Virtual` once the world quiesces).
    pub fn sleep(&self, d: Duration) {
        self.wait_until(self.deadline_after(d));
    }

    // ------------------------------------------------------------------
    // Participant lifecycle (virtual advance bookkeeping)
    // ------------------------------------------------------------------

    /// Pre-register `k` participant slots **before** spawning their threads,
    /// so a thread that has not been scheduled yet can never be mistaken for
    /// a blocked one. No-op under `Wall`.
    pub fn join_n(&self, k: usize) {
        if let Inner::Virtual(v) = &*self.0 {
            let mut st = v.state.lock().unwrap();
            st.participants += k;
        }
    }

    /// Claim one pre-registered slot; the returned guard releases it on
    /// drop — including during panic unwind, so a crashed thread cannot
    /// freeze the world's time.
    pub fn guard(&self) -> ClockGuard {
        ClockGuard {
            clock: self.clone(),
        }
    }

    // ------------------------------------------------------------------
    // Targeted wakeup channels
    // ------------------------------------------------------------------

    /// A wakeup channel for one waiter group (a mailbox, a pair cell).
    /// Producers notify the point their consumer waits on; the broadcast
    /// [`Clock::notify`] still reaches every point.
    ///
    /// Under `Wall` each point owns a private generation counter and
    /// condvar, so the send hot path locks only the target group's channel
    /// and wakes only that group's waiters (the per-mailbox-condvar
    /// behavior the runtime had before the Clock API — EXPERIMENTS.md §Perf
    /// notes microsecond-level sensitivity on the rendezvous path). Under
    /// `Virtual` the point is an alias for the world clock: quiescence
    /// detection needs every blocked thread observable through one
    /// protocol, and wakeup targeting buys nothing when threads block on
    /// logical time.
    pub fn wait_point(&self) -> WaitPoint {
        let wall = match &*self.0 {
            Inner::Wall(w) => {
                let p = Arc::new(WallPoint {
                    gen: Mutex::new(0),
                    cv: Condvar::new(),
                });
                let mut pts = w.points.lock().unwrap();
                pts.retain(|q| q.strong_count() > 0);
                pts.push(Arc::downgrade(&p));
                Some(p)
            }
            Inner::Virtual(_) => None,
        };
        WaitPoint {
            clock: self.clone(),
            wall,
        }
    }

    fn leave(&self) {
        if let Inner::Virtual(v) = &*self.0 {
            let mut st = v.state.lock().unwrap();
            st.participants = st.participants.saturating_sub(1);
            // Departure can create quiescence among the remaining waiters.
            st.bump_gen();
            v.cv.notify_all();
        }
    }
}

/// A targeted wakeup channel obtained from [`Clock::wait_point`]. Same
/// `subscribe`/`notify`/`wait` protocol as the clock itself, scoped to one
/// waiter group under a wall clock and transparently world-wide under a
/// virtual one.
pub struct WaitPoint {
    clock: Clock,
    wall: Option<Arc<WallPoint>>,
}

impl WaitPoint {
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Capture this channel's generation (see [`Clock::subscribe`]).
    pub fn subscribe(&self) -> u64 {
        match &self.wall {
            Some(p) => *p.gen.lock().unwrap(),
            None => self.clock.subscribe(),
        }
    }

    /// Wake this channel's waiters (see [`Clock::notify`]).
    pub fn notify(&self) {
        match &self.wall {
            Some(p) => {
                *p.gen.lock().unwrap() += 1;
                p.cv.notify_all();
            }
            None => self.clock.notify(),
        }
    }

    /// Park on this channel (see [`Clock::wait`]).
    pub fn wait(&self, gen: u64, deadline: Option<Tick>) -> Wait {
        match (&self.wall, &*self.clock.0) {
            (Some(p), Inner::Wall(w)) => Clock::wall_wait_on(w, &p.gen, &p.cv, gen, deadline),
            _ => self.clock.wait(gen, deadline),
        }
    }
}

/// Releases one participant slot on drop (see [`Clock::guard`]).
pub struct ClockGuard {
    clock: Clock,
}

impl Drop for ClockGuard {
    fn drop(&mut self) {
        self.clock.leave();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn clock_mode_parses() {
        assert_eq!(ClockMode::parse("wall").unwrap(), ClockMode::Wall);
        assert_eq!(ClockMode::parse(" Virtual ").unwrap(), ClockMode::Virtual);
        assert!(ClockMode::parse("cosmic").is_err());
    }

    #[test]
    fn ticks_convert_exactly() {
        assert_eq!(Clock::ticks(Duration::from_millis(2)), 2_000_000);
        assert_eq!(Clock::ticks(Duration::from_secs(1)), 1_000_000_000);
    }

    #[test]
    fn wall_clock_advances() {
        let c = Clock::wall();
        let t0 = c.now();
        std::thread::yield_now();
        assert!(c.now() >= t0);
        assert_eq!(c.mode(), ClockMode::Wall);
    }

    #[test]
    fn virtual_sleep_is_instant_in_wall_terms() {
        let c = Clock::virtual_clock();
        c.join_n(1);
        let _g = c.guard();
        let real = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(c.now() >= Clock::ticks(Duration::from_secs(3600)));
        assert!(
            real.elapsed() < Duration::from_secs(5),
            "an hour of modeled time must not cost an hour of wall time"
        );
    }

    #[test]
    fn virtual_timeout_fires_at_quiescence() {
        let c = Clock::virtual_clock();
        c.join_n(1);
        let _g = c.guard();
        let gen = c.subscribe();
        let deadline = c.deadline_after(Duration::from_millis(50));
        assert_eq!(c.wait(gen, Some(deadline)), Wait::TimedOut);
        assert_eq!(c.now(), deadline);
    }

    #[test]
    fn notify_wakes_virtual_waiter_before_deadline() {
        let c = Clock::virtual_clock();
        c.join_n(2);
        let flag = Arc::new(AtomicBool::new(false));
        let c2 = c.clone();
        let f2 = Arc::clone(&flag);
        let h = std::thread::spawn(move || {
            let _g = c2.guard();
            f2.store(true, Ordering::SeqCst);
            c2.notify();
            // The guard drop (departure) also bumps the generation, so a
            // consumer that parked before the flag store is woken either
            // way. The producer must NOT park on a deadline of its own
            // here: once the consumer departs it would be the sole
            // participant and quiescence would legitimately advance time
            // to that deadline, breaking the now() assertion below.
        });
        {
            let _g = c.guard();
            loop {
                let gen = c.subscribe();
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                let w = c.wait(gen, Some(c.deadline_after(Duration::from_secs(60))));
                assert_ne!(w, Wait::Poisoned);
            }
        }
        h.join().unwrap();
        // The flag path, not the 60 s deadline, must have ended the loop.
        assert!(c.now() < Clock::ticks(Duration::from_secs(60)));
    }

    #[test]
    fn all_same_deadline_waiters_time_out() {
        // The quiescence advance bumps the generation as part of moving
        // `now`; both the advancing thread and the other waiter on the same
        // deadline must still report TimedOut, not Notified.
        let c = Clock::virtual_clock();
        c.join_n(2);
        let c2 = c.clone();
        let deadline = Clock::ticks(Duration::from_millis(5));
        let h = std::thread::spawn(move || {
            let _g = c2.guard();
            let gen = c2.subscribe();
            c2.wait(gen, Some(deadline))
        });
        let mine = {
            let _g = c.guard();
            let gen = c.subscribe();
            c.wait(gen, Some(deadline))
        };
        assert_eq!(mine, Wait::TimedOut);
        assert_eq!(h.join().unwrap(), Wait::TimedOut);
        assert_eq!(c.now(), deadline);
    }

    #[test]
    fn deadline_free_quiescence_poisons() {
        let c = Clock::virtual_clock();
        c.join_n(1);
        let _g = c.guard();
        let gen = c.subscribe();
        assert_eq!(c.wait(gen, None), Wait::Poisoned);
        // And stays poisoned for later waiters.
        let gen = c.subscribe();
        assert_eq!(c.wait(gen, Some(1)), Wait::Poisoned);
    }

    #[test]
    fn guard_drop_releases_participant() {
        let c = Clock::virtual_clock();
        c.join_n(2);
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            let _g = c2.guard();
            // Leaves on drop; no clock interaction otherwise.
        });
        h.join().unwrap();
        let _g = c.guard();
        // With the other slot released, a single waiter quiesces the world.
        let gen = c.subscribe();
        let deadline = c.deadline_after(Duration::from_millis(5));
        assert_eq!(c.wait(gen, Some(deadline)), Wait::TimedOut);
    }

    #[test]
    fn wall_point_notify_is_targeted() {
        let c = Clock::wall();
        let a = c.wait_point();
        let b = c.wait_point();
        // Notifying B moves B's generation but not A's: a waiter on A with
        // a short deadline times out instead of waking spuriously.
        let gen_a = a.subscribe();
        let gen_b = b.subscribe();
        b.notify();
        assert_ne!(b.subscribe(), gen_b);
        assert_eq!(a.subscribe(), gen_a);
        let deadline = c.deadline_after(Duration::from_millis(10));
        assert_eq!(a.wait(gen_a, Some(deadline)), Wait::TimedOut);
    }

    #[test]
    fn wall_broadcast_reaches_points() {
        // An abort-style Clock::notify must wake a receiver parked on its
        // own mailbox channel.
        let c = Clock::wall();
        let p = c.wait_point();
        let gen = p.subscribe();
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.notify();
        });
        let w = p.wait(gen, Some(c.deadline_after(Duration::from_secs(30))));
        assert_eq!(w, Wait::Notified);
        h.join().unwrap();
        assert!(c.now() < Clock::ticks(Duration::from_secs(30)));
    }

    #[test]
    fn virtual_point_wait_is_visible_to_quiescence() {
        // Under a virtual clock the point aliases the world clock, so a
        // point wait still counts as blocked and its deadline still drives
        // the advance.
        let c = Clock::virtual_clock();
        c.join_n(1);
        let _g = c.guard();
        let p = c.wait_point();
        let gen = p.subscribe();
        let deadline = c.deadline_after(Duration::from_secs(600));
        assert_eq!(p.wait(gen, Some(deadline)), Wait::TimedOut);
        assert_eq!(c.now(), deadline);
    }

    #[test]
    fn earliest_deadline_wins() {
        let c = Clock::virtual_clock();
        c.join_n(2);
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            let _g = c2.guard();
            c2.sleep(Duration::from_millis(10));
            c2.now()
        });
        let woke_at = {
            let _g = c.guard();
            c.sleep(Duration::from_millis(200));
            c.now()
        };
        let early = h.join().unwrap();
        assert!(early >= Clock::ticks(Duration::from_millis(10)));
        assert!(early <= Clock::ticks(Duration::from_millis(200)));
        assert!(woke_at >= Clock::ticks(Duration::from_millis(200)));
    }
}
