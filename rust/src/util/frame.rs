//! The one CRC-framed record codec behind every durable SEDAR stream.
//!
//! The fleet write-ahead log (`SDWL`, [`crate::fleet::wal`]) and the trace
//! log (`SDTR`, [`crate::obs`]) persist the same physical shape:
//!
//! ```text
//! stream := record*
//! record := len u32 | crc32(body) u32 | body
//! ```
//!
//! Historically each stream hand-rolled its own copy of this framing with
//! its own torn-tail policy; this module is the single implementation, with
//! the two read disciplines both policies reduce to:
//!
//! * [`next_record`] — the **lenient** scan for append-only logs that may
//!   legitimately end mid-record (the process died mid-append, or a live
//!   reader raced a writer). Anything that does not frame — short header,
//!   implausible length, short body, CRC mismatch — is `None`: the torn
//!   tail ends the valid prefix, it is not an error.
//! * [`read_record`] — the **strict** read for write-once files (trace
//!   logs) where a record that does not frame is corruption and must
//!   surface as a typed error naming the offset.
//!
//! [`ByteReader`] (bounds-checked little-endian decoding over a record
//! body) and [`push_string`] live here too, shared by every body codec.

use std::io::Write;

use crate::error::{Result, SedarError};
use crate::util::codec::crc32;

/// Sanity cap on a single record body; real SEDAR records are ≪ this. A
/// length field above the cap is treated as framing damage (lenient) or
/// corruption (strict), never as an allocation request.
pub const MAX_RECORD: usize = 1 << 24;

/// Append one framed record (`len | crc | body`) to an in-memory buffer.
pub fn frame(body: &[u8], out: &mut Vec<u8>) {
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(body).to_le_bytes());
    out.extend_from_slice(body);
}

/// Durably append one framed record to `file`: the bytes are written in a
/// single `write_all` and synced (`sync_data`) before returning, so a kill
/// immediately afterwards cannot lose the record — at worst it tears the
/// *next* one, which the lenient scan drops.
pub fn write_record(file: &mut std::fs::File, body: &[u8]) -> Result<()> {
    let mut rec = Vec::with_capacity(8 + body.len());
    frame(body, &mut rec);
    file.write_all(&rec)?;
    file.sync_data()?;
    Ok(())
}

/// Lenient scan: `Some((body, end_offset))` if a whole, CRC-valid record
/// starts at `pos`; `None` for a torn or foreign tail.
pub fn next_record(data: &[u8], pos: usize) -> Option<(&[u8], usize)> {
    if data.len() - pos < 8 {
        return None;
    }
    let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
    if len > MAX_RECORD || data.len() - pos - 8 < len {
        return None;
    }
    let body = &data[pos + 8..pos + 8 + len];
    if crc32(body) != crc {
        return None;
    }
    Some((body, pos + 8 + len))
}

/// Strict read: `Ok((body, end_offset))` for the CRC-valid record starting
/// at `pos`; truncation and CRC damage are typed errors carrying `what`
/// ("trace log header", "trace log record", …) and the byte offset.
pub fn read_record<'a>(data: &'a [u8], pos: usize, what: &str) -> Result<(&'a [u8], usize)> {
    if data.len() - pos < 8 {
        return Err(SedarError::Checkpoint(format!(
            "{what} truncated at offset {pos}"
        )));
    }
    let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
    if len > MAX_RECORD || data.len() - pos - 8 < len {
        return Err(SedarError::Checkpoint(format!(
            "{what} truncated at offset {pos}"
        )));
    }
    let body = &data[pos + 8..pos + 8 + len];
    if crc32(body) != crc {
        return Err(SedarError::Checkpoint(format!(
            "{what} CRC mismatch at offset {pos}"
        )));
    }
    Ok((body, pos + 8 + len))
}

/// Length-prefixed string encoding shared by every record body codec.
pub fn push_string(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked little-endian reader over a decoded record body.
pub struct ByteReader<'a> {
    data: &'a [u8],
    pos: usize,
    /// Context for error messages ("WAL outcome record", "trace log", …).
    what: &'static str,
}

impl<'a> ByteReader<'a> {
    pub fn new(data: &'a [u8], what: &'static str) -> ByteReader<'a> {
        ByteReader { data, pos: 0, what }
    }

    pub fn what(&self) -> &'static str {
        self.what
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    fn truncated<T>(&self) -> Result<T> {
        Err(SedarError::Checkpoint(format!(
            "{} truncated at offset {}",
            self.what, self.pos
        )))
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return self.truncated();
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        // Defensive cap: a corrupt length must not allocate the moon. Any
        // legitimate site/mismatch string is far below this.
        if len > 1 << 20 {
            return Err(SedarError::Checkpoint(format!(
                "{}: implausible string length {len}",
                self.what
            )));
        }
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| {
            SedarError::Checkpoint(format!("{}: non-UTF-8 string payload", self.what))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrips_through_both_readers() {
        let mut buf = Vec::new();
        frame(b"alpha", &mut buf);
        frame(b"", &mut buf);
        frame("βγ".as_bytes(), &mut buf);

        let (a, p) = next_record(&buf, 0).unwrap();
        let (b, p) = next_record(&buf, p).unwrap();
        let (c, p) = next_record(&buf, p).unwrap();
        assert_eq!((a, b, c), (&b"alpha"[..], &b""[..], "βγ".as_bytes()));
        assert_eq!(p, buf.len());
        assert!(next_record(&buf, p).is_none(), "clean EOF is not a record");

        let (a2, q) = read_record(&buf, 0, "test stream").unwrap();
        assert_eq!(a2, b"alpha");
        assert_eq!(q, 8 + 5);
    }

    #[test]
    fn torn_tails_are_none_leniently_and_errors_strictly() {
        let mut buf = Vec::new();
        frame(b"whole", &mut buf);
        frame(b"torn-away", &mut buf);
        let torn = &buf[..buf.len() - 3];

        let (_, mid) = next_record(torn, 0).unwrap();
        assert!(next_record(torn, mid).is_none(), "torn tail must not frame");
        let err = read_record(torn, mid, "test stream").unwrap_err().to_string();
        assert!(err.contains("truncated at offset 13"), "{err}");
    }

    #[test]
    fn crc_damage_is_none_leniently_and_named_strictly() {
        let mut buf = Vec::new();
        frame(b"payload", &mut buf);
        buf[10] ^= 0x40; // flip a body byte under an intact header
        assert!(next_record(&buf, 0).is_none());
        let err = read_record(&buf, 0, "test stream").unwrap_err().to_string();
        assert!(err.contains("CRC mismatch at offset 0"), "{err}");
    }

    #[test]
    fn implausible_length_is_framing_damage_not_an_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 64]);
        assert!(next_record(&buf, 0).is_none());
        assert!(read_record(&buf, 0, "test stream").is_err());
    }

    #[test]
    fn write_record_appends_synced_framed_bytes() {
        let p = std::env::temp_dir().join(format!(
            "sedar-frame-{}-{:?}.bin",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&p);
        {
            let mut f = std::fs::File::create(&p).unwrap();
            write_record(&mut f, b"one").unwrap();
            write_record(&mut f, b"two").unwrap();
        }
        let data = std::fs::read(&p).unwrap();
        let (a, mid) = next_record(&data, 0).unwrap();
        let (b, end) = next_record(&data, mid).unwrap();
        assert_eq!((a, b), (&b"one"[..], &b"two"[..]));
        assert_eq!(end, data.len());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn byte_reader_guards_every_primitive() {
        let mut body = Vec::new();
        body.push(7u8);
        body.extend_from_slice(&0xABCDu32.to_le_bytes());
        body.extend_from_slice(&0xFEED_F00Du64.to_le_bytes());
        push_string(&mut body, "héllo");

        let mut r = ByteReader::new(&body, "test body");
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xABCD);
        assert_eq!(r.u64().unwrap(), 0xFEED_F00D);
        assert_eq!(r.string().unwrap(), "héllo");
        assert_eq!(r.remaining(), 0);
        assert!(r.u8().is_err(), "reads past the end must error");

        // An implausible string length errors before allocating.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = ByteReader::new(&huge, "test body").string().unwrap_err();
        assert!(err.to_string().contains("implausible string length"));

        // Non-UTF-8 payloads are refused, not lossily converted.
        let mut bad = Vec::new();
        bad.extend_from_slice(&2u32.to_le_bytes());
        bad.extend_from_slice(&[0xFF, 0xFE]);
        let err = ByteReader::new(&bad, "test body").string().unwrap_err();
        assert!(err.to_string().contains("non-UTF-8"));
    }
}
