//! Small shared utilities: deterministic PRNG, byte/bit helpers, shared
//! zero-copy buffers, hashing and compression codecs, a tiny stderr logger
//! and human-readable formatting.

pub mod arena;
pub mod bytes;
pub mod clock;
pub mod codec;
pub mod frame;
pub mod logger;
pub mod prng;
pub mod sha256;

use std::time::Duration;

/// Flip bit `bit` (0..=7 within the addressed byte) of `bytes[byte_idx]`.
///
/// This is the primitive used by the fault injector: the paper emulates a
/// transient bit-flip in a processor register by mutating one replica's copy
/// of a variable (§4.2).
pub fn flip_bit(bytes: &mut [u8], byte_idx: usize, bit: u8) {
    assert!(bit < 8, "bit index out of range");
    bytes[byte_idx] ^= 1 << bit;
}

/// Format a byte count for humans (`12.3 MiB`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration for humans (`1.24 ms`, `3.50 s`).
pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Format seconds as the paper does in Tables 4/5: hours with 2 decimals.
pub fn hours(seconds: f64) -> String {
    format!("{:.2}", seconds / 3600.0)
}

/// Lower-hex encoding of a byte slice (used for digest display).
pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flip_bit_roundtrip() {
        let mut b = vec![0u8; 4];
        flip_bit(&mut b, 2, 7);
        assert_eq!(b, [0, 0, 0x80, 0]);
        flip_bit(&mut b, 2, 7);
        assert_eq!(b, [0, 0, 0, 0]);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(6016 * 1024 * 1024), "5.88 GiB");
    }

    #[test]
    fn human_duration_scales() {
        assert_eq!(human_duration(Duration::from_millis(1240)), "1.240 s");
        assert_eq!(human_duration(Duration::from_micros(1240)), "1.240 ms");
        assert_eq!(human_duration(Duration::from_nanos(900)), "0.9 µs");
    }

    #[test]
    fn hex_encodes() {
        assert_eq!(hex(&[0xde, 0xad, 0x01]), "dead01");
    }

    #[test]
    fn hours_formats_like_paper() {
        assert_eq!(hours(10.21 * 3600.0), "10.21");
    }
}
