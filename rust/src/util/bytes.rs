//! Shared, immutable byte buffers — the zero-copy payload substrate.
//!
//! Every message payload and store variable in the system is backed by a
//! [`SharedBuf`]: an `Arc`-shared, word-aligned byte allocation. Cloning one
//! is a reference-count bump, so a broadcast shares **one** allocation
//! across all ranks and a validated send hands the network the same bytes
//! the store holds. Mutation is copy-on-write ([`SharedBuf::make_mut`]):
//! writers that hold the only reference mutate in place for free; writers
//! of a shared buffer get a private copy first, so replicas can never
//! observe each other's in-progress writes through a shared payload.
//!
//! Storage is a `u64` word array, which guarantees 8-byte alignment — every
//! element type the [`crate::state`] layer supports (f32/f64/i64/u8) can be
//! viewed directly over these bytes without realignment copies.
//!
//! [`SharedBuf::view`] extends the same economics to *sub-ranges*: a
//! scatter root slicing row chunks out of a matrix hands each rank a
//! window of the one parent allocation (a reference bump per chunk)
//! instead of a copied byte range, and copy-on-write still isolates any
//! later writer.
//!
//! [`TokenBuf`] is the companion type for the replica rendezvous channels
//! ([`crate::replica::pair::PairSync`]): small control tokens stay owned
//! `Vec<u8>`s, full-payload comparison tokens cross as `SharedBuf` views —
//! which is what makes full-contents message validation copy-free on the
//! send path.

use std::sync::Arc;

use super::arena;

/// Shared, immutable, 8-byte-aligned byte buffer with O(1) clone and
/// copy-on-write mutation.
///
/// Word storage comes from the per-thread pooled-world arena
/// ([`crate::util::arena`]): constructors recycle a free buffer of the
/// right shape when one exists, and dropping the **last** reference gives
/// the words back to the dropping thread's pool — so a campaign worker
/// rebuilding world after world of identical geometry stops churning the
/// global allocator. The partial tail beyond `len` of a recycled buffer may
/// hold stale words; no API exposes bytes past `len`, so they are
/// unobservable (see `recycled_storage_is_unobservable` below).
pub struct SharedBuf {
    /// Word storage; the last word may be partially used. `Arc<Vec<u64>>`
    /// rather than `Arc<[u64]>` so the final holder can take the `Vec` back
    /// out and recycle it through the arena.
    words: Arc<Vec<u64>>,
    /// Byte offset of this buffer's window into the word storage — 0 for
    /// whole-allocation buffers, nonzero for [`SharedBuf::view`]s.
    off: usize,
    /// Valid byte length (`off + len <= words.len() * 8`).
    len: usize,
}

impl SharedBuf {
    /// An empty buffer (no allocation shared with anything).
    pub fn empty() -> SharedBuf {
        SharedBuf {
            words: Arc::new(Vec::new()),
            off: 0,
            len: 0,
        }
    }

    /// Copy `bytes` into a word-aligned shared allocation (recycled from
    /// the thread's arena when an identical-shape buffer is free).
    pub fn from_bytes(bytes: &[u8]) -> SharedBuf {
        let mut words = arena::take_words(bytes.len().div_ceil(8));
        if !bytes.is_empty() {
            // Safety: the destination spans ceil(len/8) words >= len bytes,
            // and u8 writes have no alignment requirement.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    words.as_mut_ptr().cast::<u8>(),
                    bytes.len(),
                );
            }
        }
        SharedBuf {
            words: Arc::new(words),
            off: 0,
            len: bytes.len(),
        }
    }

    /// A zero-filled buffer of `len` bytes.
    pub fn zeroed(len: usize) -> SharedBuf {
        let mut words = arena::take_words(len.div_ceil(8));
        // A recycled buffer carries stale words; `zeroed` promises zeros
        // over the full visible length.
        words.fill(0);
        SharedBuf {
            words: Arc::new(words),
            off: 0,
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Immutable byte view. The storage base is 8-byte aligned by
    /// construction (word storage), so the returned pointer is aligned to
    /// `8.gcd(off)` — whole buffers (`off == 0`) support every element
    /// width, and the typed layer ([`crate::state::Buf::view`]) only ever
    /// creates element-multiple offsets.
    pub fn as_bytes(&self) -> &[u8] {
        // Safety: the words allocation holds at least `off + len`
        // initialized bytes (asserted at view construction); `off` is at
        // most one past the end for empty windows; u8 has no alignment
        // requirement.
        unsafe {
            std::slice::from_raw_parts(self.words.as_ptr().cast::<u8>().add(self.off), self.len)
        }
    }

    /// A zero-copy sub-range view: shares this buffer's allocation and
    /// windows `offset..offset + len` of its visible bytes. Costs one
    /// reference bump — no payload bytes move. Mutation through a view
    /// ([`SharedBuf::make_mut`]) always detaches into a private copy
    /// first, so a write can never reach the parent or sibling views.
    ///
    /// Panics if the range runs past the buffer (caller bug — the typed
    /// layer bounds-checks in element units first).
    pub fn view(&self, offset: usize, len: usize) -> SharedBuf {
        assert!(
            offset.checked_add(len).is_some_and(|end| end <= self.len),
            "view {offset}..{} exceeds {} byte buffer",
            offset.saturating_add(len),
            self.len
        );
        SharedBuf {
            words: Arc::clone(&self.words),
            off: self.off + offset,
            len,
        }
    }

    /// Mutable byte view, copy-on-write: in place when this is the only
    /// reference to a whole allocation, otherwise the visible window is
    /// copied into a private allocation first (other holders keep seeing
    /// the old bytes). A view (`off != 0`) always detaches — even a
    /// "unique" one still aliases whatever windows the parent handed out.
    pub fn make_mut(&mut self) -> &mut [u8] {
        if self.off != 0 || Arc::get_mut(&mut self.words).is_none() {
            let mut copy = arena::take_words(self.len.div_ceil(8));
            if self.len != 0 {
                // Safety: source is `len` initialized bytes; destination
                // spans ceil(len/8) words >= len bytes; the allocations are
                // distinct, so the ranges cannot overlap.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        self.as_bytes().as_ptr(),
                        copy.as_mut_ptr().cast::<u8>(),
                        self.len,
                    );
                }
            }
            self.words = Arc::new(copy);
            self.off = 0;
        }
        let words = Arc::get_mut(&mut self.words).expect("unique after copy-on-write");
        // Safety: as for `as_bytes`, plus exclusive access via `get_mut`.
        unsafe { std::slice::from_raw_parts_mut(words.as_mut_ptr().cast::<u8>(), self.len) }
    }

    /// Do two buffers share one allocation? (The observability hook the
    /// zero-copy tests assert on.)
    pub fn ptr_eq(a: &SharedBuf, b: &SharedBuf) -> bool {
        Arc::ptr_eq(&a.words, &b.words)
    }

    /// Number of live references to the allocation.
    pub fn refcount(&self) -> usize {
        Arc::strong_count(&self.words)
    }
}

impl Clone for SharedBuf {
    /// O(1): bumps the reference count; no bytes move.
    fn clone(&self) -> SharedBuf {
        SharedBuf {
            words: Arc::clone(&self.words),
            off: self.off,
            len: self.len,
        }
    }
}

impl Drop for SharedBuf {
    /// The last holder recycles the word storage into the dropping
    /// thread's arena — the pooled-world reclaim point. A still-shared
    /// buffer (any other live clone) is left untouched; `Arc::get_mut`
    /// is the uniqueness test (strong == 1, no weak refs exist here).
    fn drop(&mut self) {
        if let Some(words) = Arc::get_mut(&mut self.words) {
            arena::give_words(std::mem::take(words));
        }
    }
}

impl PartialEq for SharedBuf {
    fn eq(&self, other: &SharedBuf) -> bool {
        // The ptr_eq fast path needs matching offsets: two views of one
        // allocation window different bytes.
        self.len == other.len
            && ((SharedBuf::ptr_eq(self, other) && self.off == other.off)
                || self.as_bytes() == other.as_bytes())
    }
}

impl Eq for SharedBuf {}

impl std::fmt::Debug for SharedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SharedBuf({} B, rc {})", self.len, self.refcount())
    }
}

/// A token crossing a replica rendezvous channel: either a small owned
/// control blob or a zero-copy view of a shared payload.
#[derive(Debug, Clone)]
pub enum TokenBuf {
    /// Owned bytes (control tokens, digests, encoded vars).
    Owned(Vec<u8>),
    /// A shared view — pushing one across the channel moves a reference,
    /// never the payload bytes.
    Shared(SharedBuf),
}

impl TokenBuf {
    pub fn as_bytes(&self) -> &[u8] {
        match self {
            TokenBuf::Owned(v) => v,
            TokenBuf::Shared(s) => s.as_bytes(),
        }
    }

    pub fn len(&self) -> usize {
        self.as_bytes().len()
    }

    pub fn is_empty(&self) -> bool {
        self.as_bytes().is_empty()
    }
}

impl From<Vec<u8>> for TokenBuf {
    fn from(v: Vec<u8>) -> TokenBuf {
        TokenBuf::Owned(v)
    }
}

impl From<SharedBuf> for TokenBuf {
    fn from(s: SharedBuf) -> TokenBuf {
        TokenBuf::Shared(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_alignment() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let src: Vec<u8> = (0..n).map(|i| (i * 7 + 3) as u8).collect();
            let b = SharedBuf::from_bytes(&src);
            assert_eq!(b.len(), n);
            assert_eq!(b.as_bytes(), &src[..]);
            assert_eq!(b.as_bytes().as_ptr() as usize % 8, 0, "len {n} misaligned");
        }
    }

    #[test]
    fn clone_shares_allocation() {
        let a = SharedBuf::from_bytes(&[1, 2, 3, 4, 5]);
        let b = a.clone();
        assert!(SharedBuf::ptr_eq(&a, &b));
        assert_eq!(a.refcount(), 2);
        assert_eq!(a, b);
    }

    #[test]
    fn cow_preserves_other_holders() {
        let mut a = SharedBuf::from_bytes(&[10, 20, 30]);
        let b = a.clone();
        a.make_mut()[1] = 99;
        assert_eq!(a.as_bytes(), &[10, 99, 30]);
        assert_eq!(b.as_bytes(), &[10, 20, 30], "shared holder must see old bytes");
        assert!(!SharedBuf::ptr_eq(&a, &b), "write must have detached the copy");
    }

    #[test]
    fn unique_mutation_is_in_place() {
        let mut a = SharedBuf::from_bytes(&[1, 2, 3]);
        let before = a.as_bytes().as_ptr();
        a.make_mut()[0] = 9;
        assert_eq!(a.as_bytes().as_ptr(), before, "unique write must not reallocate");
        assert_eq!(a.as_bytes(), &[9, 2, 3]);
    }

    #[test]
    fn equality_is_by_contents() {
        let a = SharedBuf::from_bytes(b"same");
        let b = SharedBuf::from_bytes(b"same");
        assert!(!SharedBuf::ptr_eq(&a, &b));
        assert_eq!(a, b);
        assert_ne!(a, SharedBuf::from_bytes(b"diff"));
        assert_ne!(a, SharedBuf::from_bytes(b"sam"));
    }

    #[test]
    fn empty_and_zeroed() {
        let e = SharedBuf::empty();
        assert!(e.is_empty());
        assert_eq!(e.as_bytes(), &[] as &[u8]);
        let z = SharedBuf::zeroed(17);
        assert_eq!(z.as_bytes(), &[0u8; 17][..]);
    }

    #[test]
    fn recycled_storage_is_unobservable() {
        // Fill the thread pool with a poisoned buffer, then build a shorter
        // buffer that straddles a word boundary: the visible bytes must be
        // exactly the constructor's, stale tail words notwithstanding.
        crate::util::arena::reset_for_tests();
        drop(SharedBuf::from_bytes(&[0xAAu8; 64]));
        let src: Vec<u8> = (0..13u8).collect();
        let b = SharedBuf::from_bytes(&src);
        assert_eq!(b.as_bytes(), &src[..]);
        assert_eq!(b, SharedBuf::from_bytes(&src));
        // `zeroed` must re-zero a recycled buffer over its whole length.
        drop(SharedBuf::from_bytes(&[0xFFu8; 64]));
        let z = SharedBuf::zeroed(33);
        assert_eq!(z.as_bytes(), &[0u8; 33][..]);
        // COW of a recycled-storage buffer keeps both views correct.
        let mut c = b.clone();
        c.make_mut()[0] = 99;
        assert_eq!(b.as_bytes()[0], 0);
        assert_eq!(c.as_bytes()[0], 99);
    }

    #[test]
    fn drop_of_last_reference_recycles() {
        use crate::util::arena;
        // Order-independence: earlier tests on this thread (single-threaded
        // libtest runs share one pool) must not pre-fill or exhaust it.
        arena::reset_for_tests();
        let src = vec![2u8; 777];
        drop(SharedBuf::from_bytes(&src));
        let (h0, _) = arena::stats();
        let again = SharedBuf::from_bytes(&src);
        let (h1, _) = arena::stats();
        assert!(h1 > h0, "same-shape rebuild must reuse the dropped words");
        // A *shared* buffer's drop must not recycle (the clone lives on).
        let keep = again.clone();
        drop(again);
        assert_eq!(keep.as_bytes(), &src[..]);
        assert_eq!(keep.refcount(), 1);
    }

    #[test]
    fn views_share_the_allocation_and_window_the_bytes() {
        let parent = SharedBuf::from_bytes(&(0..32u8).collect::<Vec<_>>());
        let v = parent.view(8, 12);
        assert!(SharedBuf::ptr_eq(&parent, &v));
        assert_eq!(v.len(), 12);
        assert_eq!(v.as_bytes(), &(8..20u8).collect::<Vec<_>>()[..]);
        // A view of a view composes offsets into the one allocation.
        let vv = v.view(4, 4);
        assert!(SharedBuf::ptr_eq(&parent, &vv));
        assert_eq!(vv.as_bytes(), &[12, 13, 14, 15]);
        // Same allocation, different windows: equality is by contents.
        assert_ne!(v, vv);
        assert_eq!(vv, SharedBuf::from_bytes(&[12, 13, 14, 15]));
        // Zero-length windows are fine, including one at the very end.
        assert!(parent.view(32, 0).is_empty());
    }

    #[test]
    fn view_mutation_detaches_and_never_touches_the_parent() {
        let parent = SharedBuf::from_bytes(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let mut v = parent.view(2, 4);
        v.make_mut()[0] = 99;
        assert!(!SharedBuf::ptr_eq(&parent, &v), "write must detach the view");
        assert_eq!(v.as_bytes(), &[99, 4, 5, 6]);
        assert_eq!(parent.as_bytes(), &[1, 2, 3, 4, 5, 6, 7, 8]);
        // Even a view holding the LAST reference detaches: in-place writes
        // at off != 0 would corrupt the window arithmetic.
        let mut only = SharedBuf::from_bytes(&[10, 11, 12]).view(1, 2);
        only.make_mut()[1] = 77;
        assert_eq!(only.as_bytes(), &[11, 77]);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn view_past_the_end_panics() {
        let b = SharedBuf::from_bytes(&[0u8; 8]);
        let _ = b.view(4, 8);
    }

    #[test]
    fn token_buf_views() {
        let o = TokenBuf::from(vec![1u8, 2]);
        assert_eq!(o.as_bytes(), &[1, 2]);
        assert_eq!(o.len(), 2);
        let s = TokenBuf::from(SharedBuf::from_bytes(&[3u8; 40]));
        assert_eq!(s.as_bytes(), &[3u8; 40][..]);
        assert!(!s.is_empty());
    }
}
