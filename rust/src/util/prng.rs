//! SplitMix64 — a tiny, deterministic, seedable PRNG.
//!
//! Used by the property-testing framework, the workload generators and the
//! randomized injection campaigns. We deliberately avoid OS entropy: every
//! experiment in this repository must be reproducible from its seed.

/// SplitMix64 state. Passes BigCrush when used as a 64-bit generator; more
/// than adequate for test-data generation.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Multiply-shift rejection-free mapping (Lemire). The tiny bias is
        // irrelevant for test-data generation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[-1, 1)` — the element distribution used for the
    /// synthetic matrices/sequences.
    pub fn f32_signed(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a f32 buffer with signed-uniform values.
    pub fn fill_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.f32_signed();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn differs_across_seeds() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = SplitMix64::new(11);
        for _ in 0..1000 {
            let v = r.range(3, 17);
            assert!((3..17).contains(&v));
        }
    }
}
