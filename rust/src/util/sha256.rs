//! Hand-rolled SHA-256 (FIPS 180-4).
//!
//! The crate builds with zero external dependencies, so the digest used by
//! hash-based message validation and user-level checkpoint cross-validation
//! ([`crate::detect::ValidationMode::Sha256`], Algorithm 2's `hash(ckpt)`)
//! is implemented here. Exposed both as the one-shot [`sha256`] and as the
//! incremental [`Sha256`] state, which the single-pass checkpoint pipeline
//! ([`crate::util::codec::PassState`]) folds over payload bytes in the same
//! scan that encodes and CRC-checks them.

/// Initial hash values: first 32 bits of the fractional parts of the square
/// roots of the first 8 primes.
const H0: [u32; 8] = [
    0x6a09_e667, 0xbb67_ae85, 0x3c6e_f372, 0xa54f_f53a, 0x510e_527f, 0x9b05_688c, 0x1f83_d9ab,
    0x5be0_cd19,
];

/// Round constants: first 32 bits of the fractional parts of the cube roots
/// of the first 64 primes.
const K: [u32; 64] = [
    0x428a_2f98, 0x7137_4491, 0xb5c0_fbcf, 0xe9b5_dba5, 0x3956_c25b, 0x59f1_11f1, 0x923f_82a4,
    0xab1c_5ed5, 0xd807_aa98, 0x1283_5b01, 0x2431_85be, 0x550c_7dc3, 0x72be_5d74, 0x80de_b1fe,
    0x9bdc_06a7, 0xc19b_f174, 0xe49b_69c1, 0xefbe_4786, 0x0fc1_9dc6, 0x240c_a1cc, 0x2de9_2c6f,
    0x4a74_84aa, 0x5cb0_a9dc, 0x76f9_88da, 0x983e_5152, 0xa831_c66d, 0xb003_27c8, 0xbf59_7fc7,
    0xc6e0_0bf3, 0xd5a7_9147, 0x06ca_6351, 0x1429_2967, 0x27b7_0a85, 0x2e1b_2138, 0x4d2c_6dfc,
    0x5338_0d13, 0x650a_7354, 0x766a_0abb, 0x81c2_c92e, 0x9272_2c85, 0xa2bf_e8a1, 0xa81a_664b,
    0xc24b_8b70, 0xc76c_51a3, 0xd192_e819, 0xd699_0624, 0xf40e_3585, 0x106a_a070, 0x19a4_c116,
    0x1e37_6c08, 0x2748_774c, 0x34b0_bcb5, 0x391c_0cb3, 0x4ed8_aa4a, 0x5b9c_ca4f, 0x682e_6ff3,
    0x748f_82ee, 0x78a5_636f, 0x84c8_7814, 0x8cc7_0208, 0x90be_fffa, 0xa450_6ceb, 0xbef9_a3f7,
    0xc671_78f2,
];

/// Compress one 64-byte block into the running state.
fn compress(state: &mut [u32; 8], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut w = [0u32; 64];
    for (i, word) in w.iter_mut().take(16).enumerate() {
        *word = u32::from_be_bytes(block[i * 4..i * 4 + 4].try_into().unwrap());
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }

    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(big_s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = big_s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }

    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
    state[5] = state[5].wrapping_add(f);
    state[6] = state[6].wrapping_add(g);
    state[7] = state[7].wrapping_add(h);
}

/// Incremental SHA-256: feed bytes in any chunking, finalize once. The
/// digest is identical to [`sha256`] over the concatenation.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Unprocessed sub-block bytes carried between `update` calls.
    tail: [u8; 64],
    tail_len: usize,
    /// Total message bytes absorbed so far.
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 {
            state: H0,
            tail: [0u8; 64],
            tail_len: 0,
            total: 0,
        }
    }

    /// Absorb `bytes` (any chunk size, including empty).
    pub fn update(&mut self, mut bytes: &[u8]) {
        self.total = self.total.wrapping_add(bytes.len() as u64);
        // Top up a carried partial block first.
        if self.tail_len > 0 {
            let take = (64 - self.tail_len).min(bytes.len());
            self.tail[self.tail_len..self.tail_len + take].copy_from_slice(&bytes[..take]);
            self.tail_len += take;
            bytes = &bytes[take..];
            if self.tail_len < 64 {
                return;
            }
            let block = self.tail;
            compress(&mut self.state, &block);
            self.tail_len = 0;
        }
        let mut chunks = bytes.chunks_exact(64);
        for block in &mut chunks {
            compress(&mut self.state, block);
        }
        let rem = chunks.remainder();
        self.tail[..rem.len()].copy_from_slice(rem);
        self.tail_len = rem.len();
    }

    /// Apply FIPS padding and return the digest.
    pub fn finalize(mut self) -> [u8; 32] {
        // Padding: 0x80, zeros, then the 64-bit big-endian *bit* length,
        // block-aligned. The tail spills into a second block when < 9 bytes
        // of the block remain.
        let bit_len = self.total.wrapping_mul(8);
        let rem_len = self.tail_len;
        let mut tail = [0u8; 128];
        tail[..rem_len].copy_from_slice(&self.tail[..rem_len]);
        tail[rem_len] = 0x80;
        let tail_blocks = if rem_len + 9 <= 64 { 1 } else { 2 };
        tail[tail_blocks * 64 - 8..tail_blocks * 64].copy_from_slice(&bit_len.to_be_bytes());
        for block in tail[..tail_blocks * 64].chunks_exact(64) {
            compress(&mut self.state, block);
        }

        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

/// SHA-256 digest of a complete buffer.
pub fn sha256(bytes: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(bytes);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    #[test]
    fn fips_vectors() {
        // FIPS 180-4 / NIST CAVS known answers.
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn padding_boundaries() {
        // Lengths around the 55/56/64-byte padding edges must all digest
        // without panicking and must all differ.
        let mut seen = std::collections::HashSet::new();
        for n in 0..=130usize {
            let buf = vec![0xA5u8; n];
            assert!(seen.insert(sha256(&buf)), "collision at length {n}");
        }
    }

    #[test]
    fn streaming_matches_one_shot_for_any_chunking() {
        // Every (length, split-pattern) combination around the 64-byte block
        // boundary must agree with the one-shot digest.
        let data: Vec<u8> = (0..300usize).map(|i| (i * 131 + 17) as u8).collect();
        for len in [0usize, 1, 55, 56, 63, 64, 65, 127, 128, 129, 300] {
            let want = sha256(&data[..len]);
            for step in [1usize, 3, 7, 63, 64, 65, 300] {
                let mut h = Sha256::new();
                for chunk in data[..len].chunks(step) {
                    h.update(chunk);
                }
                assert_eq!(h.finalize(), want, "len {len} step {step}");
            }
            // Interleaved empty updates must be no-ops.
            let mut h = Sha256::new();
            h.update(&[]);
            h.update(&data[..len]);
            h.update(&[]);
            assert_eq!(h.finalize(), want, "len {len} with empty updates");
        }
    }

    #[test]
    fn million_a() {
        // The classic third FIPS vector: one million 'a' bytes.
        let buf = vec![b'a'; 1_000_000];
        assert_eq!(
            hex(&sha256(&buf)),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }
}
