//! The pooled-world arena: a per-thread free list of word buffers.
//!
//! A campaign sweep builds and tears down over a thousand isolated worlds
//! (`64 scenarios × 3 apps × 3 strategies × 2 collectives modes = 1152`),
//! and every world's stores, payloads and rendezvous tokens are backed by
//! [`crate::util::bytes::SharedBuf`] word allocations of the **same few
//! shapes** — the campaign geometry is fixed, only the seeds differ. Before
//! this arena existed each world build churned those identical-shape
//! allocations through the global allocator; now a worker thread recycles
//! them across the worlds it runs, exactly like the thread-local LZSS
//! [`crate::util::codec::Matcher`] recycles its hash-chain arena across
//! checkpoint frames.
//!
//! Shape of the mechanism:
//!
//! * [`take_words`] hands out a `Vec<u64>` of the requested word length —
//!   best-fit from the thread's free list when possible (no allocation, no
//!   zeroing), freshly zero-allocated otherwise;
//! * [`give_words`] returns a buffer to the free list (bounded: at most
//!   [`MAX_POOLED`] buffers of at most [`MAX_POOL_WORDS`] words each, so an
//!   unusually large world can never pin unbounded memory on a worker);
//! * `SharedBuf`'s `Drop` calls [`give_words`] when it holds the **last**
//!   reference — so the recycle point needs no cooperation from any caller,
//!   and a buffer still shared (a zero-copy broadcast payload, say) is
//!   never touched.
//!
//! Worlds are built on the campaign worker thread ([`crate::campaign::
//! scheduler`]) and their stores come back to it at join time, so the pool
//! that served a world's construction is the one its teardown refills —
//! per-worker, no cross-thread traffic, no locks. Replica threads are
//! short-lived; whatever their own pools accumulate is freed with them.
//!
//! Recycled buffers are handed out **without re-zeroing**: a `SharedBuf`
//! only ever exposes `len` bytes, every constructor overwrites exactly
//! those bytes, and the slack tail beyond `len` is unreachable through any
//! API — so stale words are unobservable (asserted by the round-trip tests
//! in [`crate::util::bytes`]).

use std::cell::RefCell;

/// Most buffers a thread keeps pooled.
pub const MAX_POOLED: usize = 64;
/// Largest buffer (in words) the pool will retain — 1 MiB. Campaign-world
/// stores are far below this; anything bigger goes back to the allocator.
pub const MAX_POOL_WORDS: usize = (1 << 20) / 8;
/// Largest acceptable fit: a pooled buffer serves a request only when its
/// span is at most this factor above it. Without the bound, one small take
/// could consume (and pin, and on every give-back re-zero) the pool's
/// biggest buffer while large requests fall through to the allocator.
pub const MAX_FIT_FACTOR: usize = 4;

#[derive(Default)]
struct Pool {
    free: Vec<Vec<u64>>,
    hits: u64,
    misses: u64,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
}

/// A `Vec<u64>` of exactly `nwords` initialized words: recycled from this
/// thread's pool when a large-enough buffer is free (contents are stale —
/// see the module docs for why that is unobservable), freshly
/// zero-allocated otherwise.
pub fn take_words(nwords: usize) -> Vec<u64> {
    if nwords == 0 {
        return Vec::new();
    }
    // `try_with` so a drop running during thread teardown (after the pool's
    // own destructor) degrades to a plain allocation instead of panicking.
    POOL.try_with(|p| {
        let mut p = p.borrow_mut();
        let cap = nwords.saturating_mul(MAX_FIT_FACTOR);
        let mut best: Option<usize> = None;
        for (i, v) in p.free.iter().enumerate() {
            if v.len() >= nwords && v.len() <= cap {
                match best {
                    Some(b) if p.free[b].len() <= v.len() => {}
                    _ => best = Some(i),
                }
            }
        }
        match best {
            Some(i) => {
                p.hits += 1;
                let mut v = p.free.swap_remove(i);
                // Shrink to the requested length: the prefix is initialized
                // (it was the previous holder's live region or better).
                v.truncate(nwords);
                v
            }
            None => {
                p.misses += 1;
                vec![0u64; nwords]
            }
        }
    })
    .unwrap_or_else(|_| vec![0u64; nwords])
}

/// Return a buffer to this thread's pool (no-op for empty or oversized
/// buffers, or when the pool is full).
pub fn give_words(mut v: Vec<u64>) {
    // Pool the FULL allocated span, not the last holder's length: a
    // best-fit take may have truncated `len` below `capacity`, and pooling
    // by the truncated length would gradually shred large buffers into
    // small-looking entries that pin memory without ever serving a large
    // request again. `resize` to capacity never reallocates and only
    // zero-fills the never-initialized gap (a no-op when len == capacity),
    // and sizing the MAX_POOL_WORDS check by capacity bounds the memory
    // actually pinned.
    let full = v.capacity();
    if full == 0 || full > MAX_POOL_WORDS {
        return;
    }
    v.resize(full, 0);
    let _ = POOL.try_with(|p| {
        let mut p = p.borrow_mut();
        if p.free.len() < MAX_POOLED {
            p.free.push(v);
        }
    });
}

/// `(hits, misses)` of this thread's pool since thread start — the
/// observability hook the recycling tests assert on.
pub fn stats() -> (u64, u64) {
    POOL.try_with(|p| {
        let p = p.borrow();
        (p.hits, p.misses)
    })
    .unwrap_or((0, 0))
}

/// Test hook: clear this thread's pool and counters, so pool-sensitive
/// assertions hold whatever ran before them on this thread (under
/// `--test-threads=1` every lib test shares the main thread's pool).
#[cfg(test)]
pub(crate) fn reset_for_tests() {
    let _ = POOL.try_with(|p| *p.borrow_mut() = Pool::default());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_same_shape() {
        reset_for_tests();
        let (h0, _) = stats();
        let a = take_words(100);
        assert_eq!(a.len(), 100);
        give_words(a);
        let b = take_words(100);
        assert_eq!(b.len(), 100);
        let (h1, _) = stats();
        assert!(h1 > h0, "second same-shape take must hit the pool");
    }

    #[test]
    fn shrinks_larger_buffers_within_the_fit_bound() {
        reset_for_tests();
        give_words(vec![7u64; 64]);
        // Within MAX_FIT_FACTOR: reuse and shrink.
        let v = take_words(32);
        assert_eq!(v.len(), 32);
        give_words(v); // restored to its full 64-word span
        // Beyond the bound: a tiny request must NOT consume (pin, and
        // later re-zero) the big buffer — it misses instead.
        let (h0, _) = stats();
        let tiny = take_words(2);
        let (h1, _) = stats();
        assert_eq!(tiny.len(), 2);
        assert_eq!(h1, h0, "tiny take must miss rather than pin a big buffer");
    }

    #[test]
    fn small_take_does_not_shred_a_large_buffer() {
        // A pooled large buffer must survive interleaved small requests at
        // its FULL span: the small take misses (bounded fit), and a
        // truncated-then-returned buffer is re-pooled at capacity — so the
        // next large request still hits.
        reset_for_tests();
        give_words(vec![3u64; 4096]);
        let truncated = take_words(2048);
        assert_eq!(truncated.len(), 2048);
        give_words(truncated); // back at the full 4096-word span
        let small = take_words(1);
        assert_eq!(small.len(), 1);
        give_words(small);
        let (h0, _) = stats();
        let large = take_words(4096);
        let (h1, _) = stats();
        assert_eq!(large.len(), 4096);
        assert!(h1 > h0, "the re-given buffer must serve the large take");
    }

    #[test]
    fn zero_and_oversize_are_not_pooled() {
        reset_for_tests();
        give_words(Vec::new());
        let big = vec![0u64; MAX_POOL_WORDS + 1];
        give_words(big);
        let v = take_words(MAX_POOL_WORDS + 1);
        assert_eq!(v.len(), MAX_POOL_WORDS + 1);
        assert!(v.iter().all(|&w| w == 0), "oversize take must be fresh");
    }

    #[test]
    fn fresh_takes_are_zeroed() {
        reset_for_tests();
        let v = take_words(33);
        assert!(v.iter().all(|&w| w == 0));
    }
}
