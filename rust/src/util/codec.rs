//! Zero-dependency byte codecs shared by the on-disk frame formats:
//! CRC-32 (IEEE 802.3, the zlib/`crc32fast` convention) and a small
//! self-contained LZSS compressor.
//!
//! The LZSS stream is **not** RFC 1951 DEFLATE — frames written by this
//! crate are only ever read back by this crate, so the codec optimizes for
//! auditability over interoperability. Format: groups of up to 8 tokens,
//! each group led by one control byte whose bit *k* (LSB-first) marks token
//! *k* as a literal. A literal token is 1 raw byte; a match token is 3
//! bytes — `len - 3` (match lengths 3..=258) followed by a little-endian
//! u16 back-distance (1..=65535). The decoder stops exactly at the declared
//! uncompressed length, which the enclosing frame always carries.
//!
//! Two perf properties back the single-pass checkpoint pipeline
//! ([`crate::checkpoint::snapshot::encode_frame`]):
//!
//! * the [`Matcher`] hash-chain arena is allocated once per thread and
//!   recycled across frames (reset is an `O(window)` fill, not a fresh
//!   384 KiB allocation per call);
//! * a [`PassState`] can be folded over the input **in the same scan** that
//!   encodes it, so CRC-32 and (optionally) SHA-256 come out of one pass
//!   over memory instead of two or three.

use std::cell::RefCell;

use crate::util::sha256::Sha256;

const CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Fold `bytes` into a raw CRC-32 state (no init/xorout — streaming form).
pub fn crc32_feed(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state = CRC_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

/// CRC-32 of a buffer (IEEE polynomial, init/xorout `0xFFFF_FFFF`).
pub fn crc32(bytes: &[u8]) -> u32 {
    crc32_feed(0xFFFF_FFFF, bytes) ^ 0xFFFF_FFFF
}

/// Digest state folded over the encoder's single scan of the payload:
/// CRC-32 always, SHA-256 on request (the user-checkpoint path needs both;
/// system checkpoints and fleet WAL records need only the CRC).
pub struct PassState {
    crc: u32,
    sha: Option<Sha256>,
}

impl PassState {
    pub fn new(want_sha: bool) -> PassState {
        PassState {
            crc: 0xFFFF_FFFF,
            sha: if want_sha { Some(Sha256::new()) } else { None },
        }
    }

    /// Fold one span of payload bytes (called by the encoders while the
    /// span is still cache-hot from the encoding read).
    pub fn absorb(&mut self, bytes: &[u8]) {
        self.crc = crc32_feed(self.crc, bytes);
        if let Some(sha) = &mut self.sha {
            sha.update(bytes);
        }
    }

    /// Finalized CRC-32 of everything absorbed so far.
    pub fn crc32(&self) -> u32 {
        self.crc ^ 0xFFFF_FFFF
    }

    /// Finalized SHA-256 (if requested at construction).
    pub fn sha256(self) -> Option<[u8; 32]> {
        self.sha.map(|s| s.finalize())
    }
}

const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 258;
const MAX_DIST: usize = 65_535;
const HASH_BITS: u32 = 15;

fn hash3(data: &[u8], i: usize) -> usize {
    let k = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
    (k.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

const NIL: u32 = u32::MAX;

/// Reusable LZSS match-finding workspace. The hash-head and chain arrays
/// (~384 KiB) are allocated once and recycled across frames — the
/// checkpoint hot loop writes a frame per interval, and reallocating the
/// arena per call was measurable against the actual matching work.
pub struct Matcher {
    head: Vec<u32>,
    prev: Vec<u32>,
}

impl Default for Matcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Matcher {
    pub fn new() -> Matcher {
        Matcher {
            head: vec![NIL; 1 << HASH_BITS],
            prev: vec![NIL; 1 << 16],
        }
    }

    /// Clear the chains so the next frame sees exactly the state a fresh
    /// arena would — output stays byte-identical to a cold matcher.
    fn reset(&mut self) {
        self.head.fill(NIL);
        self.prev.fill(NIL);
    }

    /// LZSS-compress `data` into `out`. `level` (clamped to 1..=9) scales
    /// how many match candidates are examined per position; the format is
    /// level-independent. When `pass` is given, its digests are folded over
    /// the input in the same scan (the single-pass frame pipeline).
    pub fn compress_into(
        &mut self,
        data: &[u8],
        level: u32,
        out: &mut Vec<u8>,
        mut pass: Option<&mut PassState>,
    ) {
        self.reset();
        let tries = level.clamp(1, 9) as usize * 8;
        out.reserve(data.len() / 2 + 16);
        // Chained hash over 3-byte prefixes. The prev links live in a 64 KiB
        // ring (zlib-style): distances beyond MAX_DIST are unusable anyway,
        // so the chain memory is O(window), not O(payload). Ring aliasing
        // can surface a stale candidate; the strictly-descending check below
        // drops the chain at that point (a missed match costs ratio, never
        // correctness — every candidate is byte-verified). Positions are
        // u32: beyond 4 GiB the matcher switches off and bytes pass through
        // as literals (still a valid stream).
        let matchable = data.len() < NIL as usize;
        let head = &mut self.head;
        let prev = &mut self.prev;

        let mut flags = 0u8;
        let mut ntok = 0u32;
        let mut group: Vec<u8> = Vec::with_capacity(8 * 3);
        // Digest spans are folded in ≥16 KiB chunks (still cache-resident
        // from the match scan), not per token — literal-heavy input would
        // otherwise pay a crc/sha call per byte.
        const DIGEST_SPAN: usize = 16 * 1024;
        let mut digested = 0usize;

        let mut i = 0;
        while i < data.len() {
            let mut best_len = 0usize;
            let mut best_dist = 0usize;
            if matchable && i + MIN_MATCH <= data.len() {
                let mut cand = head[hash3(data, i)];
                let mut examined = 0;
                while cand != NIL && examined < tries {
                    let c = cand as usize;
                    if c >= i || i - c > MAX_DIST {
                        break;
                    }
                    let limit = (data.len() - i).min(MAX_MATCH);
                    let mut l = 0;
                    while l < limit && data[c + l] == data[i + l] {
                        l += 1;
                    }
                    if l > best_len {
                        best_len = l;
                        best_dist = i - c;
                        if l == limit {
                            break;
                        }
                    }
                    let next = prev[c & 0xFFFF];
                    if next == NIL || next as usize >= c {
                        break;
                    }
                    cand = next;
                    examined += 1;
                }
            }

            let step = if best_len >= MIN_MATCH {
                group.push((best_len - MIN_MATCH) as u8);
                group.extend_from_slice(&(best_dist as u16).to_le_bytes());
                best_len
            } else {
                flags |= 1 << ntok;
                group.push(data[i]);
                1
            };
            ntok += 1;
            if ntok == 8 {
                out.push(flags);
                out.extend_from_slice(&group);
                flags = 0;
                ntok = 0;
                group.clear();
            }

            // Enter every position the token covered into the hash chains.
            let end = i + step;
            while i < end {
                if matchable && i + MIN_MATCH <= data.len() {
                    let h = hash3(data, i);
                    prev[i & 0xFFFF] = head[h];
                    head[h] = i as u32;
                }
                i += 1;
            }

            // Fold the digests over the accumulated span once it is large
            // enough to amortize the call.
            if i - digested >= DIGEST_SPAN {
                if let Some(p) = &mut pass {
                    p.absorb(&data[digested..i]);
                }
                digested = i;
            }
        }
        if ntok > 0 {
            out.push(flags);
            out.extend_from_slice(&group);
        }
        if let Some(p) = &mut pass {
            p.absorb(&data[digested..]);
        }
    }
}

thread_local! {
    /// Per-thread matcher arena shared by every frame this thread encodes.
    static TL_MATCHER: RefCell<Matcher> = RefCell::new(Matcher::new());
}

/// LZSS-compress `data` (thread-local arena; see [`Matcher`]).
pub fn compress(data: &[u8], level: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    TL_MATCHER.with(|m| m.borrow_mut().compress_into(data, level, &mut out, None));
    out
}

/// LZSS-compress into `out` while folding `pass` digests over the input in
/// the same scan (thread-local arena).
pub fn compress_fused(data: &[u8], level: u32, out: &mut Vec<u8>, pass: &mut PassState) {
    TL_MATCHER.with(|m| m.borrow_mut().compress_into(data, level, out, Some(pass)));
}

/// Stream `data` into `out` uncompressed while folding `pass` digests —
/// the `Codec::Raw` arm of the single-pass frame writer. Chunked so every
/// block is digested while still cache-hot from the copy.
pub fn copy_fused(data: &[u8], out: &mut Vec<u8>, pass: &mut PassState) {
    out.reserve(data.len());
    for chunk in data.chunks(64 * 1024) {
        pass.absorb(chunk);
        out.extend_from_slice(chunk);
    }
}

/// Decompress an LZSS stream produced by [`compress`] into exactly
/// `expected_len` bytes. Any malformation (truncation, bad back-reference,
/// overrun of the declared length) is an error, never a panic — corrupt
/// frames must surface as recoverable failures.
pub fn decompress(data: &[u8], expected_len: usize) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(expected_len);
    let mut i = 0;
    while out.len() < expected_len {
        if i >= data.len() {
            return Err("compressed stream truncated".into());
        }
        let flags = data[i];
        i += 1;
        let mut bit = 0;
        while bit < 8 && out.len() < expected_len {
            if (flags >> bit) & 1 == 1 {
                if i >= data.len() {
                    return Err("compressed stream truncated in literal".into());
                }
                out.push(data[i]);
                i += 1;
            } else {
                if i + 3 > data.len() {
                    return Err("compressed stream truncated in match".into());
                }
                let len = data[i] as usize + MIN_MATCH;
                let dist = u16::from_le_bytes([data[i + 1], data[i + 2]]) as usize;
                i += 3;
                if dist == 0 || dist > out.len() {
                    return Err(format!(
                        "bad back-reference (distance {dist} at output offset {})",
                        out.len()
                    ));
                }
                if out.len() + len > expected_len {
                    return Err("compressed stream overruns declared length".into());
                }
                let start = out.len() - dist;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
            bit += 1;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::SplitMix64;

    #[test]
    fn crc32_check_value() {
        // The standard CRC-32/IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_assorted() {
        // The corpus ends with incompressible random bytes — those must
        // round-trip too.
        for payload in assorted_corpus() {
            for level in [1, 6, 9] {
                let packed = compress(&payload, level);
                let back = decompress(&packed, payload.len()).unwrap();
                assert_eq!(back, payload, "level {level}, len {}", payload.len());
            }
        }
    }

    #[test]
    fn repetitive_data_shrinks() {
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let packed = compress(&payload, 1);
        assert!(
            packed.len() < payload.len() / 10,
            "expected >10x on periodic data, got {} -> {}",
            payload.len(),
            packed.len()
        );
    }

    /// The corpus `roundtrip_assorted` sweeps, reused by the streaming-sink
    /// equivalence tests below.
    fn assorted_corpus() -> Vec<Vec<u8>> {
        let mut rng = SplitMix64::new(7);
        let mut cases: Vec<Vec<u8>> = vec![
            vec![],
            b"a".to_vec(),
            b"ab".to_vec(),
            b"abcabcabcabcabc".to_vec(),
            (0..100_000u32).map(|i| (i % 251) as u8).collect(),
            vec![0u8; 70_000],
        ];
        cases.push((0..10_000).map(|_| rng.next_u64() as u8).collect());
        cases
    }

    #[test]
    fn crc32_feed_is_chunking_invariant() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 31) as u8).collect();
        for step in [1usize, 7, 64, 4096, 10_000] {
            let mut state = 0xFFFF_FFFFu32;
            for chunk in data.chunks(step) {
                state = crc32_feed(state, chunk);
            }
            assert_eq!(state ^ 0xFFFF_FFFF, crc32(&data), "step {step}");
        }
    }

    #[test]
    fn streaming_sink_matches_one_shot_compress() {
        // A dedicated (reused!) matcher, with digest fusion on, must emit
        // byte-identical streams to the one-shot API — on every corpus
        // entry, across levels, without resetting between payloads by hand.
        let mut m = Matcher::new();
        for payload in assorted_corpus() {
            for level in [1, 6, 9] {
                let mut out = Vec::new();
                let mut pass = PassState::new(true);
                m.compress_into(&payload, level, &mut out, Some(&mut pass));
                assert_eq!(
                    out,
                    compress(&payload, level),
                    "stream/one-shot divergence at level {level}, len {}",
                    payload.len()
                );
                // Fused digests must equal the standalone ones.
                assert_eq!(pass.crc32(), crc32(&payload));
                assert_eq!(
                    pass.sha256().unwrap(),
                    crate::util::sha256::sha256(&payload)
                );
                // And the stream still round-trips.
                assert_eq!(decompress(&out, payload.len()).unwrap(), payload);
            }
        }
    }

    #[test]
    fn copy_fused_digests_match() {
        for payload in assorted_corpus() {
            let mut out = Vec::new();
            let mut pass = PassState::new(true);
            copy_fused(&payload, &mut out, &mut pass);
            assert_eq!(out, payload);
            assert_eq!(pass.crc32(), crc32(&payload));
            assert_eq!(pass.sha256().unwrap(), crate::util::sha256::sha256(&payload));
        }
    }

    #[test]
    fn pass_state_without_sha_is_crc_only() {
        let mut pass = PassState::new(false);
        pass.absorb(b"123456789");
        assert_eq!(pass.crc32(), 0xCBF4_3926);
        assert!(pass.sha256().is_none());
    }

    #[test]
    fn decompress_rejects_malformed() {
        let payload = b"the quick brown fox jumps over the lazy dog".to_vec();
        let packed = compress(&payload, 6);
        // Truncated stream.
        assert!(decompress(&packed[..packed.len() / 2], payload.len()).is_err());
        // Garbage: a match token with distance 0xFFFF into an empty window.
        assert!(decompress(&[0x00, 10, 0xFF, 0xFF], 64).is_err());
        // Empty input with nonzero expectation.
        assert!(decompress(&[], 1).is_err());
        // A declared length shorter than the stream produces is fine for the
        // decoder (it stops exactly at expected_len)...
        assert!(decompress(&packed, 5).is_ok());
        // ...and the frame-level length/CRC checks above this layer catch it.
    }
}
