//! Minimal leveled stderr logger.
//!
//! We keep our own ~60-line logger rather than pulling a logging facade: the
//! offline crate set has no emitter, and the coordinator's event *trace* (the
//! Figure-3-style experiment log) is handled separately by
//! [`crate::coordinator::trace`].

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Set the global verbosity (e.g. from `--verbose` on the CLI).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// True if `lvl` messages are currently emitted.
pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[sedar {tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Debug);
        set_level(Level::Info);
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Warn);
    }
}
