//! Collective operations composed from point-to-point messages.
//!
//! Deterministic rank-ascending order everywhere: determinism is a SEDAR
//! prerequisite (replicated executions must be bit-identical, §3.1). The tag
//! space above [`COLLECTIVE_TAG_BASE`] is reserved for these internals; user
//! code must use tags below it.
//!
//! Because every collective is composed from [`Endpoint::send`] /
//! [`Endpoint::recv`], an installed
//! [`FaultLayer`](crate::faultnet::FaultLayer) perturbs collective
//! internals exactly like user point-to-point traffic: a dropped
//! scatter chunk stalls that rank's receive (timeout/poison, never a
//! hang — see `rust/tests/faultnet.rs`), a corrupted broadcast payload
//! trips the transport CRC on take.

use crate::error::{Result, SedarError};
use crate::state::{Buf, Var};

use super::Endpoint;

/// First tag reserved for collective internals.
pub const COLLECTIVE_TAG_BASE: u32 = 1 << 16;

const TAG_BARRIER_IN: u32 = COLLECTIVE_TAG_BASE;
const TAG_BARRIER_OUT: u32 = COLLECTIVE_TAG_BASE + 1;
const TAG_SCATTER: u32 = COLLECTIVE_TAG_BASE + 2;
const TAG_BCAST: u32 = COLLECTIVE_TAG_BASE + 3;
const TAG_GATHER: u32 = COLLECTIVE_TAG_BASE + 4;
const TAG_REDUCE: u32 = COLLECTIVE_TAG_BASE + 5;
const TAG_ALLREDUCE_OUT: u32 = COLLECTIVE_TAG_BASE + 6;

fn token() -> Var {
    Var {
        shape: vec![],
        buf: Buf::u8(&[0]),
    }
}

impl Endpoint {
    /// Dissemination-free centralized barrier: everyone checks in with the
    /// root, the root releases everyone. O(n) messages, deterministic.
    pub fn barrier(&self, root: usize) -> Result<()> {
        if self.rank() == root {
            for r in 0..self.nranks() {
                if r != root {
                    self.recv(r, TAG_BARRIER_IN)?;
                }
            }
            for r in 0..self.nranks() {
                if r != root {
                    self.send(r, TAG_BARRIER_OUT, token())?;
                }
            }
        } else {
            self.send(root, TAG_BARRIER_IN, token())?;
            self.recv(root, TAG_BARRIER_OUT)?;
        }
        Ok(())
    }

    /// Scatter: root holds `chunks` (one per rank, including itself) and
    /// every rank returns its own chunk.
    pub fn scatter(&self, root: usize, chunks: Option<Vec<Var>>) -> Result<Var> {
        if self.rank() == root {
            let chunks = chunks.ok_or_else(|| {
                SedarError::Vmpi("scatter root must supply chunks".into())
            })?;
            if chunks.len() != self.nranks() {
                return Err(SedarError::Vmpi(format!(
                    "scatter needs {} chunks, got {}",
                    self.nranks(),
                    chunks.len()
                )));
            }
            let mut own = None;
            for (r, chunk) in chunks.into_iter().enumerate() {
                if r == root {
                    own = Some(chunk);
                } else {
                    self.send(r, TAG_SCATTER, chunk)?;
                }
            }
            Ok(own.unwrap())
        } else {
            self.recv(root, TAG_SCATTER)
        }
    }

    /// Broadcast from root. Root passes `Some(var)`, others `None`.
    ///
    /// Zero-copy fan-out: payload buffers are shared
    /// ([`crate::util::bytes::SharedBuf`]-backed), so the per-destination
    /// `var.clone()` is a reference-count bump — one allocation serves the
    /// root and every receiver, whatever the world size (asserted by
    /// `bcast_shares_one_allocation` below).
    pub fn bcast(&self, root: usize, var: Option<Var>) -> Result<Var> {
        if self.rank() == root {
            let var =
                var.ok_or_else(|| SedarError::Vmpi("bcast root must supply var".into()))?;
            for r in 0..self.nranks() {
                if r != root {
                    self.send(r, TAG_BCAST, var.clone())?;
                }
            }
            Ok(var)
        } else {
            self.recv(root, TAG_BCAST)
        }
    }

    /// Gather every rank's `var` at root (rank-ascending order, root's own
    /// contribution in place). Non-roots get `None`.
    pub fn gather(&self, root: usize, var: Var) -> Result<Option<Vec<Var>>> {
        if self.rank() == root {
            let mut out = Vec::with_capacity(self.nranks());
            for r in 0..self.nranks() {
                if r == root {
                    out.push(var.clone());
                } else {
                    out.push(self.recv(r, TAG_GATHER)?);
                }
            }
            Ok(Some(out))
        } else {
            self.send(root, TAG_GATHER, var)?;
            Ok(None)
        }
    }

    /// Sum-reduce f32 buffers at root (deterministic rank-ascending
    /// accumulation order). Non-roots get `None`.
    pub fn reduce_sum_f32(&self, root: usize, var: Var) -> Result<Option<Var>> {
        if self.rank() == root {
            let mut acc: Vec<f32> = var.buf.as_f32()?.to_vec();
            let shape = var.shape.clone();
            for r in 0..self.nranks() {
                if r == root {
                    continue;
                }
                let other = self.recv(r, TAG_REDUCE)?;
                let o = other.buf.as_f32()?;
                if o.len() != acc.len() {
                    return Err(SedarError::Vmpi(format!(
                        "reduce length mismatch: {} vs {}",
                        o.len(),
                        acc.len()
                    )));
                }
                for (a, b) in acc.iter_mut().zip(o) {
                    *a += *b;
                }
            }
            Ok(Some(Var::f32(&shape, acc)))
        } else {
            self.send(root, TAG_REDUCE, var)?;
            Ok(None)
        }
    }

    /// Allreduce = reduce at root + broadcast of the result. Like `bcast`,
    /// the result fan-out shares one allocation across all ranks.
    pub fn allreduce_sum_f32(&self, root: usize, var: Var) -> Result<Var> {
        let reduced = self.reduce_sum_f32(root, var)?;
        if self.rank() == root {
            let v = reduced.unwrap();
            for r in 0..self.nranks() {
                if r != root {
                    self.send(r, TAG_ALLREDUCE_OUT, v.clone())?;
                }
            }
            Ok(v)
        } else {
            self.recv(root, TAG_ALLREDUCE_OUT)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vmpi::Network;

    fn run_world<F>(n: usize, f: F)
    where
        F: Fn(Endpoint) + Send + Sync + 'static + Clone,
    {
        let net = Network::new(n);
        let mut handles = Vec::new();
        for r in 0..n {
            let ep = net.endpoint(r);
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(ep)));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn scatter_distributes_chunks() {
        run_world(4, |ep| {
            let chunks = if ep.rank() == 0 {
                Some(
                    (0..4)
                        .map(|i| Var::f32(&[2], vec![i as f32, i as f32 + 0.5]))
                        .collect(),
                )
            } else {
                None
            };
            let mine = ep.scatter(0, chunks).unwrap();
            let want = ep.rank() as f32;
            assert_eq!(mine.buf.as_f32().unwrap(), &[want, want + 0.5]);
        });
    }

    #[test]
    fn bcast_delivers_to_all() {
        run_world(4, |ep| {
            let var = (ep.rank() == 1).then(|| Var::f32(&[3], vec![7.0, 8.0, 9.0]));
            let got = ep.bcast(1, var).unwrap();
            assert_eq!(got.buf.as_f32().unwrap(), &[7.0, 8.0, 9.0]);
        });
    }

    #[test]
    fn gather_collects_in_rank_order() {
        run_world(4, |ep| {
            let mine = Var::f32(&[1], vec![ep.rank() as f32 * 10.0]);
            let all = ep.gather(0, mine).unwrap();
            if ep.rank() == 0 {
                let all = all.unwrap();
                for (r, v) in all.iter().enumerate() {
                    assert_eq!(v.buf.as_f32().unwrap(), &[r as f32 * 10.0]);
                }
            } else {
                assert!(all.is_none());
            }
        });
    }

    #[test]
    fn reduce_sums() {
        run_world(4, |ep| {
            let mine = Var::f32(&[2], vec![1.0, ep.rank() as f32]);
            let out = ep.reduce_sum_f32(0, mine).unwrap();
            if ep.rank() == 0 {
                assert_eq!(out.unwrap().buf.as_f32().unwrap(), &[4.0, 6.0]);
            }
        });
    }

    #[test]
    fn allreduce_everyone_gets_sum() {
        run_world(3, |ep| {
            let mine = Var::f32(&[1], vec![(ep.rank() + 1) as f32]);
            let out = ep.allreduce_sum_f32(0, mine).unwrap();
            assert_eq!(out.buf.as_f32().unwrap(), &[6.0]);
        });
    }

    #[test]
    fn bcast_shares_one_allocation() {
        use std::sync::{Arc, Mutex};
        let bufs: Arc<Mutex<Vec<(usize, crate::state::Buf)>>> = Arc::new(Mutex::new(Vec::new()));
        let net = Network::new(4);
        let mut handles = Vec::new();
        for r in 0..4 {
            let ep = net.endpoint(r);
            let bufs = Arc::clone(&bufs);
            handles.push(std::thread::spawn(move || {
                let var = (r == 0).then(|| Var::f32(&[256], vec![0.5; 256]));
                let got = ep.bcast(0, var).unwrap();
                bufs.lock().unwrap().push((r, got.buf));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let bufs = bufs.lock().unwrap();
        assert_eq!(bufs.len(), 4);
        let root = &bufs.iter().find(|(r, _)| *r == 0).unwrap().1;
        for (r, b) in bufs.iter() {
            assert!(
                b.shares_allocation(root),
                "rank {r} received a copy instead of the shared payload"
            );
        }
    }

    #[test]
    fn barrier_orders_effects() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let before = Arc::new(AtomicUsize::new(0));
        let net = Network::new(4);
        let mut handles = Vec::new();
        for r in 0..4 {
            let ep = net.endpoint(r);
            let before = Arc::clone(&before);
            handles.push(std::thread::spawn(move || {
                before.fetch_add(1, Ordering::SeqCst);
                ep.barrier(0).unwrap();
                // After the barrier, every rank must have incremented.
                assert_eq!(before.load(Ordering::SeqCst), 4);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
