//! `vmpi` — the in-process message-passing substrate.
//!
//! Stand-in for MPICH on the paper's Blade cluster (DESIGN.md §2): ranks are
//! OS threads inside one process; messages are typed [`crate::state::Var`]
//! payloads moved through per-rank mailboxes with blocking, FIFO-per-pair,
//! tag-matched semantics — exactly the subset of MPI semantics SEDAR's
//! mechanisms rely on. Payload buffers are shared and immutable
//! ([`crate::util::bytes::SharedBuf`]-backed), so a send moves a reference
//! through the mailbox, never the bytes, and collective fan-outs share one
//! allocation across every destination. Collectives (scatter/bcast/gather/reduce/barrier) are
//! built from point-to-point sends in deterministic rank order, mirroring
//! §4.2's note that the functional-validation implementation of SEDAR is
//! point-to-point based.
//!
//! All blocking goes through the world's [`Clock`]: a send publishes via
//! its destination mailbox's [`WaitPoint`] (a targeted wakeup — under a
//! wall clock only the destination rank's receiver is woken, and the send
//! hot path never takes a world-global lock), an abort broadcasts via
//! [`Clock::notify`], and a receive parks via the generation-capture wait
//! protocol on its own mailbox's point. Under a virtual clock the points
//! alias the world clock, which is what lets `recv_timeout` deadlines fire
//! in logical ticks the instant the world quiesces, instead of burning
//! real time.
//!
//! A network-wide **abort flag** implements SEDAR's safe-stop: when any rank
//! reports a fault, the coordinator calls [`Network::abort`] and every
//! blocked or future operation unwinds with [`SedarError::Aborted`], so all
//! replica threads can be joined promptly.

pub mod collectives;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{Result, SedarError};
use crate::state::Var;
use crate::util::clock::{Clock, Wait, WaitPoint};

/// A message in flight.
#[derive(Debug)]
pub struct Envelope {
    pub src: usize,
    pub tag: u32,
    pub payload: Var,
}

struct Mailbox {
    q: Mutex<VecDeque<Envelope>>,
    /// This mailbox's wakeup channel: senders notify it, the owning rank's
    /// receives park on it.
    wp: WaitPoint,
}

/// Byte / message accounting, kept per network (Table 3's communication
/// characterization draws from these).
#[derive(Debug, Default)]
pub struct NetStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

/// The in-process interconnect for one application instance (one "MPI
/// world"). A SEDAR run owns exactly one; the baseline strategy owns two.
pub struct Network {
    n: usize,
    boxes: Vec<Mailbox>,
    aborted: AtomicBool,
    clock: Clock,
    pub stats: NetStats,
}

impl Network {
    /// Wall-clock network (interactive/test default).
    pub fn new(nranks: usize) -> Arc<Network> {
        Self::with_clock(nranks, Clock::wall())
    }

    /// Network whose blocking operations route through `clock` — the
    /// coordinator passes the per-world clock here so every rank shares it.
    pub fn with_clock(nranks: usize, clock: Clock) -> Arc<Network> {
        assert!(nranks >= 1);
        Arc::new(Network {
            n: nranks,
            boxes: (0..nranks)
                .map(|_| Mailbox {
                    q: Mutex::new(VecDeque::new()),
                    wp: clock.wait_point(),
                })
                .collect(),
            aborted: AtomicBool::new(false),
            clock,
            stats: NetStats::default(),
        })
    }

    pub fn nranks(&self) -> usize {
        self.n
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Safe-stop: wake every blocked receiver with [`SedarError::Aborted`].
    pub fn abort(&self) {
        self.aborted.store(true, Ordering::SeqCst);
        self.clock.notify();
    }

    pub fn is_aborted(&self) -> bool {
        self.aborted.load(Ordering::SeqCst)
    }

    /// Obtain the endpoint for `rank`.
    pub fn endpoint(self: &Arc<Self>, rank: usize) -> Endpoint {
        assert!(rank < self.n, "rank {rank} out of range");
        Endpoint {
            rank,
            net: Arc::clone(self),
        }
    }
}

/// One rank's handle on the network.
#[derive(Clone)]
pub struct Endpoint {
    rank: usize,
    net: Arc<Network>,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nranks(&self) -> usize {
        self.net.n
    }

    pub fn network(&self) -> &Arc<Network> {
        &self.net
    }

    fn check_abort(&self) -> Result<()> {
        if self.net.is_aborted() {
            Err(SedarError::Aborted)
        } else {
            Ok(())
        }
    }

    /// Non-blocking buffered send (MPI eager mode).
    pub fn send(&self, dst: usize, tag: u32, payload: Var) -> Result<()> {
        self.check_abort()?;
        if dst >= self.net.n {
            return Err(SedarError::Vmpi(format!(
                "send to invalid rank {dst} (world size {})",
                self.net.n
            )));
        }
        let bytes = payload.buf.byte_len() as u64;
        let mbox = &self.net.boxes[dst];
        {
            let mut q = mbox.q.lock().unwrap();
            q.push_back(Envelope {
                src: self.rank,
                tag,
                payload,
            });
        }
        mbox.wp.notify();
        self.net.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.net.stats.bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Blocking receive matching `(src, tag)`; FIFO among matching messages.
    pub fn recv(&self, src: usize, tag: u32) -> Result<Var> {
        self.recv_inner(src, tag, None)
    }

    /// Blocking receive with a deadline (used by watchdog paths). The
    /// timeout is modeled time: ticks under a virtual clock, real time
    /// under a wall clock.
    pub fn recv_timeout(&self, src: usize, tag: u32, timeout: Duration) -> Result<Var> {
        self.recv_inner(src, tag, Some(timeout))
    }

    fn try_take(&self, src: usize, tag: u32) -> Result<Option<Var>> {
        let mut q = self.net.boxes[self.rank].q.lock().unwrap();
        if self.net.is_aborted() {
            return Err(SedarError::Aborted);
        }
        Ok(q
            .iter()
            .position(|e| e.src == src && e.tag == tag)
            .map(|pos| q.remove(pos).unwrap().payload))
    }

    fn recv_inner(&self, src: usize, tag: u32, timeout: Option<Duration>) -> Result<Var> {
        let wp = &self.net.boxes[self.rank].wp;
        let deadline = timeout.map(|t| self.net.clock.deadline_after(t));
        loop {
            // Generation first, queue check second: a send that lands after
            // the check has already bumped the generation, so the wait below
            // returns `Notified` instead of losing the wakeup.
            let gen = wp.subscribe();
            if let Some(v) = self.try_take(src, tag)? {
                return Ok(v);
            }
            match wp.wait(gen, deadline) {
                Wait::Notified => continue,
                Wait::TimedOut => {
                    // The deadline and a matching send can race; prefer the
                    // message, exactly like a real just-in-time arrival.
                    if let Some(v) = self.try_take(src, tag)? {
                        return Ok(v);
                    }
                    return Err(SedarError::Vmpi(format!(
                        "recv timeout waiting for src={src} tag={tag} at rank {}",
                        self.rank
                    )));
                }
                Wait::Poisoned => {
                    return Err(SedarError::Vmpi(format!(
                        "virtual-clock deadlock: all participants blocked with no \
                         pending deadline (recv src={src} tag={tag} at rank {})",
                        self.rank
                    )));
                }
            }
        }
    }

    /// Count of queued (unmatched) messages — used by tests.
    pub fn pending(&self) -> usize {
        self.net.boxes[self.rank].q.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::Var;

    fn v(data: &[f32]) -> Var {
        Var::f32(&[data.len()], data.to_vec())
    }

    #[test]
    fn send_recv_roundtrip() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, 7, v(&[1.0, 2.0])).unwrap();
        let got = b.recv(0, 7).unwrap();
        assert_eq!(got.buf.as_f32().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn tag_matching_skips_nonmatching() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, 1, v(&[1.0])).unwrap();
        a.send(1, 2, v(&[2.0])).unwrap();
        // Receive tag 2 first even though tag 1 arrived first.
        assert_eq!(b.recv(0, 2).unwrap().buf.as_f32().unwrap(), &[2.0]);
        assert_eq!(b.recv(0, 1).unwrap().buf.as_f32().unwrap(), &[1.0]);
    }

    #[test]
    fn fifo_within_same_src_tag() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        for i in 0..10 {
            a.send(1, 3, v(&[i as f32])).unwrap();
        }
        for i in 0..10 {
            assert_eq!(b.recv(0, 3).unwrap().buf.as_f32().unwrap(), &[i as f32]);
        }
    }

    #[test]
    fn cross_thread_blocking_recv() {
        // No ordering sleep needed: the receiver blocks until the sender's
        // clock notification, whichever thread runs first.
        let net = Network::new(2);
        let b = net.endpoint(1);
        let net2 = Arc::clone(&net);
        let h = std::thread::spawn(move || {
            net2.endpoint(0).send(1, 0, v(&[9.0])).unwrap();
        });
        let got = b.recv(0, 0).unwrap();
        assert_eq!(got.buf.as_f32().unwrap(), &[9.0]);
        h.join().unwrap();
    }

    #[test]
    fn abort_wakes_blocked_receiver() {
        // Either interleaving passes: abort-before-recv fails fast, recv-
        // before-abort is woken by the abort's clock notification.
        let net = Network::new(2);
        let b = net.endpoint(1);
        let net2 = Arc::clone(&net);
        let h = std::thread::spawn(move || {
            net2.abort();
        });
        let err = b.recv(0, 0).unwrap_err();
        assert!(matches!(err, SedarError::Aborted));
        h.join().unwrap();
    }

    #[test]
    fn recv_timeout_expires() {
        let net = Network::new(2);
        let b = net.endpoint(1);
        let err = b.recv_timeout(0, 0, Duration::from_millis(10)).unwrap_err();
        assert!(matches!(err, SedarError::Vmpi(_)));
    }

    #[test]
    fn recv_timeout_fires_instantly_under_virtual_clock() {
        let clock = Clock::virtual_clock();
        clock.join_n(1);
        let _g = clock.guard();
        let net = Network::with_clock(2, clock.clone());
        let b = net.endpoint(1);
        // An hour of modeled waiting elapses the moment the world quiesces.
        let err = b
            .recv_timeout(0, 0, Duration::from_secs(3600))
            .unwrap_err();
        assert!(matches!(err, SedarError::Vmpi(_)));
        assert!(clock.now() >= Clock::ticks(Duration::from_secs(3600)));
    }

    #[test]
    fn deadline_free_virtual_recv_poisons_instead_of_hanging() {
        let clock = Clock::virtual_clock();
        clock.join_n(1);
        let _g = clock.guard();
        let net = Network::with_clock(2, clock);
        let b = net.endpoint(1);
        let err = b.recv(0, 0).unwrap_err();
        match err {
            SedarError::Vmpi(msg) => assert!(msg.contains("deadlock"), "got: {msg}"),
            other => panic!("expected Vmpi deadlock error, got {other:?}"),
        }
    }

    #[test]
    fn send_to_invalid_rank_fails() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        assert!(a.send(5, 0, v(&[0.0])).is_err());
    }

    #[test]
    fn send_shares_payload_allocation() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let v = Var::f32(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        a.send(1, 9, v.clone()).unwrap();
        let got = b.recv(0, 9).unwrap();
        assert!(
            got.buf.shares_allocation(&v.buf),
            "transport must move a reference, not copy the payload"
        );
    }

    #[test]
    fn stats_account_bytes() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        a.send(1, 0, v(&[0.0; 16])).unwrap();
        assert_eq!(net.stats.messages.load(Ordering::Relaxed), 1);
        assert_eq!(net.stats.bytes.load(Ordering::Relaxed), 64);
    }
}
