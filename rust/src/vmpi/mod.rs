//! `vmpi` — the in-process message-passing substrate.
//!
//! Stand-in for MPICH on the paper's Blade cluster (DESIGN.md §2): ranks are
//! OS threads inside one process; messages are typed [`crate::state::Var`]
//! payloads moved through per-rank mailboxes with blocking, FIFO-per-pair,
//! tag-matched semantics — exactly the subset of MPI semantics SEDAR's
//! mechanisms rely on. Payload buffers are shared and immutable
//! ([`crate::util::bytes::SharedBuf`]-backed), so a send moves a reference
//! through the mailbox, never the bytes, and collective fan-outs share one
//! allocation across every destination. Collectives (scatter/bcast/gather/reduce/barrier) are
//! built from point-to-point sends in deterministic rank order, mirroring
//! §4.2's note that the functional-validation implementation of SEDAR is
//! point-to-point based.
//!
//! All blocking goes through the world's [`Clock`]: a send publishes via
//! its destination mailbox's [`WaitPoint`] (a targeted wakeup — under a
//! wall clock only the destination rank's receiver is woken, and the send
//! hot path never takes a world-global lock), an abort broadcasts via
//! [`Clock::notify`], and a receive parks via the generation-capture wait
//! protocol on its own mailbox's point. Under a virtual clock the points
//! alias the world clock, which is what lets `recv_timeout` deadlines fire
//! in logical ticks the instant the world quiesces, instead of burning
//! real time.
//!
//! A network-wide **abort flag** implements SEDAR's safe-stop: when any rank
//! reports a fault, the coordinator calls [`Network::abort`] and every
//! blocked or future operation unwinds with [`SedarError::Aborted`], so all
//! replica threads can be joined promptly.
//!
//! A network may carry a [`FaultLayer`](crate::faultnet::FaultLayer):
//! every send is then sequenced per (src, dst), CRC-stamped, and run
//! through the layer's deterministic plan (drop / duplicate /
//! reorder-delay / corrupt-payload-bit). Delivery preserves per-(src,
//! tag) FIFO even for delayed messages (MPI's non-overtaking guarantee),
//! absorbs duplicate redeliveries through a bounded dedup window, and
//! verifies the payload CRC on take — a flipped bit surfaces as the
//! typed [`SedarError::NetCorrupt`], never silently corrupt data.

pub mod collectives;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{Result, SedarError};
use crate::faultnet::{FaultAction, FaultLayer};
use crate::state::Var;
use crate::util::clock::{Clock, Tick, Wait, WaitPoint};
use crate::util::codec::crc32;

/// Most duplicate copies one (src, tag) stream may hold queued at once:
/// the redelivery cap that keeps a duplicate storm from growing a mailbox
/// unboundedly.
pub const MAX_QUEUED_DUPS: usize = 8;

/// A message in flight.
#[derive(Debug)]
pub struct Envelope {
    pub src: usize,
    pub tag: u32,
    pub payload: Var,
    /// Per-(src, dst) send sequence number — the sender's program order,
    /// so it is deterministic under any thread interleaving.
    pub seq: u64,
    /// Earliest tick at which this message may be taken (0 = immediately;
    /// only a faultnet reorder-delay sets it).
    pub deliver_at: Tick,
    /// CRC-32 of the payload bytes stamped at send, *before* the fault
    /// layer may corrupt them — the transport's link-level checksum.
    /// `None` on clean networks (no per-message hashing overhead).
    pub integrity: Option<u32>,
    /// True for a faultnet-injected duplicate copy (counted against
    /// [`MAX_QUEUED_DUPS`]).
    pub dup: bool,
}

struct MailboxState {
    q: VecDeque<Envelope>,
    /// Next sequence number per source rank.
    next_seq: Vec<u64>,
    /// Highest delivered seq per (src, tag) — the bounded dedup window
    /// that absorbs duplicate redeliveries (faulted networks only).
    delivered: HashMap<(usize, u32), u64>,
}

struct Mailbox {
    state: Mutex<MailboxState>,
    /// This mailbox's wakeup channel: senders notify it, the owning rank's
    /// receives park on it.
    wp: WaitPoint,
}

/// Outcome of one non-blocking mailbox scan.
enum Take {
    Got(Var),
    /// The head-of-line message of this (src, tag) stream exists but may
    /// not be delivered before this tick.
    NotDue(Tick),
    Empty,
}

/// Byte / message accounting, kept per network (Table 3's communication
/// characterization draws from these).
#[derive(Debug, Default)]
pub struct NetStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

/// The in-process interconnect for one application instance (one "MPI
/// world"). A SEDAR run owns exactly one; the baseline strategy owns two.
pub struct Network {
    n: usize,
    boxes: Vec<Mailbox>,
    aborted: AtomicBool,
    clock: Clock,
    /// Installed perturbation layer, if any (`sedar` runs with
    /// `netfault != none`).
    faults: Option<Arc<FaultLayer>>,
    pub stats: NetStats,
}

impl Network {
    /// Wall-clock network (interactive/test default).
    pub fn new(nranks: usize) -> Arc<Network> {
        Self::with_clock(nranks, Clock::wall())
    }

    /// Network whose blocking operations route through `clock` — the
    /// coordinator passes the per-world clock here so every rank shares it.
    pub fn with_clock(nranks: usize, clock: Clock) -> Arc<Network> {
        Self::with_faults(nranks, clock, None)
    }

    /// Network with an optional deterministic fault layer installed.
    pub fn with_faults(
        nranks: usize,
        clock: Clock,
        faults: Option<Arc<FaultLayer>>,
    ) -> Arc<Network> {
        assert!(nranks >= 1);
        Arc::new(Network {
            n: nranks,
            boxes: (0..nranks)
                .map(|_| Mailbox {
                    state: Mutex::new(MailboxState {
                        q: VecDeque::new(),
                        next_seq: vec![0; nranks],
                        delivered: HashMap::new(),
                    }),
                    wp: clock.wait_point(),
                })
                .collect(),
            aborted: AtomicBool::new(false),
            clock,
            faults,
            stats: NetStats::default(),
        })
    }

    pub fn nranks(&self) -> usize {
        self.n
    }

    /// The installed fault layer, if any (the coordinator drains its
    /// typed events into the run trace after each attempt).
    pub fn fault_layer(&self) -> Option<&Arc<FaultLayer>> {
        self.faults.as_ref()
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Safe-stop: wake every blocked receiver with [`SedarError::Aborted`].
    pub fn abort(&self) {
        self.aborted.store(true, Ordering::SeqCst);
        self.clock.notify();
    }

    pub fn is_aborted(&self) -> bool {
        self.aborted.load(Ordering::SeqCst)
    }

    /// Obtain the endpoint for `rank`.
    pub fn endpoint(self: &Arc<Self>, rank: usize) -> Endpoint {
        assert!(rank < self.n, "rank {rank} out of range");
        Endpoint {
            rank,
            net: Arc::clone(self),
        }
    }
}

/// One rank's handle on the network.
#[derive(Clone)]
pub struct Endpoint {
    rank: usize,
    net: Arc<Network>,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nranks(&self) -> usize {
        self.net.n
    }

    pub fn network(&self) -> &Arc<Network> {
        &self.net
    }

    fn check_abort(&self) -> Result<()> {
        if self.net.is_aborted() {
            Err(SedarError::Aborted)
        } else {
            Ok(())
        }
    }

    /// Non-blocking buffered send (MPI eager mode).
    pub fn send(&self, dst: usize, tag: u32, payload: Var) -> Result<()> {
        self.check_abort()?;
        if dst >= self.net.n {
            return Err(SedarError::Vmpi(format!(
                "send to invalid rank {dst} (world size {})",
                self.net.n
            )));
        }
        let bytes = payload.buf.byte_len() as u64;
        let mbox = &self.net.boxes[dst];
        {
            let mut st = mbox.state.lock().unwrap();
            let seq = st.next_seq[self.rank];
            st.next_seq[self.rank] = seq + 1;
            match self.net.faults.as_deref() {
                None => st.q.push_back(Envelope {
                    src: self.rank,
                    tag,
                    payload,
                    seq,
                    deliver_at: 0,
                    integrity: None,
                    dup: false,
                }),
                Some(fl) => self.push_faulted(&mut st, fl, dst, tag, payload, seq),
            }
        }
        mbox.wp.notify();
        self.net.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.net.stats.bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Queue one message through the fault layer's plan. The CRC is
    /// stamped before any perturbation, so a corrupted bit trips the
    /// integrity check on take.
    fn push_faulted(
        &self,
        st: &mut MailboxState,
        fl: &FaultLayer,
        dst: usize,
        tag: u32,
        payload: Var,
        seq: u64,
    ) {
        let action = fl.plan().action(self.rank, dst, seq);
        let crc = crc32(payload.buf.bytes());
        let mut env = Envelope {
            src: self.rank,
            tag,
            payload,
            seq,
            deliver_at: 0,
            integrity: Some(crc),
            dup: false,
        };
        let now = self.net.clock.now();
        match action {
            FaultAction::Deliver => st.q.push_back(env),
            FaultAction::Drop => {
                fl.record(now, self.rank, dst, tag, seq, &action);
            }
            FaultAction::Duplicate => {
                fl.record(now, self.rank, dst, tag, seq, &action);
                let copy = Envelope {
                    src: env.src,
                    tag,
                    payload: env.payload.clone(),
                    seq,
                    deliver_at: 0,
                    integrity: env.integrity,
                    dup: true,
                };
                st.q.push_back(env);
                // Redelivery cap: a storm may queue at most
                // MAX_QUEUED_DUPS extra copies per (src, tag).
                let queued = st
                    .q
                    .iter()
                    .filter(|e| e.dup && e.src == self.rank && e.tag == tag)
                    .count();
                if queued < MAX_QUEUED_DUPS {
                    st.q.push_back(copy);
                }
            }
            FaultAction::Delay(d) => {
                fl.record(now, self.rank, dst, tag, seq, &action);
                env.deliver_at = now + d;
                st.q.push_back(env);
            }
            FaultAction::CorruptBit(k) => {
                let bits = (env.payload.buf.byte_len() * 8) as u64;
                if bits == 0 {
                    st.q.push_back(env);
                    return;
                }
                fl.record(now, self.rank, dst, tag, seq, &action);
                let bit = (k % bits) as usize;
                env.payload.buf.bytes_mut()[bit / 8] ^= 1 << (bit % 8);
                st.q.push_back(env);
            }
        }
    }

    /// Blocking receive matching `(src, tag)`; FIFO among matching messages.
    pub fn recv(&self, src: usize, tag: u32) -> Result<Var> {
        self.recv_inner(src, tag, None)
    }

    /// Blocking receive with a deadline (used by watchdog paths). The
    /// timeout is modeled time: ticks under a virtual clock, real time
    /// under a wall clock.
    pub fn recv_timeout(&self, src: usize, tag: u32, timeout: Duration) -> Result<Var> {
        self.recv_inner(src, tag, Some(timeout))
    }

    fn try_take(&self, src: usize, tag: u32) -> Result<Take> {
        let mut st = self.net.boxes[self.rank].state.lock().unwrap();
        if self.net.is_aborted() {
            return Err(SedarError::Aborted);
        }
        let faulted = self.net.faults.is_some();
        loop {
            let pos = match st.q.iter().position(|e| e.src == src && e.tag == tag) {
                Some(pos) => pos,
                None => return Ok(Take::Empty),
            };
            // Per-(src, tag) FIFO is MPI's non-overtaking guarantee, which
            // SEDAR's protocol is entitled to assume: a delayed head holds
            // its whole stream instead of being overtaken.
            if st.q[pos].deliver_at > 0 {
                let due = st.q[pos].deliver_at;
                if due > self.net.clock.now() {
                    return Ok(Take::NotDue(due));
                }
            }
            let env = st.q.remove(pos).unwrap();
            if faulted {
                // Dedup window: a redelivery at or below the last
                // delivered seq of this stream is absorbed silently.
                if let Some(&last) = st.delivered.get(&(src, tag)) {
                    if env.seq <= last {
                        continue;
                    }
                }
                if let Some(crc) = env.integrity {
                    if crc32(env.payload.buf.bytes()) != crc {
                        return Err(SedarError::NetCorrupt {
                            src,
                            dst: self.rank,
                            tag,
                            seq: env.seq,
                        });
                    }
                }
                st.delivered.insert((src, tag), env.seq);
            }
            return Ok(Take::Got(env.payload));
        }
    }

    fn recv_inner(&self, src: usize, tag: u32, timeout: Option<Duration>) -> Result<Var> {
        let wp = &self.net.boxes[self.rank].wp;
        // An installed fault layer imposes its default deadline on
        // receives that would otherwise block forever: a dropped message
        // must surface as a timeout verdict, never a hang, on either
        // clock.
        let timeout =
            timeout.or_else(|| self.net.faults.as_ref().and_then(|f| f.recv_deadline()));
        let deadline = timeout.map(|t| self.net.clock.deadline_after(t));
        loop {
            // Generation first, queue check second: a send that lands after
            // the check has already bumped the generation, so the wait below
            // returns `Notified` instead of losing the wakeup.
            let gen = wp.subscribe();
            let held = match self.try_take(src, tag)? {
                Take::Got(v) => return Ok(v),
                Take::NotDue(due) => Some(due),
                Take::Empty => None,
            };
            // Park until the earlier of the recv deadline and the held
            // head-of-line message's due tick.
            let wake = match (deadline, held) {
                (Some(d), Some(h)) => Some(d.min(h)),
                (d, h) => d.or(h),
            };
            match wp.wait(gen, wake) {
                Wait::Notified => continue,
                Wait::TimedOut => {
                    // A held message coming due is not the recv deadline
                    // expiring — only give up once the deadline passed.
                    let expired = match deadline {
                        Some(d) => self.net.clock.now() >= d,
                        None => false,
                    };
                    if !expired {
                        continue;
                    }
                    // The deadline and a matching send can race; prefer the
                    // message, exactly like a real just-in-time arrival.
                    if let Take::Got(v) = self.try_take(src, tag)? {
                        return Ok(v);
                    }
                    return Err(SedarError::Vmpi(format!(
                        "recv timeout waiting for src={src} tag={tag} at rank {}",
                        self.rank
                    )));
                }
                Wait::Poisoned => {
                    return Err(SedarError::Vmpi(format!(
                        "virtual-clock deadlock: all participants blocked with no \
                         pending deadline (recv src={src} tag={tag} at rank {})",
                        self.rank
                    )));
                }
            }
        }
    }

    /// Count of queued (unmatched) messages — used by tests.
    pub fn pending(&self) -> usize {
        self.net.boxes[self.rank].state.lock().unwrap().q.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::Var;

    fn v(data: &[f32]) -> Var {
        Var::f32(&[data.len()], data.to_vec())
    }

    #[test]
    fn send_recv_roundtrip() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, 7, v(&[1.0, 2.0])).unwrap();
        let got = b.recv(0, 7).unwrap();
        assert_eq!(got.buf.as_f32().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn tag_matching_skips_nonmatching() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, 1, v(&[1.0])).unwrap();
        a.send(1, 2, v(&[2.0])).unwrap();
        // Receive tag 2 first even though tag 1 arrived first.
        assert_eq!(b.recv(0, 2).unwrap().buf.as_f32().unwrap(), &[2.0]);
        assert_eq!(b.recv(0, 1).unwrap().buf.as_f32().unwrap(), &[1.0]);
    }

    #[test]
    fn fifo_within_same_src_tag() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        for i in 0..10 {
            a.send(1, 3, v(&[i as f32])).unwrap();
        }
        for i in 0..10 {
            assert_eq!(b.recv(0, 3).unwrap().buf.as_f32().unwrap(), &[i as f32]);
        }
    }

    #[test]
    fn cross_thread_blocking_recv() {
        // No ordering sleep needed: the receiver blocks until the sender's
        // clock notification, whichever thread runs first.
        let net = Network::new(2);
        let b = net.endpoint(1);
        let net2 = Arc::clone(&net);
        let h = std::thread::spawn(move || {
            net2.endpoint(0).send(1, 0, v(&[9.0])).unwrap();
        });
        let got = b.recv(0, 0).unwrap();
        assert_eq!(got.buf.as_f32().unwrap(), &[9.0]);
        h.join().unwrap();
    }

    #[test]
    fn abort_wakes_blocked_receiver() {
        // Either interleaving passes: abort-before-recv fails fast, recv-
        // before-abort is woken by the abort's clock notification.
        let net = Network::new(2);
        let b = net.endpoint(1);
        let net2 = Arc::clone(&net);
        let h = std::thread::spawn(move || {
            net2.abort();
        });
        let err = b.recv(0, 0).unwrap_err();
        assert!(matches!(err, SedarError::Aborted));
        h.join().unwrap();
    }

    #[test]
    fn recv_timeout_expires() {
        let net = Network::new(2);
        let b = net.endpoint(1);
        let err = b.recv_timeout(0, 0, Duration::from_millis(10)).unwrap_err();
        assert!(matches!(err, SedarError::Vmpi(_)));
    }

    #[test]
    fn recv_timeout_fires_instantly_under_virtual_clock() {
        let clock = Clock::virtual_clock();
        clock.join_n(1);
        let _g = clock.guard();
        let net = Network::with_clock(2, clock.clone());
        let b = net.endpoint(1);
        // An hour of modeled waiting elapses the moment the world quiesces.
        let err = b
            .recv_timeout(0, 0, Duration::from_secs(3600))
            .unwrap_err();
        assert!(matches!(err, SedarError::Vmpi(_)));
        assert!(clock.now() >= Clock::ticks(Duration::from_secs(3600)));
    }

    #[test]
    fn deadline_free_virtual_recv_poisons_instead_of_hanging() {
        let clock = Clock::virtual_clock();
        clock.join_n(1);
        let _g = clock.guard();
        let net = Network::with_clock(2, clock);
        let b = net.endpoint(1);
        let err = b.recv(0, 0).unwrap_err();
        match err {
            SedarError::Vmpi(msg) => assert!(msg.contains("deadlock"), "got: {msg}"),
            other => panic!("expected Vmpi deadlock error, got {other:?}"),
        }
    }

    #[test]
    fn send_to_invalid_rank_fails() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        assert!(a.send(5, 0, v(&[0.0])).is_err());
    }

    #[test]
    fn send_shares_payload_allocation() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let v = Var::f32(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        a.send(1, 9, v.clone()).unwrap();
        let got = b.recv(0, 9).unwrap();
        assert!(
            got.buf.shares_allocation(&v.buf),
            "transport must move a reference, not copy the payload"
        );
    }

    #[test]
    fn stats_account_bytes() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        a.send(1, 0, v(&[0.0; 16])).unwrap();
        assert_eq!(net.stats.messages.load(Ordering::Relaxed), 1);
        assert_eq!(net.stats.bytes.load(Ordering::Relaxed), 64);
    }

    // ---- faultnet integration -------------------------------------------

    use crate::faultnet::{FaultPlan, NetFaultMode};

    fn faulted_net(
        mode: NetFaultMode,
        seed: u64,
        deadline: Option<Duration>,
    ) -> (Arc<Network>, Arc<FaultLayer>) {
        let layer = Arc::new(FaultLayer::new(FaultPlan::new(mode, seed), 1, deadline));
        let net = Network::with_faults(2, Clock::wall(), Some(Arc::clone(&layer)));
        (net, layer)
    }

    #[test]
    fn dropped_message_surfaces_as_timeout_not_hang() {
        let (net, layer) = faulted_net(
            NetFaultMode::Drop,
            11,
            Some(Duration::from_millis(20)),
        );
        let plan = *layer.plan();
        let dropped = (0u64..)
            .find(|&s| plan.action(0, 1, s) == FaultAction::Drop)
            .unwrap();
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        for s in 0..=dropped {
            a.send(1, 3, v(&[s as f32])).unwrap();
        }
        for s in 0..dropped {
            assert_eq!(b.recv(0, 3).unwrap().buf.as_f32().unwrap(), &[s as f32]);
        }
        // The dropped message: the layer's default deadline turns the
        // plain (unbounded) recv into a clean timeout, never a hang.
        let err = b.recv(0, 3).unwrap_err();
        match err {
            SedarError::Vmpi(msg) => assert!(msg.contains("recv timeout"), "{msg}"),
            other => panic!("expected timeout, got {other:?}"),
        }
        assert!(layer.counters.drops.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn duplicates_are_absorbed_and_the_storm_is_capped() {
        let (net, layer) = faulted_net(
            NetFaultMode::Dup,
            5,
            Some(Duration::from_millis(20)),
        );
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        const N: usize = 200;
        for i in 0..N {
            a.send(1, 3, v(&[i as f32])).unwrap();
        }
        let dups = layer.counters.dups.load(Ordering::Relaxed) as usize;
        assert!(dups > MAX_QUEUED_DUPS, "want a real storm, got {dups} dups");
        // The redelivery cap bounds mailbox growth below the storm size.
        assert!(
            b.pending() <= N + MAX_QUEUED_DUPS,
            "mailbox grew to {} (cap {})",
            b.pending(),
            N + MAX_QUEUED_DUPS
        );
        // Every payload arrives exactly once, in order.
        for i in 0..N {
            assert_eq!(b.recv(0, 3).unwrap().buf.as_f32().unwrap(), &[i as f32]);
        }
        // Leftover duplicate copies are absorbed, not delivered.
        assert!(b.recv(0, 3).is_err());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn delayed_stream_stays_fifo_under_virtual_clock() {
        let clock = Clock::virtual_clock();
        clock.join_n(1);
        let _g = clock.guard();
        let layer = Arc::new(FaultLayer::new(
            FaultPlan::new(NetFaultMode::Reorder, 9),
            1,
            None,
        ));
        let net = Network::with_faults(2, clock.clone(), Some(Arc::clone(&layer)));
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        for i in 0..50 {
            a.send(1, 3, v(&[i as f32])).unwrap();
        }
        assert!(layer.counters.delays.load(Ordering::Relaxed) >= 1);
        // Delays hold the stream head (non-overtaking), and the virtual
        // clock jumps to each due tick — in-order delivery, no wall time.
        for i in 0..50 {
            assert_eq!(b.recv(0, 3).unwrap().buf.as_f32().unwrap(), &[i as f32]);
        }
        assert!(clock.now() > 0, "delays must advance the modeled clock");
    }

    #[test]
    fn corrupt_payload_is_a_typed_error_never_a_panic() {
        let (net, layer) = faulted_net(
            NetFaultMode::Corrupt,
            13,
            Some(Duration::from_millis(20)),
        );
        let plan = *layer.plan();
        let bent = (0u64..)
            .find(|&s| matches!(plan.action(0, 1, s), FaultAction::CorruptBit(_)))
            .unwrap();
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        for s in 0..=bent {
            a.send(1, 3, v(&[s as f32])).unwrap();
        }
        for s in 0..bent {
            assert_eq!(b.recv(0, 3).unwrap().buf.as_f32().unwrap(), &[s as f32]);
        }
        // The flipped bit trips the send-time CRC on take.
        let err = b.recv(0, 3).unwrap_err();
        match err {
            SedarError::NetCorrupt { src, dst, tag, seq } => {
                assert_eq!((src, dst, tag, seq), (0, 1, 3, bent));
            }
            other => panic!("expected NetCorrupt, got {other:?}"),
        }
        assert!(layer.counters.corrupts.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn fault_layer_records_typed_events_with_send_ticks() {
        let (net, layer) = faulted_net(
            NetFaultMode::Mixed,
            3,
            Some(Duration::from_millis(20)),
        );
        let a = net.endpoint(0);
        for i in 0..100 {
            a.send(1, 3, v(&[i as f32])).unwrap();
        }
        let events = layer.take_events();
        assert_eq!(events.len() as u64, layer.faults_applied());
        assert!(!events.is_empty());
        for e in &events {
            assert_eq!(e.kind, crate::obs::EventKind::NetFault);
            assert!(e.detail.starts_with("netfault: "), "{}", e.detail);
        }
    }
}
