//! The Master/Worker matrix-multiplication test application — Algorithm 3
//! of the paper, the substrate of the 64-scenario workfault (§4.1).
//!
//! Phase structure (cursor values in brackets):
//!
//! ```text
//! [0] INIT      every rank builds its initial store (master: A, B, C)
//! [1] CK0       SEDAR_Ckpt()
//! [2] SCATTER   master scatters row-chunks of A (keeps chunk 0)
//! [3] CK1       SEDAR_Ckpt()
//! [4] BCAST     master broadcasts B
//! [5] CK2       SEDAR_Ckpt()
//! [6] MATMUL    every rank computes C_chunk = A_chunk × B (sub-blocked)
//! [7] GATHER    master gathers the C chunks
//! [8] CK3       SEDAR_Ckpt()
//! [9] VALIDATE  master compares the final C between replicas
//! ```
//!
//! The MATMUL phase is split into `sub_blocks` row bands so the TOE
//! scenarios (index-variable corruption, e.g. Scenario 59) can force one
//! replica to redo part of its work and miss the GATHER rendezvous.
//!
//! The compute hot spot runs through the AOT artifact
//! `matmul_r<band-rows>_n<N>` (Layer 1 Pallas kernel under Layer 2 JAX),
//! falling back to a bit-identical naive loop when artifacts are disabled.

use crate::apps::oracle;
use crate::apps::spec::AppSpec;
use crate::error::Result;
use crate::replica::ReplicaCtx;
use crate::state::{Var, VarStore};

/// Phase cursors (public: the workfault catalog addresses windows by them).
pub mod phases {
    pub const INIT: u64 = 0;
    pub const CK0: u64 = 1;
    pub const SCATTER: u64 = 2;
    pub const CK1: u64 = 3;
    pub const BCAST: u64 = 4;
    pub const CK2: u64 = 5;
    pub const MATMUL: u64 = 6;
    pub const GATHER: u64 = 7;
    pub const CK3: u64 = 8;
    pub const VALIDATE: u64 = 9;
    pub const COUNT: u64 = 10;
}

/// Master/Worker `C = A × B` over `nranks` ranks (rank 0 = master, which
/// also computes a chunk, as in the paper's test application).
#[derive(Debug, Clone)]
pub struct MatmulApp {
    /// Matrix dimension (N × N). Must be divisible by `nranks * sub_blocks`.
    pub n: usize,
    pub nranks: usize,
    /// Row bands per rank in the MATMUL phase.
    pub sub_blocks: usize,
}

impl MatmulApp {
    pub fn new(n: usize, nranks: usize) -> MatmulApp {
        let app = MatmulApp {
            n,
            nranks,
            sub_blocks: 4,
        };
        assert!(
            n % (nranks * app.sub_blocks) == 0,
            "N={n} must be divisible by nranks*sub_blocks={}",
            nranks * app.sub_blocks
        );
        app
    }

    /// Rows of each rank's chunk.
    pub fn chunk_rows(&self) -> usize {
        self.n / self.nranks
    }

    /// Rows of one compute sub-block.
    pub fn band_rows(&self) -> usize {
        self.chunk_rows() / self.sub_blocks
    }

    /// The AOT artifact this app's compute uses.
    pub fn artifact(&self) -> String {
        format!("matmul_r{}_n{}", self.band_rows(), self.n)
    }

    /// The scatter root's chunk list: zero-copy row-band views of `A`
    /// (one reference bump per rank — no payload bytes are copied; see
    /// `scatter_chunks_are_zero_copy_views`). Copy-on-write isolates any
    /// downstream writer, so the views are safe to hand to other ranks.
    pub fn scatter_chunks(&self, a: &Var) -> Result<Vec<Var>> {
        let (rows, n) = (self.chunk_rows(), self.n);
        (0..self.nranks)
            .map(|r| {
                Ok(Var {
                    shape: vec![rows, n],
                    buf: a.buf.view(r * rows * n, rows * n)?,
                })
            })
            .collect()
    }

    fn seed_a(seed: u64) -> u64 {
        seed.wrapping_mul(31).wrapping_add(1)
    }

    fn seed_b(seed: u64) -> u64 {
        seed.wrapping_mul(31).wrapping_add(2)
    }

    /// Compute one row band: `C_band = A_band × B`.
    fn compute_band(&self, ctx: &ReplicaCtx, a_band: Var, b: Var) -> Result<Vec<f32>> {
        let rows = self.band_rows();
        let n = self.n;
        let out = ctx.compute(&self.artifact(), vec![a_band, b], |inputs| {
            let a = inputs[0].buf.as_f32()?;
            let b = inputs[1].buf.as_f32()?;
            Ok(vec![Var::f32(
                &[rows, n],
                oracle::matmul_seq(a, b, rows, n, n),
            )])
        })?;
        Ok(out[0].buf.as_f32()?.to_vec())
    }
}

impl AppSpec for MatmulApp {
    fn name(&self) -> &'static str {
        "matmul"
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    fn n_phases(&self) -> u64 {
        phases::COUNT
    }

    fn phase_name(&self, phase: u64) -> String {
        match phase {
            phases::INIT => "INIT",
            phases::CK0 => "CK0",
            phases::SCATTER => "SCATTER",
            phases::CK1 => "CK1",
            phases::BCAST => "BCAST",
            phases::CK2 => "CK2",
            phases::MATMUL => "MATMUL",
            phases::GATHER => "GATHER",
            phases::CK3 => "CK3",
            phases::VALIDATE => "VALIDATE",
            _ => "?",
        }
        .to_string()
    }

    fn init_store(&self, rank: usize, seed: u64) -> VarStore {
        let n = self.n;
        let rows = self.chunk_rows();
        let mut s = VarStore::new();
        if rank == 0 {
            s.insert(
                "A",
                Var::f32(&[n, n], oracle::gen_matrix(Self::seed_a(seed), n, n)),
            );
            s.insert(
                "B",
                Var::f32(&[n, n], oracle::gen_matrix(Self::seed_b(seed), n, n)),
            );
            s.insert("C", Var::f32(&[n, n], vec![0.0; n * n]));
        } else {
            s.insert("B", Var::f32(&[n, n], vec![0.0; n * n]));
        }
        s.insert("A_chunk", Var::f32(&[rows, n], vec![0.0; rows * n]));
        s.insert("C_chunk", Var::f32(&[rows, n], vec![0.0; rows * n]));
        s
    }

    fn run_phase(&self, ctx: &mut ReplicaCtx, phase: u64) -> Result<()> {
        let n = self.n;
        let rows = self.chunk_rows();
        match phase {
            phases::INIT => Ok(()),
            phases::CK0 => ctx.checkpoint(0, "CK0"),
            phases::SCATTER => {
                let chunks = if ctx.rank == 0 {
                    Some(self.scatter_chunks(ctx.store.get("A")?)?)
                } else {
                    None
                };
                ctx.scatter(0, chunks, "A_chunk", "SCATTER")
            }
            phases::CK1 => ctx.checkpoint(1, "CK1"),
            phases::BCAST => ctx.bcast(0, "B", "BCAST"),
            phases::CK2 => ctx.checkpoint(2, "CK2"),
            phases::MATMUL => {
                let band = self.band_rows();
                let b = ctx.store.get("B")?.clone();
                let mut sb: u64 = 0;
                while sb < self.sub_blocks as u64 {
                    let lo = sb as usize * band * n;
                    let hi = lo + band * n;
                    let a_band = {
                        let a = ctx.store.f32("A_chunk")?;
                        Var::f32(&[band, n], a[lo..hi].to_vec())
                    };
                    let c_band = self.compute_band(ctx, a_band, b.clone())?;
                    ctx.store.f32_mut("C_chunk")?[lo..hi].copy_from_slice(&c_band);
                    // Index-corruption injection (TOE scenarios): the loop
                    // variable is knocked back, the replica redoes work and
                    // arrives late at GATHER.
                    if let Some((redo, delay)) = ctx.maybe_index_rollback(phases::MATMUL, sb) {
                        // Modeled-time delay: instant in wall terms under a
                        // virtual clock, where the sibling's TOE lapse and
                        // this delay resolve purely in ticks.
                        ctx.sleep(delay);
                        sb = sb.saturating_sub(redo);
                        continue;
                    }
                    sb += 1;
                }
                Ok(())
            }
            phases::GATHER => {
                let parts = ctx.gather(0, "C_chunk", "GATHER")?;
                if let Some(parts) = parts {
                    let c = ctx.store.f32_mut("C")?;
                    for (r, part) in parts.iter().enumerate() {
                        let p = part.buf.as_f32()?;
                        c[r * rows * n..(r + 1) * rows * n].copy_from_slice(p);
                    }
                }
                Ok(())
            }
            phases::CK3 => ctx.checkpoint(3, "CK3"),
            phases::VALIDATE => {
                if ctx.rank == 0 {
                    ctx.validate_result("C", "VALIDATE")?;
                }
                Ok(())
            }
            other => unreachable!("matmul has no phase {other}"),
        }
    }

    fn significant_vars(&self, rank: usize) -> Vec<String> {
        if rank == 0 {
            vec!["A", "B", "C", "A_chunk", "C_chunk"]
        } else {
            vec!["A_chunk", "B", "C_chunk"]
        }
        .into_iter()
        .map(String::from)
        .collect()
    }

    fn result_var(&self) -> &'static str {
        "C"
    }

    fn expected_result(&self, seed: u64) -> Vec<f32> {
        let n = self.n;
        let a = oracle::gen_matrix(Self::seed_a(seed), n, n);
        let b = oracle::gen_matrix(Self::seed_b(seed), n, n);
        oracle::matmul_seq(&a, &b, n, n, n)
    }

    fn ckpt_phases(&self) -> Vec<u64> {
        vec![phases::CK0, phases::CK1, phases::CK2, phases::CK3]
    }

    fn artifacts(&self) -> Vec<String> {
        vec![self.artifact()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry() {
        let app = MatmulApp::new(64, 4);
        assert_eq!(app.chunk_rows(), 16);
        assert_eq!(app.band_rows(), 4);
        assert_eq!(app.artifact(), "matmul_r4_n64");
        assert_eq!(app.n_phases(), 10);
    }

    #[test]
    fn phase_names_match_paper() {
        let app = MatmulApp::new(64, 4);
        assert_eq!(app.phase_name(2), "SCATTER");
        assert_eq!(app.cursor_of("GATHER"), 7);
        assert_eq!(app.ckpt_phases().len(), 4);
    }

    #[test]
    fn init_stores_deterministic_and_distinct() {
        let app = MatmulApp::new(32, 4);
        let m1 = app.init_store(0, 7);
        let m2 = app.init_store(0, 7);
        assert_eq!(m1, m2);
        let w = app.init_store(1, 7);
        assert!(!w.contains("A"));
        assert!(w.contains("A_chunk"));
    }

    #[test]
    fn scatter_chunks_are_zero_copy_views() {
        let app = MatmulApp::new(16, 4);
        let store = app.init_store(0, 7);
        let a = store.get("A").unwrap();
        let chunks = app.scatter_chunks(a).unwrap();
        assert_eq!(chunks.len(), 4);
        let full = a.buf.as_f32().unwrap();
        let per = app.chunk_rows() * app.n;
        for (r, c) in chunks.iter().enumerate() {
            assert!(
                c.buf.shares_allocation(&a.buf),
                "chunk {r} must view A's allocation, not copy it"
            );
            assert_eq!(c.shape, vec![app.chunk_rows(), app.n]);
            assert_eq!(c.buf.as_f32().unwrap(), &full[r * per..(r + 1) * per]);
        }
    }

    #[test]
    fn oracle_is_full_matmul() {
        let app = MatmulApp::new(16, 4);
        let c = app.expected_result(3);
        assert_eq!(c.len(), 256);
        // Spot-check one element against a manual dot product.
        let a = oracle::gen_matrix(MatmulApp::seed_a(3), 16, 16);
        let b = oracle::gen_matrix(MatmulApp::seed_b(3), 16, 16);
        let mut acc = 0f32;
        for k in 0..16 {
            acc += a[5 * 16 + k] * b[k * 16 + 9];
        }
        assert_eq!(c[5 * 16 + 9], acc);
    }
}
