//! The benchmark applications of the paper's evaluation (§4.1, §4.3).
//!
//! Three communication patterns, as in the paper:
//!
//! * [`matmul`] — Master/Worker matrix multiplication, the §4.1 test
//!   application (Algorithm 3) over which the 64-scenario workfault is
//!   defined;
//! * [`jacobi`] — SPMD Jacobi iteration for Laplace's equation (neighbor
//!   halo exchange every iteration);
//! * [`sw`] — pipelined Smith-Waterman DNA sequence alignment (frontier
//!   flows rank→rank+1).
//!
//! All are phase-structured [`spec::AppSpec`]s whose compute hot spots run
//! through the AOT Pallas/XLA artifacts (with bit-deterministic pure-rust
//! fallbacks), and all are deterministic — the SEDAR replication
//! prerequisite.

pub mod jacobi;
pub mod matmul;
pub mod oracle;
pub mod spec;
pub mod sw;

pub use jacobi::JacobiApp;
pub use matmul::MatmulApp;
pub use spec::AppSpec;
pub use sw::SwApp;
