//! Sequential ground-truth implementations of the three benchmarks.
//!
//! These run outside the fault-tolerance machinery and outside XLA; the
//! coordinator compares the protected run's final result against them, which
//! closes the end-to-end loop: *a recovered execution must produce the same
//! answer as an unprotected sequential one*.

use crate::util::prng::SplitMix64;

/// Deterministic workload matrix of `rows × cols`, seeded like the apps do.
pub fn gen_matrix(seed: u64, rows: usize, cols: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    let mut m = vec![0f32; rows * cols];
    rng.fill_f32(&mut m);
    m
}

/// Naive `C = A × B`, row-major, k-innermost — the exact accumulation order
/// the distributed fallback uses, so results match bitwise.
pub fn matmul_seq(a: &[f32], b: &[f32], n_rows: usize, n_inner: usize, n_cols: usize) -> Vec<f32> {
    let mut c = vec![0f32; n_rows * n_cols];
    for i in 0..n_rows {
        for j in 0..n_cols {
            let mut acc = 0f32;
            for k in 0..n_inner {
                acc += a[i * n_inner + k] * b[k * n_cols + j];
            }
            c[i * n_cols + j] = acc;
        }
    }
    c
}

/// Jacobi sweeps on an `n × n` grid with fixed boundary, `iters` iterations.
/// Interior point = mean of its 4 neighbors.
pub fn jacobi_seq(grid0: &[f32], n: usize, iters: usize) -> Vec<f32> {
    let mut cur = grid0.to_vec();
    let mut next = grid0.to_vec();
    for _ in 0..iters {
        for i in 1..n - 1 {
            for j in 1..n - 1 {
                next[i * n + j] = 0.25
                    * (cur[(i - 1) * n + j]
                        + cur[(i + 1) * n + j]
                        + cur[i * n + j - 1]
                        + cur[i * n + j + 1]);
            }
        }
        std::mem::swap(&mut cur, &mut next);
    }
    cur
}

/// Smith-Waterman local-alignment score of two byte sequences with linear
/// gap penalty. Returns the maximum cell of the DP matrix.
///
/// Scoring: match = +2, mismatch = -1, gap = -1 (the classic defaults the
/// SW benchmark of the paper's reference [29] uses for DNA).
pub fn sw_seq(s1: &[u8], s2: &[u8]) -> f32 {
    let m = s1.len();
    let n = s2.len();
    let mut prev = vec![0f32; n + 1];
    let mut cur = vec![0f32; n + 1];
    let mut best = 0f32;
    for i in 1..=m {
        cur[0] = 0.0;
        for j in 1..=n {
            let score = if s1[i - 1] == s2[j - 1] { 2.0 } else { -1.0 };
            let v = (prev[j - 1] + score)
                .max(prev[j] - 1.0)
                .max(cur[j - 1] - 1.0)
                .max(0.0);
            cur[j] = v;
            if v > best {
                best = v;
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    best
}

/// Deterministic DNA-like sequence (values 0..4).
pub fn gen_sequence(seed: u64, len: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed);
    (0..len).map(|_| (rng.below(4)) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        // A × I = A
        let n = 4;
        let a = gen_matrix(1, n, n);
        let mut id = vec![0f32; n * n];
        for i in 0..n {
            id[i * n + i] = 1.0;
        }
        let c = matmul_seq(&a, &id, n, n, n);
        assert_eq!(c, a);
    }

    #[test]
    fn matmul_known_2x2() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let c = matmul_seq(&a, &b, 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn jacobi_converges_toward_boundary_mean() {
        // All-zero boundary, hot interior: interior must cool monotonically.
        let n = 8;
        let mut g = vec![0f32; n * n];
        for i in 1..n - 1 {
            for j in 1..n - 1 {
                g[i * n + j] = 100.0;
            }
        }
        let out = jacobi_seq(&g, n, 200);
        for i in 1..n - 1 {
            for j in 1..n - 1 {
                assert!(out[i * n + j] < 1.0, "grid did not relax");
            }
        }
        // Boundary untouched.
        assert_eq!(out[0], 0.0);
    }

    #[test]
    fn jacobi_fixed_point_is_fixed() {
        // A constant grid is a fixed point of the averaging operator.
        let n = 6;
        let g = vec![3.5f32; n * n];
        let out = jacobi_seq(&g, n, 10);
        assert_eq!(out, g);
    }

    #[test]
    fn sw_identical_sequences() {
        let s = b"ACGTACGT";
        // Perfect match: 2 points per symbol.
        assert_eq!(sw_seq(s, s), 16.0);
    }

    #[test]
    fn sw_no_similarity() {
        assert_eq!(sw_seq(b"AAAA", b"CCCC"), 0.0);
    }

    #[test]
    fn sw_known_alignment() {
        // "GGTT" vs "GGAT": best local alignment GG (4) or GG?T with
        // mismatch: GGTT vs GGAT = 2+2-1+2 = 5.
        assert_eq!(sw_seq(b"GGTT", b"GGAT"), 5.0);
    }

    #[test]
    fn sequences_deterministic() {
        assert_eq!(gen_sequence(7, 32), gen_sequence(7, 32));
        assert_ne!(gen_sequence(7, 32), gen_sequence(8, 32));
        assert!(gen_sequence(7, 100).iter().all(|&b| b < 4));
    }
}
