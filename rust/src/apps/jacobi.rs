//! SPMD Jacobi iteration for Laplace's equation (§4.3's second benchmark).
//!
//! The n×n grid is decomposed into horizontal row blocks, one per rank.
//! Every iteration exchanges halo rows with both neighbors and sweeps the
//! block with the 5-point stencil; every `ckpt_every` iterations a SEDAR
//! checkpoint is taken. This is the paper's *most communication-intensive*
//! pattern — its measured `f_d` is the largest of the three benchmarks
//! (Table 3), which our Table-3 bench reproduces in shape.
//!
//! Phase layout (`I` iterations, `E = ckpt_every`):
//!
//! ```text
//! [0] INIT
//! [1..] groups of E × ITER phases followed by one CK phase
//! [last-1] GATHER   master collects the blocks
//! [last]   VALIDATE master compares the assembled grid between replicas
//! ```
//!
//! The sweep runs through the AOT artifact `jacobi_r<rows>_n<n>` (a Pallas
//! 5-point stencil kernel); the rust fallback is bit-identical.

use crate::apps::oracle;
use crate::apps::spec::AppSpec;
use crate::error::Result;
use crate::replica::ReplicaCtx;
use crate::state::{Var, VarStore};

/// SPMD Jacobi over `nranks` row blocks.
#[derive(Debug, Clone)]
pub struct JacobiApp {
    /// Grid dimension (n × n); divisible by `nranks`.
    pub n: usize,
    pub nranks: usize,
    /// Total iterations; divisible by `ckpt_every`.
    pub iters: usize,
    /// Checkpoint after every this many iterations.
    pub ckpt_every: usize,
}

impl JacobiApp {
    pub fn new(n: usize, nranks: usize, iters: usize, ckpt_every: usize) -> JacobiApp {
        assert!(n % nranks == 0, "n must divide by nranks");
        assert!(
            iters % ckpt_every == 0,
            "iters must divide by ckpt_every"
        );
        JacobiApp {
            n,
            nranks,
            iters,
            ckpt_every,
        }
    }

    pub fn rows(&self) -> usize {
        self.n / self.nranks
    }

    pub fn artifact(&self) -> String {
        format!("jacobi_r{}_n{}", self.rows(), self.n)
    }

    fn n_cks(&self) -> u64 {
        (self.iters / self.ckpt_every) as u64
    }

    /// Phase classification: INIT | Iter(i) | Ck(j) | GATHER | VALIDATE.
    fn classify(&self, phase: u64) -> JPhase {
        if phase == 0 {
            return JPhase::Init;
        }
        let e = self.ckpt_every as u64;
        let body = 1 + self.iters as u64 + self.n_cks();
        if phase < body {
            let p = phase - 1;
            let group = p / (e + 1);
            let within = p % (e + 1);
            if within < e {
                JPhase::Iter(group * e + within)
            } else {
                JPhase::Ck(group)
            }
        } else if phase == body {
            JPhase::Gather
        } else {
            JPhase::Validate
        }
    }

    /// Sweep one iteration of this rank's block (with halos attached).
    fn sweep(&self, ctx: &ReplicaCtx, padded: Var) -> Result<Vec<f32>> {
        let rows = self.rows();
        let n = self.n;
        let out = ctx.compute(&self.artifact(), vec![padded], |inputs| {
            let g = inputs[0].buf.as_f32()?;
            // Pure stencil over the padded (rows+2)×n input: out[i][j] =
            // mean of the 4 neighbors; columns handled below by the caller.
            let mut o = vec![0f32; rows * n];
            for i in 0..rows {
                let pi = i + 1;
                for j in 0..n {
                    let left = if j > 0 { g[pi * n + j - 1] } else { 0.0 };
                    let right = if j < n - 1 { g[pi * n + j + 1] } else { 0.0 };
                    o[i * n + j] =
                        0.25 * (g[(pi - 1) * n + j] + g[(pi + 1) * n + j] + left + right);
                }
            }
            Ok(vec![Var::f32(&[rows, n], o)])
        })?;
        Ok(out[0].buf.as_f32()?.to_vec())
    }
}

#[derive(Debug, PartialEq)]
enum JPhase {
    Init,
    Iter(u64),
    Ck(u64),
    Gather,
    Validate,
}

impl AppSpec for JacobiApp {
    fn name(&self) -> &'static str {
        "jacobi"
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    fn n_phases(&self) -> u64 {
        1 + self.iters as u64 + self.n_cks() + 2
    }

    fn phase_name(&self, phase: u64) -> String {
        match self.classify(phase) {
            JPhase::Init => "INIT".into(),
            JPhase::Iter(i) => format!("ITER{i}"),
            JPhase::Ck(j) => format!("CK{j}"),
            JPhase::Gather => "GATHER".into(),
            JPhase::Validate => "VALIDATE".into(),
        }
    }

    fn init_store(&self, rank: usize, seed: u64) -> VarStore {
        let n = self.n;
        let rows = self.rows();
        let full = oracle::gen_matrix(seed.wrapping_mul(17).wrapping_add(3), n, n);
        let block = full[rank * rows * n..(rank + 1) * rows * n].to_vec();
        let mut s = VarStore::new();
        s.insert("grid", Var::f32(&[rows, n], block));
        s.insert("ghost_top", Var::f32(&[n], vec![0.0; n]));
        s.insert("ghost_bot", Var::f32(&[n], vec![0.0; n]));
        if rank == 0 {
            s.insert("G", Var::f32(&[n, n], vec![0.0; n * n]));
        }
        s
    }

    fn run_phase(&self, ctx: &mut ReplicaCtx, phase: u64) -> Result<()> {
        let n = self.n;
        let rows = self.rows();
        let rank = ctx.rank;
        let last = self.nranks - 1;
        match self.classify(phase) {
            JPhase::Init => Ok(()),
            JPhase::Ck(j) => ctx.checkpoint(j, &format!("CK{j}")),
            JPhase::Iter(i) => {
                let site = format!("ITER{i}");
                // --- halo exchange (buffered sends first: no deadlock) ---
                let (top_row, bot_row) = {
                    let g = ctx.store.f32("grid")?;
                    (
                        Var::f32(&[n], g[0..n].to_vec()),
                        Var::f32(&[n], g[(rows - 1) * n..rows * n].to_vec()),
                    )
                };
                if rank > 0 {
                    ctx.sedar_send_value(rank - 1, 7, &top_row, &site)?;
                }
                if rank < last {
                    ctx.sedar_send_value(rank + 1, 8, &bot_row, &site)?;
                }
                if rank > 0 {
                    ctx.sedar_recv(rank - 1, 8, "ghost_top", &site)?;
                }
                if rank < last {
                    ctx.sedar_recv(rank + 1, 7, "ghost_bot", &site)?;
                }
                // --- sweep ---
                let padded = {
                    let g = ctx.store.f32("grid")?;
                    let gt = ctx.store.f32("ghost_top")?;
                    let gb = ctx.store.f32("ghost_bot")?;
                    let mut p = Vec::with_capacity((rows + 2) * n);
                    p.extend_from_slice(gt);
                    p.extend_from_slice(g);
                    p.extend_from_slice(gb);
                    Var::f32(&[rows + 2, n], p)
                };
                let mut new = self.sweep(ctx, padded)?;
                // Fixed (Dirichlet) boundary: restore global edge rows and
                // the two edge columns from the current block.
                {
                    let g = ctx.store.f32("grid")?;
                    if rank == 0 {
                        new[0..n].copy_from_slice(&g[0..n]);
                    }
                    if rank == last {
                        new[(rows - 1) * n..].copy_from_slice(&g[(rows - 1) * n..]);
                    }
                    for i in 0..rows {
                        new[i * n] = g[i * n];
                        new[i * n + n - 1] = g[i * n + n - 1];
                    }
                }
                ctx.store.f32_mut("grid")?.copy_from_slice(&new);
                Ok(())
            }
            JPhase::Gather => {
                let parts = ctx.gather(0, "grid", "GATHER")?;
                if let Some(parts) = parts {
                    let g = ctx.store.f32_mut("G")?;
                    for (r, part) in parts.iter().enumerate() {
                        g[r * rows * n..(r + 1) * rows * n]
                            .copy_from_slice(part.buf.as_f32()?);
                    }
                }
                Ok(())
            }
            JPhase::Validate => {
                if ctx.rank == 0 {
                    ctx.validate_result("G", "VALIDATE")?;
                }
                Ok(())
            }
        }
    }

    fn significant_vars(&self, rank: usize) -> Vec<String> {
        let mut v = vec!["grid".to_string()];
        if rank == 0 {
            v.push("G".to_string());
        }
        v
    }

    fn result_var(&self) -> &'static str {
        "G"
    }

    fn expected_result(&self, seed: u64) -> Vec<f32> {
        let full = oracle::gen_matrix(seed.wrapping_mul(17).wrapping_add(3), self.n, self.n);
        oracle::jacobi_seq(&full, self.n, self.iters)
    }

    fn ckpt_phases(&self) -> Vec<u64> {
        (0..self.n_phases())
            .filter(|p| matches!(self.classify(*p), JPhase::Ck(_)))
            .collect()
    }

    fn artifacts(&self) -> Vec<String> {
        vec![self.artifact()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_layout() {
        let app = JacobiApp::new(64, 4, 6, 3);
        // INIT + 6 iters + 2 cks + GATHER + VALIDATE = 11 phases.
        assert_eq!(app.n_phases(), 11);
        assert_eq!(app.phase_name(0), "INIT");
        assert_eq!(app.phase_name(1), "ITER0");
        assert_eq!(app.phase_name(3), "ITER2");
        assert_eq!(app.phase_name(4), "CK0");
        assert_eq!(app.phase_name(5), "ITER3");
        assert_eq!(app.phase_name(8), "CK1");
        assert_eq!(app.phase_name(9), "GATHER");
        assert_eq!(app.phase_name(10), "VALIDATE");
        assert_eq!(app.ckpt_phases(), vec![4, 8]);
    }

    #[test]
    fn oracle_block_consistency() {
        // The sequential oracle and a manual single-rank sweep agree.
        let app = JacobiApp::new(16, 4, 4, 2);
        let want = app.expected_result(5);
        assert_eq!(want.len(), 256);
        // Boundary preserved by the oracle.
        let full = oracle::gen_matrix(5u64.wrapping_mul(17).wrapping_add(3), 16, 16);
        assert_eq!(want[0], full[0]);
        assert_eq!(want[255], full[255]);
    }
}
