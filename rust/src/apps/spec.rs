//! The application contract: phase-structured, resumable, deterministic
//! message-passing programs.
//!
//! SEDAR's recovery needs to relaunch an application *from a phase
//! boundary* with restored state (the DMTCP restart / user-checkpoint
//! restore paths). Apps therefore describe themselves as an ordered list of
//! **phases**; everything a phase needs must live in the replica's
//! [`VarStore`] (so that a snapshot taken at any checkpoint phase is
//! sufficient to resume). This is the in-library equivalent of DMTCP
//! capturing the whole process image.

use crate::error::Result;
use crate::replica::ReplicaCtx;
use crate::state::VarStore;

/// A deterministic, phase-structured parallel application.
pub trait AppSpec: Send + Sync {
    /// Short name (used for run dirs, artifact names, reports).
    fn name(&self) -> &'static str;

    /// World size (rank 0 is the Master where the pattern has one).
    fn nranks(&self) -> usize;

    /// Number of phases; cursors run `0..n_phases()`.
    fn n_phases(&self) -> u64;

    /// Human name of a phase (`"SCATTER"`, `"CK2"`, …) — used for detection
    /// sites and traces, so it must match what the scenario oracle predicts.
    fn phase_name(&self, phase: u64) -> String;

    /// Fresh phase-0 state for `rank`, generated deterministically from
    /// `seed` (both replicas call this with the same arguments and must get
    /// bit-identical stores).
    fn init_store(&self, rank: usize, seed: u64) -> VarStore;

    /// Execute one phase on this replica.
    fn run_phase(&self, ctx: &mut ReplicaCtx, phase: u64) -> Result<()>;

    /// The variables a user-level checkpoint must capture for `rank`
    /// (§3.3's "set of variables that are significant to the application").
    fn significant_vars(&self, rank: usize) -> Vec<String>;

    /// Name of the final-result variable on rank 0.
    fn result_var(&self) -> &'static str;

    /// Ground-truth final result (computed sequentially, outside the
    /// fault-tolerance machinery) — the end-to-end correctness oracle.
    fn expected_result(&self, seed: u64) -> Vec<f32>;

    /// Cursors of the checkpoint phases, in order (ck number = index).
    fn ckpt_phases(&self) -> Vec<u64>;

    /// AOT artifacts this app's compute needs (warmed by the coordinator;
    /// if any is missing the run falls back to the pure-rust compute path).
    fn artifacts(&self) -> Vec<String> {
        Vec::new()
    }

    /// Cursor of the phase whose name is `name` (convenience for scenario
    /// tables; panics if absent).
    fn cursor_of(&self, name: &str) -> u64 {
        (0..self.n_phases())
            .find(|p| self.phase_name(*p) == name)
            .unwrap_or_else(|| panic!("{}: no phase named {name}", self.name()))
    }
}
