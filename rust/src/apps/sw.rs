//! Pipelined Smith-Waterman DNA sequence alignment (§4.3's third benchmark).
//!
//! The DP matrix (s1 × s2) is decomposed into **column bands**, one per
//! rank; s1 is processed in row blocks that flow through the ranks as a
//! pipeline: rank r computes block b as soon as rank r-1 has produced the
//! frontier (the H values of its band's last column) for block b. This is
//! the paper's *pipeline* communication pattern — long-lived point-to-point
//! streams between neighbors only.
//!
//! Scoring: match +2, mismatch −1, linear gap −1, local alignment (H ≥ 0).
//! Only the similarity *score* is validated at the end, which is why SW has
//! the smallest `T_comp` of Table 3 — our measured-parameters bench
//! reproduces that shape.
//!
//! The block compute runs through the AOT artifact `sw_b<rows>_w<band>`
//! (Layer 1: a Pallas row-update kernel + the max-plus prefix trick — see
//! python/compile/kernels/sw.py); the rust fallback is bit-identical
//! because all cell values are small integers exactly representable in f32.

use crate::apps::oracle;
use crate::apps::spec::AppSpec;
use crate::error::Result;
use crate::replica::ReplicaCtx;
use crate::state::{Var, VarStore};

/// Pipelined Smith-Waterman: `s1` (length m) against `s2` (length m),
/// column bands of width `m / nranks`, row blocks of `block_rows`.
#[derive(Debug, Clone)]
pub struct SwApp {
    /// Sequence length (both sequences).
    pub m: usize,
    pub nranks: usize,
    /// Rows per pipeline block; divides `m`.
    pub block_rows: usize,
    /// Checkpoint after every this many blocks (0 = no mid-run ckpts).
    pub ckpt_every: usize,
}

impl SwApp {
    pub fn new(m: usize, nranks: usize, block_rows: usize, ckpt_every: usize) -> SwApp {
        assert!(m % nranks == 0, "m must divide by nranks");
        assert!(m % block_rows == 0, "m must divide by block_rows");
        let blocks = m / block_rows;
        if ckpt_every > 0 {
            assert!(blocks % ckpt_every == 0, "blocks must divide by ckpt_every");
        }
        SwApp {
            m,
            nranks,
            block_rows,
            ckpt_every,
        }
    }

    pub fn band_width(&self) -> usize {
        self.m / self.nranks
    }

    pub fn n_blocks(&self) -> usize {
        self.m / self.block_rows
    }

    pub fn artifact(&self) -> String {
        format!("sw_b{}_w{}", self.block_rows, self.band_width())
    }

    fn n_cks(&self) -> u64 {
        if self.ckpt_every == 0 {
            0
        } else {
            (self.n_blocks() / self.ckpt_every) as u64
        }
    }

    fn classify(&self, phase: u64) -> SPhase {
        if phase == 0 {
            return SPhase::Init;
        }
        let body = 1 + self.n_blocks() as u64 + self.n_cks();
        if phase < body {
            if self.ckpt_every == 0 {
                return SPhase::Block((phase - 1) as usize);
            }
            let e = self.ckpt_every as u64;
            let p = phase - 1;
            let group = p / (e + 1);
            let within = p % (e + 1);
            if within < e {
                SPhase::Block((group * e + within) as usize)
            } else {
                SPhase::Ck(group)
            }
        } else if phase == body {
            SPhase::Reduce
        } else {
            SPhase::Validate
        }
    }

    fn seed_s1(seed: u64) -> u64 {
        seed.wrapping_mul(101).wrapping_add(11)
    }

    fn seed_s2(seed: u64) -> u64 {
        seed.wrapping_mul(101).wrapping_add(22)
    }

    /// Compute one `block_rows × band_width` DP block.
    ///
    /// Inputs: the block's s1 symbols, the band's s2 symbols, the carried
    /// previous row (H of the last processed row over the band), and the
    /// left frontier `left[0..=block_rows]` where `left[i]` is the left
    /// neighbor's last-column H at global row `row_start - 1 + i` (zeros
    /// for rank 0). Returns (new prev_row, outgoing frontier, block max).
    #[allow(clippy::too_many_arguments)]
    fn compute_block(
        &self,
        ctx: &ReplicaCtx,
        s1_block: Var,
        s2_band: Var,
        prev_row: Var,
        left: Var,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let br = self.block_rows;
        let bw = self.band_width();
        let out = ctx.compute(
            &self.artifact(),
            vec![s1_block, s2_band, prev_row, left],
            |inputs| {
                let s1 = inputs[0].buf.as_f32()?;
                let s2 = inputs[1].buf.as_f32()?;
                let prev0 = inputs[2].buf.as_f32()?;
                let left = inputs[3].buf.as_f32()?;
                let mut prev = prev0.to_vec();
                let mut frontier = vec![0f32; br + 1];
                frontier[0] = prev[bw - 1];
                let mut best = 0f32;
                let mut cur = vec![0f32; bw];
                for i in 0..br {
                    for j in 0..bw {
                        let s = if s1[i] == s2[j] { 2.0 } else { -1.0 };
                        let diag = if j == 0 { left[i] } else { prev[j - 1] };
                        let up = prev[j];
                        let lf = if j == 0 { left[i + 1] } else { cur[j - 1] };
                        cur[j] = (diag + s).max(up - 1.0).max(lf - 1.0).max(0.0);
                        if cur[j] > best {
                            best = cur[j];
                        }
                    }
                    prev.copy_from_slice(&cur);
                    frontier[i + 1] = cur[bw - 1];
                }
                Ok(vec![
                    Var::f32(&[bw], prev),
                    Var::f32(&[br + 1], frontier),
                    Var::f32(&[1], vec![best]),
                ])
            },
        )?;
        Ok((
            out[0].buf.as_f32()?.to_vec(),
            out[1].buf.as_f32()?.to_vec(),
            out[2].buf.as_f32()?[0],
        ))
    }
}

#[derive(Debug, PartialEq)]
enum SPhase {
    Init,
    Block(usize),
    Ck(u64),
    Reduce,
    Validate,
}

impl AppSpec for SwApp {
    fn name(&self) -> &'static str {
        "sw"
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    fn n_phases(&self) -> u64 {
        1 + self.n_blocks() as u64 + self.n_cks() + 2
    }

    fn phase_name(&self, phase: u64) -> String {
        match self.classify(phase) {
            SPhase::Init => "INIT".into(),
            SPhase::Block(b) => format!("BLOCK{b}"),
            SPhase::Ck(j) => format!("CK{j}"),
            SPhase::Reduce => "REDUCE".into(),
            SPhase::Validate => "VALIDATE".into(),
        }
    }

    fn init_store(&self, rank: usize, seed: u64) -> VarStore {
        let bw = self.band_width();
        let s1 = oracle::gen_sequence(Self::seed_s1(seed), self.m);
        let s2 = oracle::gen_sequence(Self::seed_s2(seed), self.m);
        let mut s = VarStore::new();
        // Sequences as f32 so they can feed the XLA kernel directly.
        s.insert(
            "s1",
            Var::f32(&[self.m], s1.iter().map(|&b| b as f32).collect()),
        );
        s.insert(
            "s2_band",
            Var::f32(
                &[bw],
                s2[rank * bw..(rank + 1) * bw].iter().map(|&b| b as f32).collect(),
            ),
        );
        s.insert("prev_row", Var::f32(&[bw], vec![0.0; bw]));
        s.insert("left_col", Var::f32(&[self.block_rows + 1], vec![0.0; self.block_rows + 1]));
        s.insert("local_max", Var::f32(&[1], vec![0.0]));
        if rank == 0 {
            s.insert("score", Var::f32(&[1], vec![0.0]));
        }
        s
    }

    fn run_phase(&self, ctx: &mut ReplicaCtx, phase: u64) -> Result<()> {
        let br = self.block_rows;
        let rank = ctx.rank;
        let last = self.nranks - 1;
        match self.classify(phase) {
            SPhase::Init => Ok(()),
            SPhase::Ck(j) => ctx.checkpoint(j, &format!("CK{j}")),
            SPhase::Block(b) => {
                let site = format!("BLOCK{b}");
                // Receive the left frontier from the pipeline predecessor.
                if rank > 0 {
                    ctx.sedar_recv(rank - 1, 9, "left_col", &site)?;
                } else {
                    // Left boundary of the DP matrix: all zeros.
                    let z = vec![0.0; br + 1];
                    ctx.store.f32_mut("left_col")?.copy_from_slice(&z);
                }
                let (s1_block, s2_band, prev_row, left) = {
                    let s1 = ctx.store.f32("s1")?;
                    (
                        Var::f32(&[br], s1[b * br..(b + 1) * br].to_vec()),
                        ctx.store.get("s2_band")?.clone(),
                        ctx.store.get("prev_row")?.clone(),
                        ctx.store.get("left_col")?.clone(),
                    )
                };
                let (new_prev, frontier, best) =
                    self.compute_block(ctx, s1_block, s2_band, prev_row, left)?;
                ctx.store.f32_mut("prev_row")?.copy_from_slice(&new_prev);
                {
                    let lm = ctx.store.f32_mut("local_max")?;
                    if best > lm[0] {
                        lm[0] = best;
                    }
                }
                // Pass the frontier downstream.
                if rank < last {
                    let f = Var::f32(&[br + 1], frontier);
                    ctx.sedar_send_value(rank + 1, 9, &f, &site)?;
                }
                Ok(())
            }
            SPhase::Reduce => {
                let parts = ctx.gather(0, "local_max", "REDUCE")?;
                if let Some(parts) = parts {
                    let mut best = 0f32;
                    for p in &parts {
                        best = best.max(p.buf.as_f32()?[0]);
                    }
                    ctx.store.f32_mut("score")?[0] = best;
                }
                Ok(())
            }
            SPhase::Validate => {
                if ctx.rank == 0 {
                    ctx.validate_result("score", "VALIDATE")?;
                }
                Ok(())
            }
        }
    }

    fn significant_vars(&self, rank: usize) -> Vec<String> {
        let mut v = vec![
            "s1".to_string(),
            "s2_band".to_string(),
            "prev_row".to_string(),
            "left_col".to_string(),
            "local_max".to_string(),
        ];
        if rank == 0 {
            v.push("score".to_string());
        }
        v
    }

    fn result_var(&self) -> &'static str {
        "score"
    }

    fn expected_result(&self, seed: u64) -> Vec<f32> {
        let s1 = oracle::gen_sequence(Self::seed_s1(seed), self.m);
        let s2 = oracle::gen_sequence(Self::seed_s2(seed), self.m);
        vec![oracle::sw_seq(&s1, &s2)]
    }

    fn ckpt_phases(&self) -> Vec<u64> {
        (0..self.n_phases())
            .filter(|p| matches!(self.classify(*p), SPhase::Ck(_)))
            .collect()
    }

    fn artifacts(&self) -> Vec<String> {
        vec![self.artifact()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_layout_with_ckpts() {
        let app = SwApp::new(64, 4, 16, 2);
        // 4 blocks, ck every 2 → INIT + 4 + 2 + REDUCE + VALIDATE = 9.
        assert_eq!(app.n_phases(), 9);
        assert_eq!(app.phase_name(1), "BLOCK0");
        assert_eq!(app.phase_name(3), "CK0");
        assert_eq!(app.phase_name(6), "CK1");
        assert_eq!(app.phase_name(7), "REDUCE");
        assert_eq!(app.ckpt_phases(), vec![3, 6]);
    }

    #[test]
    fn phase_layout_no_ckpts() {
        let app = SwApp::new(64, 4, 16, 0);
        assert_eq!(app.n_phases(), 7);
        assert_eq!(app.phase_name(4), "BLOCK3");
        assert!(app.ckpt_phases().is_empty());
    }

    #[test]
    fn band_geometry() {
        let app = SwApp::new(128, 4, 32, 0);
        assert_eq!(app.band_width(), 32);
        assert_eq!(app.n_blocks(), 4);
        assert_eq!(app.artifact(), "sw_b32_w32");
    }

    #[test]
    fn block_recurrence_matches_oracle_single_band() {
        // One rank, one band = the full matrix: the block recurrence must
        // reproduce the sequential SW score.
        let app = SwApp::new(32, 1, 8, 0);
        let want = app.expected_result(9)[0];
        // Manually run the block chain like run_phase does.
        let s1 = oracle::gen_sequence(SwApp::seed_s1(9), 32);
        let s2 = oracle::gen_sequence(SwApp::seed_s2(9), 32);
        let s1f: Vec<f32> = s1.iter().map(|&b| b as f32).collect();
        let s2f: Vec<f32> = s2.iter().map(|&b| b as f32).collect();
        let mut prev = vec![0f32; 32];
        let mut best = 0f32;
        for b in 0..4 {
            let left = vec![0f32; 9];
            // Inline the fallback recurrence.
            let mut cur = vec![0f32; 32];
            for i in 0..8 {
                for j in 0..32 {
                    let s = if s1f[b * 8 + i] == s2f[j] { 2.0 } else { -1.0 };
                    let diag = if j == 0 { left[i] } else { prev[j - 1] };
                    let up = prev[j];
                    let lf = if j == 0 { left[i + 1] } else { cur[j - 1] };
                    cur[j] = (diag + s).max(up - 1.0).max(lf - 1.0).max(0.0f32);
                    best = best.max(cur[j]);
                }
                prev.copy_from_slice(&cur);
            }
        }
        assert_eq!(best, want);
    }
}
