//! Run-time instrumentation in **modeled ticks**.
//!
//! Counters here feed the execution-parameter measurements of Table 3
//! (`f_d`, `t_cs`, `t_ca`, `T_comp`, …) and the perf pass of
//! EXPERIMENTS.md §Perf. Everything is atomic so replica threads update
//! without locks on the hot path.
//!
//! Since PR 7 the module is clocked by the run's [`Clock`], never by
//! `Instant`: elapsed time accumulates in ticks (1 tick = 1 ns of modeled
//! time), so under `--clock virtual` every tick field is a deterministic
//! replayable quantity — byte-identical across repeat runs, `--jobs`
//! widths and shard splits — and the module sits inside the CI
//! wall-clock grep gate instead of being exempt from it.
//!
//! Two families of fields coexist in [`MetricsSnapshot`]:
//!
//! * **work counters** (`compare_bytes`, `sync_events`, `sys_ckpts`, …):
//!   pure counts of work performed. Identical under the wall and virtual
//!   clocks, which is why the report's "Table 3 (measured)" section
//!   derives from these alone (via the [`cost`] constants);
//! * **tick accumulators** (`*_ticks`): modeled time spent per phase.
//!   Deterministic under the virtual clock, physical under the wall
//!   clock — excluded from the deterministic report for the same reason
//!   wall time is.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::clock::{Clock, Tick};

/// The modeled per-unit costs that convert work counters into Table-3
/// time parameters. One tick is one modeled nanosecond; the constants are
/// calibration knobs of the reproduction, not measurements of this host —
/// what matters is that they are fixed, documented, and applied
/// identically to every cell, so measured-vs-model comparisons are
/// apples-to-apples across the sweep.
pub mod cost {
    /// Ticks per byte run through the replica comparator (detection).
    pub const COMPARE_TICKS_PER_BYTE: u64 = 1;
    /// Ticks per replica rendezvous event (sync latency).
    pub const SYNC_TICKS_PER_EVENT: u64 = 2_000;
    /// Ticks per byte serialized into a checkpoint (system or user).
    pub const CKPT_TICKS_PER_BYTE: u64 = 4;
    /// Ticks per compute-engine launch (the workload quantum).
    pub const EXEC_TICKS_PER_LAUNCH: u64 = 1_000_000;
}

/// The instrumented phases of a SEDAR run, one per span/counter family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Compute-engine execution (XLA or fallback).
    Exec,
    /// Replica-pair buffer comparison (detection cost).
    Compare,
    /// Blocked in replica rendezvous (sync cost).
    Sync,
    /// Serializing + writing a system-level checkpoint.
    SysCkpt,
    /// Storing + validating a user-level checkpoint.
    UserCkpt,
    /// Coordinator recovery decision + chain truncation.
    Rollback,
}

impl Phase {
    pub const ALL: [Phase; 6] = [
        Phase::Exec,
        Phase::Compare,
        Phase::Sync,
        Phase::SysCkpt,
        Phase::UserCkpt,
        Phase::Rollback,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Phase::Exec => "exec",
            Phase::Compare => "compare",
            Phase::Sync => "sync",
            Phase::SysCkpt => "sys-ckpt",
            Phase::UserCkpt => "user-ckpt",
            Phase::Rollback => "rollback",
        }
    }

    /// Stable ordinal, persisted in trace logs — frozen once released.
    pub fn ordinal(self) -> u8 {
        match self {
            Phase::Exec => 0,
            Phase::Compare => 1,
            Phase::Sync => 2,
            Phase::SysCkpt => 3,
            Phase::UserCkpt => 4,
            Phase::Rollback => 5,
        }
    }

    /// Inverse of [`Phase::ordinal`] (trace-log decoding).
    pub fn from_ordinal(ord: u8) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.ordinal() == ord)
    }
}

/// One begin/end tick pair recorded by a [`ScopedTimer`]: which phase ran
/// where, from when to when, in modeled ticks since the run started.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub phase: Phase,
    /// Rank that ran the phase; `u32::MAX` = the coordinator itself.
    pub rank: u32,
    pub replica: u32,
    pub begin: Tick,
    pub end: Tick,
}

/// Sort spans into their canonical order: by begin tick, then rank,
/// replica, phase and end tick. The sort is stable, so same-key spans
/// (possible only within one thread) keep their per-thread push order —
/// cross-thread interleaving of the shared vector can never leak into the
/// serialized log.
pub fn canonicalize_spans(spans: &mut [Span]) {
    spans.sort_by_key(|s| (s.begin, s.rank, s.replica, s.phase.ordinal(), s.end));
}

/// Shared counters for one execution run (across attempts), clocked by the
/// run's [`Clock`].
#[derive(Debug)]
pub struct RunMetrics {
    clock: Clock,
    /// Tick at which the run (and its tick origin) started.
    start: Tick,
    /// Ticks spent in replica-pair buffer comparisons (detection cost).
    pub compare_ticks: AtomicU64,
    /// Bytes run through the comparator.
    pub compare_bytes: AtomicU64,
    /// Ticks spent blocked in replica rendezvous (sync cost).
    pub sync_ticks: AtomicU64,
    /// Number of rendezvous events.
    pub sync_events: AtomicU64,
    /// Ticks spent serializing + writing system-level checkpoints.
    pub sys_ckpt_ticks: AtomicU64,
    /// Bytes written to system-level checkpoints.
    pub sys_ckpt_bytes: AtomicU64,
    /// Number of system-level checkpoints stored.
    pub sys_ckpts: AtomicU64,
    /// Same, user-level.
    pub user_ckpt_ticks: AtomicU64,
    pub user_ckpt_bytes: AtomicU64,
    pub user_ckpts: AtomicU64,
    /// Ticks in compute-engine execution (XLA or fallback).
    pub exec_ticks: AtomicU64,
    /// Number of compute launches.
    pub execs: AtomicU64,
    /// Ticks spent in coordinator rollback decisions.
    pub rollback_ticks: AtomicU64,
    /// Number of rollback decisions taken.
    pub rollbacks: AtomicU64,
    /// Begin/end tick pairs recorded by [`ScopedTimer`]s.
    spans: Mutex<Vec<Span>>,
}

impl RunMetrics {
    /// Metrics clocked by the run's clock; tick origin = `clock.now()`.
    pub fn new(clock: Clock) -> Self {
        let start = clock.now();
        RunMetrics {
            clock,
            start,
            compare_ticks: AtomicU64::new(0),
            compare_bytes: AtomicU64::new(0),
            sync_ticks: AtomicU64::new(0),
            sync_events: AtomicU64::new(0),
            sys_ckpt_ticks: AtomicU64::new(0),
            sys_ckpt_bytes: AtomicU64::new(0),
            sys_ckpts: AtomicU64::new(0),
            user_ckpt_ticks: AtomicU64::new(0),
            user_ckpt_bytes: AtomicU64::new(0),
            user_ckpts: AtomicU64::new(0),
            exec_ticks: AtomicU64::new(0),
            execs: AtomicU64::new(0),
            rollback_ticks: AtomicU64::new(0),
            rollbacks: AtomicU64::new(0),
            spans: Mutex::new(Vec::new()),
        }
    }

    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Ticks elapsed since the run started (the span time base).
    pub fn now(&self) -> Tick {
        self.clock.since(self.start).as_nanos() as Tick
    }

    /// Open a phase span: ticks accumulate into the phase counter and a
    /// begin/end [`Span`] is recorded when the returned timer drops.
    pub fn span(&self, phase: Phase, rank: u32, replica: u32) -> ScopedTimer<'_> {
        ScopedTimer {
            metrics: self,
            phase,
            rank,
            replica,
            begin: self.now(),
        }
    }

    fn phase_counter(&self, phase: Phase) -> &AtomicU64 {
        match phase {
            Phase::Exec => &self.exec_ticks,
            Phase::Compare => &self.compare_ticks,
            Phase::Sync => &self.sync_ticks,
            Phase::SysCkpt => &self.sys_ckpt_ticks,
            Phase::UserCkpt => &self.user_ckpt_ticks,
            Phase::Rollback => &self.rollback_ticks,
        }
    }

    /// Average cost of storing one system-level checkpoint — the measured
    /// `t_cs` of Table 3, in modeled time.
    pub fn t_cs(&self) -> Option<Duration> {
        let n = self.sys_ckpts.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        Some(Duration::from_nanos(
            self.sys_ckpt_ticks.load(Ordering::Relaxed) / n,
        ))
    }

    /// Average cost of one user-level checkpoint — the measured `t_ca`.
    pub fn t_ca(&self) -> Option<Duration> {
        let n = self.user_ckpts.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        Some(Duration::from_nanos(
            self.user_ckpt_ticks.load(Ordering::Relaxed) / n,
        ))
    }

    /// Snapshot all counters (for reports).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            compare_ticks: self.compare_ticks.load(Ordering::Relaxed),
            compare_bytes: self.compare_bytes.load(Ordering::Relaxed),
            sync_ticks: self.sync_ticks.load(Ordering::Relaxed),
            sync_events: self.sync_events.load(Ordering::Relaxed),
            sys_ckpt_ticks: self.sys_ckpt_ticks.load(Ordering::Relaxed),
            sys_ckpt_bytes: self.sys_ckpt_bytes.load(Ordering::Relaxed),
            sys_ckpts: self.sys_ckpts.load(Ordering::Relaxed),
            user_ckpt_ticks: self.user_ckpt_ticks.load(Ordering::Relaxed),
            user_ckpt_bytes: self.user_ckpt_bytes.load(Ordering::Relaxed),
            user_ckpts: self.user_ckpts.load(Ordering::Relaxed),
            exec_ticks: self.exec_ticks.load(Ordering::Relaxed),
            execs: self.execs.load(Ordering::Relaxed),
            rollback_ticks: self.rollback_ticks.load(Ordering::Relaxed),
            rollbacks: self.rollbacks.load(Ordering::Relaxed),
        }
    }

    /// Drain the recorded spans in canonical order.
    pub fn take_spans(&self) -> Vec<Span> {
        let mut spans = std::mem::take(&mut *self.spans.lock().unwrap());
        canonicalize_spans(&mut spans);
        spans
    }
}

/// Plain-data copy of [`RunMetrics`] at a point in time. All `*_ticks`
/// fields are modeled ticks (1 tick = 1 ns); the rest are work counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub compare_ticks: u64,
    pub compare_bytes: u64,
    pub sync_ticks: u64,
    pub sync_events: u64,
    pub sys_ckpt_ticks: u64,
    pub sys_ckpt_bytes: u64,
    pub sys_ckpts: u64,
    pub user_ckpt_ticks: u64,
    pub user_ckpt_bytes: u64,
    pub user_ckpts: u64,
    pub exec_ticks: u64,
    pub execs: u64,
    pub rollback_ticks: u64,
    pub rollbacks: u64,
}

impl MetricsSnapshot {
    /// Accumulate another snapshot into this one (report aggregation).
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.compare_ticks += other.compare_ticks;
        self.compare_bytes += other.compare_bytes;
        self.sync_ticks += other.sync_ticks;
        self.sync_events += other.sync_events;
        self.sys_ckpt_ticks += other.sys_ckpt_ticks;
        self.sys_ckpt_bytes += other.sys_ckpt_bytes;
        self.sys_ckpts += other.sys_ckpts;
        self.user_ckpt_ticks += other.user_ckpt_ticks;
        self.user_ckpt_bytes += other.user_ckpt_bytes;
        self.user_ckpts += other.user_ckpts;
        self.exec_ticks += other.exec_ticks;
        self.execs += other.execs;
        self.rollback_ticks += other.rollback_ticks;
        self.rollbacks += other.rollbacks;
    }

    /// Modeled execution time: launches × per-launch cost.
    pub fn modeled_exec_ticks(&self) -> u64 {
        self.execs * cost::EXEC_TICKS_PER_LAUNCH
    }

    /// Modeled detection time: comparator bytes + rendezvous events.
    pub fn modeled_detect_ticks(&self) -> u64 {
        self.compare_bytes * cost::COMPARE_TICKS_PER_BYTE
            + self.sync_events * cost::SYNC_TICKS_PER_EVENT
    }

    /// Modeled total system-checkpoint time.
    pub fn modeled_sys_ckpt_ticks(&self) -> u64 {
        self.sys_ckpt_bytes * cost::CKPT_TICKS_PER_BYTE
    }

    /// Modeled total user-checkpoint time.
    pub fn modeled_user_ckpt_ticks(&self) -> u64 {
        self.user_ckpt_bytes * cost::CKPT_TICKS_PER_BYTE
    }

    /// Measured `t_cs` of Table 3: modeled ticks per system checkpoint.
    /// `None` if the cell stored no system checkpoints.
    pub fn measured_t_cs_ticks(&self) -> Option<u64> {
        (self.sys_ckpts > 0).then(|| self.modeled_sys_ckpt_ticks() / self.sys_ckpts)
    }

    /// Measured `t_ca` of Table 3: modeled ticks per user checkpoint.
    pub fn measured_t_ca_ticks(&self) -> Option<u64> {
        (self.user_ckpts > 0).then(|| self.modeled_user_ckpt_ticks() / self.user_ckpts)
    }

    pub fn markdown(&self) -> String {
        format!(
            "| metric | value |\n|---|---|\n\
             | comparisons | {} in {} |\n\
             | sync events | {} blocking {} |\n\
             | system ckpts | {} ({}, {}) |\n\
             | user ckpts | {} ({}, {}) |\n\
             | compute launches | {} ({}) |\n\
             | rollbacks | {} ({}) |\n",
            crate::util::human_bytes(self.compare_bytes),
            crate::util::human_duration(Duration::from_nanos(self.compare_ticks)),
            self.sync_events,
            crate::util::human_duration(Duration::from_nanos(self.sync_ticks)),
            self.sys_ckpts,
            crate::util::human_bytes(self.sys_ckpt_bytes),
            crate::util::human_duration(Duration::from_nanos(self.sys_ckpt_ticks)),
            self.user_ckpts,
            crate::util::human_bytes(self.user_ckpt_bytes),
            crate::util::human_duration(Duration::from_nanos(self.user_ckpt_ticks)),
            self.execs,
            crate::util::human_duration(Duration::from_nanos(self.exec_ticks)),
            self.rollbacks,
            crate::util::human_duration(Duration::from_nanos(self.rollback_ticks)),
        )
    }
}

/// RAII phase timer: on drop, adds its elapsed modeled ticks to the phase
/// counter and records a begin/end [`Span`].
pub struct ScopedTimer<'a> {
    metrics: &'a RunMetrics,
    phase: Phase,
    rank: u32,
    replica: u32,
    begin: Tick,
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        let end = self.metrics.now();
        self.metrics
            .phase_counter(self.phase)
            .fetch_add(end.saturating_sub(self.begin), Ordering::Relaxed);
        self.metrics.spans.lock().unwrap().push(Span {
            phase: self.phase,
            rank: self.rank,
            replica: self.replica,
            begin: self.begin,
            end,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A virtual clock this thread participates in, so `sleep` advances
    /// modeled time deterministically (the trace-test idiom).
    fn vclock() -> (Clock, crate::util::clock::ClockGuard) {
        let c = Clock::virtual_clock();
        c.join_n(1);
        let g = c.guard();
        (c, g)
    }

    #[test]
    fn span_accumulates_modeled_ticks_deterministically() {
        let (c, _g) = vclock();
        let m = RunMetrics::new(c.clone());
        {
            let _t = m.span(Phase::SysCkpt, 0, 1);
            c.sleep(Duration::from_millis(5));
        }
        assert_eq!(m.sys_ckpt_ticks.load(Ordering::Relaxed), 5_000_000);
        let spans = m.take_spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].phase, Phase::SysCkpt);
        assert_eq!((spans[0].rank, spans[0].replica), (0, 1));
        assert_eq!(spans[0].end - spans[0].begin, 5_000_000);
        // Drained: a second take is empty.
        assert!(m.take_spans().is_empty());
    }

    #[test]
    fn spans_canonicalize_by_begin_then_rank() {
        let mut spans = vec![
            Span { phase: Phase::Sync, rank: 1, replica: 0, begin: 7, end: 9 },
            Span { phase: Phase::Exec, rank: 0, replica: 0, begin: 7, end: 8 },
            Span { phase: Phase::Exec, rank: 0, replica: 0, begin: 3, end: 5 },
        ];
        canonicalize_spans(&mut spans);
        assert_eq!(spans[0].begin, 3);
        assert_eq!((spans[1].rank, spans[2].rank), (0, 1));
    }

    #[test]
    fn t_cs_averages() {
        let (c, _g) = vclock();
        let m = RunMetrics::new(c);
        assert!(m.t_cs().is_none());
        m.sys_ckpts.store(4, Ordering::Relaxed);
        m.sys_ckpt_ticks.store(4_000_000, Ordering::Relaxed);
        assert_eq!(m.t_cs().unwrap(), Duration::from_millis(1));
    }

    #[test]
    fn snapshot_copies_and_compares() {
        let (c, _g) = vclock();
        let m = RunMetrics::new(c);
        m.add(&m.compare_bytes, 128);
        let s = m.snapshot();
        assert_eq!(s.compare_bytes, 128);
        assert!(s.markdown().contains("128 B"));
        // Snapshots are plain data: equality is field-for-field.
        assert_eq!(s, m.snapshot());
        assert_ne!(s, MetricsSnapshot::default());
    }

    #[test]
    fn merge_sums_every_field() {
        let a = MetricsSnapshot {
            compare_ticks: 1,
            compare_bytes: 2,
            sync_ticks: 3,
            sync_events: 4,
            sys_ckpt_ticks: 5,
            sys_ckpt_bytes: 6,
            sys_ckpts: 7,
            user_ckpt_ticks: 8,
            user_ckpt_bytes: 9,
            user_ckpts: 10,
            exec_ticks: 11,
            execs: 12,
            rollback_ticks: 13,
            rollbacks: 14,
        };
        let mut sum = a.clone();
        sum.merge(&a);
        assert_eq!(sum.compare_ticks, 2);
        assert_eq!(sum.user_ckpts, 20);
        assert_eq!(sum.rollbacks, 28);
    }

    #[test]
    fn modeled_table3_values_derive_from_work_counters() {
        let s = MetricsSnapshot {
            compare_bytes: 1_000,
            sync_events: 3,
            sys_ckpt_bytes: 400,
            sys_ckpts: 2,
            user_ckpt_bytes: 100,
            user_ckpts: 1,
            execs: 4,
            ..MetricsSnapshot::default()
        };
        assert_eq!(s.modeled_exec_ticks(), 4 * cost::EXEC_TICKS_PER_LAUNCH);
        assert_eq!(
            s.modeled_detect_ticks(),
            1_000 * cost::COMPARE_TICKS_PER_BYTE + 3 * cost::SYNC_TICKS_PER_EVENT
        );
        assert_eq!(s.measured_t_cs_ticks(), Some(200 * cost::CKPT_TICKS_PER_BYTE));
        assert_eq!(s.measured_t_ca_ticks(), Some(100 * cost::CKPT_TICKS_PER_BYTE));
        assert_eq!(MetricsSnapshot::default().measured_t_cs_ticks(), None);
    }

    #[test]
    fn phase_ordinals_roundtrip() {
        for p in Phase::ALL {
            assert_eq!(Phase::from_ordinal(p.ordinal()), Some(p));
            assert!(!p.label().is_empty());
        }
        assert_eq!(Phase::from_ordinal(99), None);
    }
}
