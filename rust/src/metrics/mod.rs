//! Run-time instrumentation.
//!
//! Counters here feed the execution-parameter measurements of Table 3
//! (`f_d`, `t_cs`, `t_ca`, `T_comp`, …) and the perf pass of
//! EXPERIMENTS.md §Perf. Everything is atomic so replica threads update
//! without locks on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Shared counters for one execution attempt.
#[derive(Debug, Default)]
pub struct RunMetrics {
    /// Nanoseconds spent in replica-pair buffer comparisons (detection cost).
    pub compare_ns: AtomicU64,
    /// Bytes run through the comparator.
    pub compare_bytes: AtomicU64,
    /// Nanoseconds spent blocked in replica rendezvous (sync cost).
    pub sync_ns: AtomicU64,
    /// Number of rendezvous events.
    pub sync_events: AtomicU64,
    /// Nanoseconds spent serializing + writing system-level checkpoints.
    pub sys_ckpt_ns: AtomicU64,
    /// Bytes written to system-level checkpoints.
    pub sys_ckpt_bytes: AtomicU64,
    /// Number of system-level checkpoints stored (this attempt).
    pub sys_ckpts: AtomicU64,
    /// Same, user-level.
    pub user_ckpt_ns: AtomicU64,
    pub user_ckpt_bytes: AtomicU64,
    pub user_ckpts: AtomicU64,
    /// Nanoseconds in compute-engine execution (XLA or fallback).
    pub exec_ns: AtomicU64,
    /// Number of compute launches.
    pub execs: AtomicU64,
}

impl RunMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn add_duration(&self, counter: &AtomicU64, d: Duration) {
        counter.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Average cost of storing one system-level checkpoint — the measured
    /// `t_cs` of Table 3.
    pub fn t_cs(&self) -> Option<Duration> {
        let n = self.sys_ckpts.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        Some(Duration::from_nanos(
            self.sys_ckpt_ns.load(Ordering::Relaxed) / n,
        ))
    }

    /// Average cost of one user-level checkpoint — the measured `t_ca`.
    pub fn t_ca(&self) -> Option<Duration> {
        let n = self.user_ckpts.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        Some(Duration::from_nanos(
            self.user_ckpt_ns.load(Ordering::Relaxed) / n,
        ))
    }

    /// Snapshot all counters (for reports).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            compare_ns: self.compare_ns.load(Ordering::Relaxed),
            compare_bytes: self.compare_bytes.load(Ordering::Relaxed),
            sync_ns: self.sync_ns.load(Ordering::Relaxed),
            sync_events: self.sync_events.load(Ordering::Relaxed),
            sys_ckpt_ns: self.sys_ckpt_ns.load(Ordering::Relaxed),
            sys_ckpt_bytes: self.sys_ckpt_bytes.load(Ordering::Relaxed),
            sys_ckpts: self.sys_ckpts.load(Ordering::Relaxed),
            user_ckpt_ns: self.user_ckpt_ns.load(Ordering::Relaxed),
            user_ckpt_bytes: self.user_ckpt_bytes.load(Ordering::Relaxed),
            user_ckpts: self.user_ckpts.load(Ordering::Relaxed),
            exec_ns: self.exec_ns.load(Ordering::Relaxed),
            execs: self.execs.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of [`RunMetrics`] at a point in time.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub compare_ns: u64,
    pub compare_bytes: u64,
    pub sync_ns: u64,
    pub sync_events: u64,
    pub sys_ckpt_ns: u64,
    pub sys_ckpt_bytes: u64,
    pub sys_ckpts: u64,
    pub user_ckpt_ns: u64,
    pub user_ckpt_bytes: u64,
    pub user_ckpts: u64,
    pub exec_ns: u64,
    pub execs: u64,
}

impl MetricsSnapshot {
    pub fn markdown(&self) -> String {
        format!(
            "| metric | value |\n|---|---|\n\
             | comparisons | {} in {} |\n\
             | sync events | {} blocking {} |\n\
             | system ckpts | {} ({}, {}) |\n\
             | user ckpts | {} ({}, {}) |\n\
             | compute launches | {} ({}) |\n",
            crate::util::human_bytes(self.compare_bytes),
            crate::util::human_duration(Duration::from_nanos(self.compare_ns)),
            self.sync_events,
            crate::util::human_duration(Duration::from_nanos(self.sync_ns)),
            self.sys_ckpts,
            crate::util::human_bytes(self.sys_ckpt_bytes),
            crate::util::human_duration(Duration::from_nanos(self.sys_ckpt_ns)),
            self.user_ckpts,
            crate::util::human_bytes(self.user_ckpt_bytes),
            crate::util::human_duration(Duration::from_nanos(self.user_ckpt_ns)),
            self.execs,
            crate::util::human_duration(Duration::from_nanos(self.exec_ns)),
        )
    }
}

/// RAII timer that adds its elapsed time to an atomic counter on drop.
pub struct ScopedTimer<'a> {
    counter: &'a AtomicU64,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(counter: &'a AtomicU64) -> Self {
        ScopedTimer {
            counter,
            start: Instant::now(),
        }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.counter
            .fetch_add(self.start.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_timer_accumulates() {
        let c = AtomicU64::new(0);
        {
            let _t = ScopedTimer::new(&c);
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(c.load(Ordering::Relaxed) >= 4_000_000);
    }

    #[test]
    fn t_cs_averages() {
        let m = RunMetrics::new();
        assert!(m.t_cs().is_none());
        m.sys_ckpts.store(4, Ordering::Relaxed);
        m.sys_ckpt_ns.store(4_000_000, Ordering::Relaxed);
        assert_eq!(m.t_cs().unwrap(), Duration::from_millis(1));
    }

    #[test]
    fn snapshot_copies() {
        let m = RunMetrics::new();
        m.add(&m.compare_bytes, 128);
        let s = m.snapshot();
        assert_eq!(s.compare_bytes, 128);
        assert!(s.markdown().contains("128 B"));
    }
}
