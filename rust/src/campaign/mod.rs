//! The parallel campaign engine: the 64-scenario workfault catalog (§4.1)
//! swept across the three benchmark applications and the three SEDAR
//! protection strategies, fanned over a bounded worker pool.
//!
//! The paper validates SEDAR by exhaustively exercising every scenario of
//! the workfault against each application and protection level (§4.1–§4.2).
//! This module makes that sweep a first-class subsystem:
//!
//! * [`CampaignSpec`] names the cross-product to run (scenarios × apps ×
//!   strategies × collective implementations, plus the beyond-paper
//!   validation-mode and faults-per-cell axes) and the base [`RunConfig`]
//!   every task derives from;
//! * [`shard`] executes one task in an isolated `SedarRun` world, with a
//!   deterministic per-task seed derived as
//!   `hash(campaign_seed, scenario, app, strategy, collectives,
//!   validation, faults)` — no wall-clock in any decision path;
//! * [`scheduler`] fans tasks across `jobs` workers pulling from a shared
//!   queue, all worlds borrowing one injected engine handle
//!   ([`crate::coordinator::RunDeps`]);
//! * [`aggregate`] merges per-task outcomes in task order — independent of
//!   completion order — into the paper's Table-2-style report rows and a
//!   campaign-level verdict against the §4.1 prediction oracle.
//!
//! Determinism contract: the same spec (seed, filters) produces a
//! byte-identical [`aggregate::CampaignReport::deterministic_report`]
//! regardless of `jobs` (`rust/tests/campaign_determinism.rs`) — and, via
//! [`crate::fleet`], regardless of how the sweep is split into
//! multi-process shards (`rust/tests/fleet_shard_equivalence.rs`).

pub mod aggregate;
pub mod scheduler;
pub mod shard;

pub use aggregate::CampaignReport;
pub use scheduler::{run_campaign, run_tasks};
pub use shard::{CampaignTask, TaskOutcome};

use std::sync::Arc;

use crate::apps::spec::AppSpec;
use crate::apps::{JacobiApp, MatmulApp, SwApp};
use crate::config::{CollectiveImpl, RunConfig, Strategy};
use crate::detect::ValidationMode;
use crate::error::{Result, SedarError};
use crate::faultnet::NetFaultMode;
use crate::util::clock::ClockMode;
use crate::util::prng::SplitMix64;
use crate::workfault::{self, Scenario};

/// One enum axis of the sweep, described once: its filter key, the full
/// decodable value domain (a superset of the default sweep set — e.g. the
/// strategy axis can decode `Baseline` from old persisted records even
/// though the sweep never schedules it), and the ordinal/parse/label
/// functions every consumer (seed folding, WAL codecs, filters, report
/// rows) shares.
///
/// Adding an axis value means extending the enum, its `parse`/`label`
/// arms and the `domain` slice — the roundtrip test below checks nothing
/// was missed; there is no per-consumer match to keep in sync.
pub struct Axis<T: Copy + PartialEq + 'static> {
    /// Filter key (`app=`, `strategy=`, …) in `apply_filter` strings.
    pub key: &'static str,
    /// Every decodable value, in ordinal order.
    pub domain: &'static [T],
    /// Stable ordinal, folded into per-task seeds and persisted in shard
    /// WALs — frozen forever once released.
    pub ordinal: fn(T) -> u64,
    /// Parse a filter/CLI spelling.
    pub parse: fn(&str) -> Result<T>,
    /// Short label for report rows.
    pub label: fn(T) -> &'static str,
}

impl<T: Copy + PartialEq + 'static> Axis<T> {
    /// Inverse of `ordinal` (WAL record decoding): scans `domain`.
    pub fn from_ordinal(&self, ord: u64) -> Option<T> {
        self.domain.iter().copied().find(|v| (self.ordinal)(*v) == ord)
    }
}

/// Which benchmark application a campaign task drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CampaignApp {
    Matmul,
    Jacobi,
    Sw,
}

impl CampaignApp {
    pub const ALL: [CampaignApp; 3] = [CampaignApp::Matmul, CampaignApp::Jacobi, CampaignApp::Sw];

    pub fn label(self) -> &'static str {
        match self {
            CampaignApp::Matmul => "matmul",
            CampaignApp::Jacobi => "jacobi",
            CampaignApp::Sw => "sw",
        }
    }

    pub fn parse(s: &str) -> Result<CampaignApp> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "matmul" => CampaignApp::Matmul,
            "jacobi" => CampaignApp::Jacobi,
            "sw" => CampaignApp::Sw,
            other => {
                return Err(SedarError::Config(format!(
                    "unknown app '{other}' (matmul|jacobi|sw)"
                )))
            }
        })
    }

    /// Stable ordinal, folded into the per-task seed and persisted in shard
    /// WALs ([`crate::fleet::wal`]).
    pub fn ordinal(self) -> u64 {
        match self {
            CampaignApp::Matmul => 0,
            CampaignApp::Jacobi => 1,
            CampaignApp::Sw => 2,
        }
    }

    /// Inverse of [`CampaignApp::ordinal`] (WAL record decoding).
    pub fn from_ordinal(ord: u64) -> Option<CampaignApp> {
        APP_AXIS.from_ordinal(ord)
    }

    /// The campaign-geometry instance: small enough that the full
    /// 1152-task sweep completes in minutes, large enough that every
    /// scenario is live
    /// (matmul needs ≥ 2 workers for the catalog; jacobi/sw need mid-run
    /// checkpoints for the recovery strategies to differ).
    pub fn instantiate(self) -> Arc<dyn AppSpec> {
        match self {
            CampaignApp::Matmul => Arc::new(campaign_matmul()),
            CampaignApp::Jacobi => Arc::new(JacobiApp::new(64, 4, 8, 4)),
            CampaignApp::Sw => Arc::new(SwApp::new(64, 4, 16, 2)),
        }
    }
}

/// The matmul geometry the scenario catalog is materialized over.
pub fn campaign_matmul() -> MatmulApp {
    MatmulApp::new(64, 4)
}

/// The three protection strategies the sweep covers (§4.2). The baseline is
/// excluded: it has no detection machinery to validate.
pub const STRATEGIES: [Strategy; 3] = [
    Strategy::DetectOnly,
    Strategy::SysCkpt,
    Strategy::UserCkpt,
];

/// Both collective implementations, in sweep order (§4.2: the functional
/// point-to-point validation first, then the optimized native one).
pub const COLLECTIVES: [CollectiveImpl; 2] =
    [CollectiveImpl::PointToPoint, CollectiveImpl::Native];

/// The app axis. `domain` doubles as the default sweep set
/// ([`CampaignApp::ALL`]).
pub static APP_AXIS: Axis<CampaignApp> = Axis {
    key: "app",
    domain: &CampaignApp::ALL,
    ordinal: CampaignApp::ordinal,
    parse: CampaignApp::parse,
    label: CampaignApp::label,
};

/// The strategy axis. The domain includes `Baseline` (old persisted
/// records may encode it) even though the sweep set [`STRATEGIES`]
/// excludes it.
pub static STRATEGY_AXIS: Axis<Strategy> = Axis {
    key: "strategy",
    domain: &[
        Strategy::Baseline,
        Strategy::DetectOnly,
        Strategy::SysCkpt,
        Strategy::UserCkpt,
    ],
    ordinal: strategy_ordinal,
    parse: Strategy::parse,
    label: strategy_label,
};

/// The collective-implementation axis (§4.2).
pub static COLLECTIVES_AXIS: Axis<CollectiveImpl> = Axis {
    key: "collectives",
    domain: &COLLECTIVES,
    ordinal: collective_ordinal,
    parse: CollectiveImpl::parse,
    label: CollectiveImpl::label,
};

/// The validation-mode axis (beyond-paper).
pub static VALIDATION_AXIS: Axis<ValidationMode> = Axis {
    key: "validation",
    domain: &[ValidationMode::Full, ValidationMode::Sha256],
    ordinal: validation_ordinal,
    parse: ValidationMode::parse,
    label: ValidationMode::label,
};

/// The network-fault axis (beyond-paper): which transport perturbation
/// family each cell's world runs under ([`crate::faultnet`]). The default
/// sweep set is `[None]` — the fault-free 1152-task geometry — so the
/// axis only widens a sweep when asked for (`netfault=mixed`, …).
pub static NETFAULT_AXIS: Axis<NetFaultMode> = Axis {
    key: "netfault",
    domain: &NetFaultMode::ALL,
    ordinal: netfault_ordinal,
    parse: NetFaultMode::parse,
    label: netfault_label,
};

/// Stable strategy ordinal, folded into the per-task seed.
pub fn strategy_ordinal(s: Strategy) -> u64 {
    match s {
        Strategy::Baseline => 0,
        Strategy::DetectOnly => 1,
        Strategy::SysCkpt => 2,
        Strategy::UserCkpt => 3,
    }
}

/// Inverse of [`strategy_ordinal`] (WAL record decoding).
pub fn strategy_from_ordinal(ord: u64) -> Option<Strategy> {
    STRATEGY_AXIS.from_ordinal(ord)
}

/// Short label for report rows and filters (see [`Strategy::label`]).
pub fn strategy_label(s: Strategy) -> &'static str {
    s.label()
}

/// Stable collectives ordinal, folded into the per-task seed.
pub fn collective_ordinal(c: CollectiveImpl) -> u64 {
    match c {
        CollectiveImpl::PointToPoint => 0,
        CollectiveImpl::Native => 1,
    }
}

/// Inverse of [`collective_ordinal`] (WAL record decoding).
pub fn collective_from_ordinal(ord: u64) -> Option<CollectiveImpl> {
    COLLECTIVES_AXIS.from_ordinal(ord)
}

/// Short label for report rows and filters (see [`CollectiveImpl::label`]).
pub fn collective_label(c: CollectiveImpl) -> &'static str {
    c.label()
}

/// Stable validation-mode ordinal, folded into the per-task seed.
pub fn validation_ordinal(v: ValidationMode) -> u64 {
    match v {
        ValidationMode::Full => 0,
        ValidationMode::Sha256 => 1,
    }
}

/// Inverse of [`validation_ordinal`] (WAL record decoding).
pub fn validation_from_ordinal(ord: u64) -> Option<ValidationMode> {
    VALIDATION_AXIS.from_ordinal(ord)
}

/// Short label for report rows and filters (see [`ValidationMode::label`]).
pub fn validation_label(v: ValidationMode) -> &'static str {
    v.label()
}

/// Stable netfault ordinal, folded into the per-task seed.
pub fn netfault_ordinal(m: NetFaultMode) -> u64 {
    m.ordinal() as u64
}

/// Inverse of [`netfault_ordinal`] (WAL record decoding).
pub fn netfault_from_ordinal(ord: u64) -> Option<NetFaultMode> {
    NETFAULT_AXIS.from_ordinal(ord)
}

/// Short label for report rows and filters (see [`NetFaultMode::label`]).
pub fn netfault_label(m: NetFaultMode) -> &'static str {
    m.label()
}

/// Every key [`CampaignSpec::apply_filter`] accepts: the enum-axis table
/// keys plus the two scalar keys (`scenario` ids/ranges, `faults` counts)
/// that aren't enum axes. Error messages render this so the listing can
/// never drift from the parser.
pub fn filter_key_listing() -> String {
    [
        APP_AXIS.key,
        STRATEGY_AXIS.key,
        "scenario",
        COLLECTIVES_AXIS.key,
        VALIDATION_AXIS.key,
        "faults",
        NETFAULT_AXIS.key,
    ]
    .join("|")
}

/// Most faults a single campaign cell may arm (each extra fault is an
/// independent seed-derived bit-flip; beyond a handful the cell stops
/// telling us anything new about recovery and just burns wall-clock).
pub const MAX_FAULTS: u32 = 4;

/// Fold one field into a running hash (SplitMix64 finalizer — the same
/// generator the workload seeds use, so the whole campaign stays
/// reproducible from one number).
fn fold(h: u64, v: u64) -> u64 {
    SplitMix64::new(h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

/// The per-task deterministic seed:
/// `hash(campaign_seed, scenario_id, app, strategy, collectives,
/// validation, faults, netfault)`.
///
/// Every task's workload generation, injection-site choice and run
/// directory derive from this value alone — never from wall-clock time,
/// scheduling order or *shard assignment* — which is what makes the
/// aggregated report invariant under `--jobs` and under any `--shard i/N`
/// split of the sweep.
#[allow(clippy::too_many_arguments)]
pub fn task_seed(
    campaign_seed: u64,
    scenario_id: u32,
    app: CampaignApp,
    strategy: Strategy,
    collectives: CollectiveImpl,
    validation: ValidationMode,
    faults: u32,
    netfault: NetFaultMode,
) -> u64 {
    // Domain tag bumped (…04) when the netfault axis joined the fold set
    // (…03 added collectives, …02 validation/faults), so cross-version
    // persisted records can never alias.
    let h = fold(campaign_seed, 0x5EDA_2C04);
    let h = fold(h, scenario_id as u64 + 1);
    let h = fold(h, app.ordinal() + 1);
    let h = fold(h, strategy_ordinal(strategy) + 1);
    let h = fold(h, collective_ordinal(collectives) + 1);
    let h = fold(h, validation_ordinal(validation) + 1);
    let h = fold(h, faults as u64);
    fold(h, netfault_ordinal(netfault) + 1)
}

/// What to sweep and how wide to fan out.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Campaign master seed (every task seed derives from it).
    pub seed: u64,
    /// Worker threads (each runs one isolated world at a time).
    pub jobs: usize,
    /// Applications to sweep (task order follows this list's order).
    pub apps: Vec<CampaignApp>,
    /// Strategies to sweep (task order follows this list's order).
    pub strategies: Vec<Strategy>,
    /// Collective implementations to sweep (§4.2 axis; default **both** —
    /// the functional point-to-point validation and the optimized native
    /// collectives, whose detection coverage differs at scatter/gather
    /// roots). Narrow with `collectives=p2p` or `collectives=native`.
    pub collectives: Vec<CollectiveImpl>,
    /// Validation modes to sweep (beyond-paper axis; default `[Full]`, the
    /// paper's §4.2 message validation — add `sha256` for RedMPI-style
    /// digest comparison cells).
    pub validations: Vec<ValidationMode>,
    /// Armed-faults-per-cell counts to sweep (beyond-paper axis; default
    /// `[1]`, the paper's single-fault campaign — higher counts arm extra
    /// independent seed-derived bit-flips per §3.2's multi-fault
    /// discussion).
    pub fault_counts: Vec<u32>,
    /// Network-fault families to sweep (beyond-paper axis; default
    /// `[None]`, the fault-free transport — `netfault=mixed` etc. widen
    /// the sweep with [`crate::faultnet`]-perturbed worlds graded against
    /// the safety oracle in [`shard::grade`]).
    pub netfaults: Vec<NetFaultMode>,
    /// Keep only these scenario ids (`None` = the full 64).
    pub scenarios: Option<Vec<u32>>,
    /// Base config every task derives from. `base.run_dir` is the campaign
    /// root (each task gets an isolated subdirectory); `base.strategy` and
    /// `base.seed` are overridden per task.
    pub base: RunConfig,
    /// Print one progress line per finished task.
    pub echo: bool,
    /// When set, every finished task writes its typed event log
    /// ([`crate::obs`]) to `<dir>/task-NNNN.trace` (`--trace-out`).
    pub trace_out: Option<std::path::PathBuf>,
}

impl CampaignSpec {
    /// The full sweep: 64 scenarios × 3 apps × 3 strategies × 2 collective
    /// implementations = 1152 worlds.
    pub fn new(seed: u64) -> CampaignSpec {
        let base = RunConfig {
            // Generous rendezvous lapse: a loaded worker pool must never
            // turn a healthy-but-descheduled sibling into a spurious TOE
            // (that would break the jobs-invariance of the report).
            toe_timeout: std::time::Duration::from_millis(2000),
            // Campaign worlds default to the virtual clock: TOE lapses and
            // injected delays resolve in modeled ticks at quiescence, so a
            // timeout-heavy sweep costs no wall time waiting and verdicts
            // are independent of host load. `--clock wall` restores the
            // physical clock for comparison runs — the report is
            // byte-identical either way.
            clock: ClockMode::Virtual,
            run_dir: std::path::PathBuf::from("runs/campaign"),
            ..RunConfig::default()
        };
        CampaignSpec {
            seed,
            jobs: 1,
            apps: CampaignApp::ALL.to_vec(),
            strategies: STRATEGIES.to_vec(),
            collectives: COLLECTIVES.to_vec(),
            validations: vec![ValidationMode::Full],
            fault_counts: vec![1],
            netfaults: vec![NetFaultMode::None],
            scenarios: None,
            base,
            echo: false,
            trace_out: None,
        }
    }

    /// Sensible worker-pool width for interactive use: the machine's
    /// parallelism, capped at 8 (beyond that the tiny worlds contend more
    /// than they gain).
    pub fn default_jobs() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get().min(8))
            .unwrap_or(1)
    }

    /// Apply one comma-separated filter string, e.g.
    /// `app=matmul,strategy=sys,scenario=1-8,collectives=native,
    /// validation=sha256,faults=2`.
    /// Repeated keys accumulate (`app=matmul,app=sw` keeps both).
    pub fn apply_filter(&mut self, filter: &str) -> Result<()> {
        let mut apps: Vec<CampaignApp> = Vec::new();
        let mut strategies: Vec<Strategy> = Vec::new();
        let mut collectives: Vec<CollectiveImpl> = Vec::new();
        let mut validations: Vec<ValidationMode> = Vec::new();
        let mut fault_counts: Vec<u32> = Vec::new();
        let mut netfaults: Vec<NetFaultMode> = Vec::new();
        let mut scenarios: Vec<u32> = Vec::new();
        for term in filter.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (key, value) = term.split_once('=').ok_or_else(|| {
                SedarError::Config(format!("filter term '{term}': expected key=value"))
            })?;
            let key = key.trim();
            match key {
                k if k == APP_AXIS.key => apps.push((APP_AXIS.parse)(value.trim())?),
                k if k == STRATEGY_AXIS.key => {
                    strategies.push((STRATEGY_AXIS.parse)(value.trim())?)
                }
                k if k == COLLECTIVES_AXIS.key => {
                    collectives.push((COLLECTIVES_AXIS.parse)(value.trim())?)
                }
                k if k == VALIDATION_AXIS.key => {
                    validations.push((VALIDATION_AXIS.parse)(value.trim())?)
                }
                k if k == NETFAULT_AXIS.key => {
                    netfaults.push((NETFAULT_AXIS.parse)(value.trim())?)
                }
                "faults" => {
                    let k: u32 = value.trim().parse().map_err(|e| {
                        SedarError::Config(format!("faults '{}': {e}", value.trim()))
                    })?;
                    if k == 0 || k > MAX_FAULTS {
                        return Err(SedarError::Config(format!(
                            "faults={k} out of range (1..={MAX_FAULTS})"
                        )));
                    }
                    fault_counts.push(k);
                }
                "scenario" => {
                    let v = value.trim();
                    if let Some((lo, hi)) = v.split_once('-') {
                        let lo: u32 = lo.parse().map_err(|e| {
                            SedarError::Config(format!("scenario range '{v}': {e}"))
                        })?;
                        let hi: u32 = hi.parse().map_err(|e| {
                            SedarError::Config(format!("scenario range '{v}': {e}"))
                        })?;
                        if lo > hi {
                            return Err(SedarError::Config(format!(
                                "scenario range '{v}' is reversed (use {hi}-{lo})"
                            )));
                        }
                        scenarios.extend(lo..=hi);
                    } else {
                        scenarios.push(v.parse().map_err(|e| {
                            SedarError::Config(format!("scenario '{v}': {e}"))
                        })?);
                    }
                }
                other => {
                    return Err(SedarError::Config(format!(
                        "unknown filter key '{other}' ({})",
                        filter_key_listing()
                    )))
                }
            }
        }
        if !apps.is_empty() {
            self.apps = apps;
        }
        if !strategies.is_empty() {
            self.strategies = strategies;
        }
        if !collectives.is_empty() {
            self.collectives = collectives;
        }
        if !validations.is_empty() {
            self.validations = validations;
        }
        if !fault_counts.is_empty() {
            self.fault_counts = fault_counts;
        }
        if !netfaults.is_empty() {
            self.netfaults = netfaults;
        }
        if !scenarios.is_empty() {
            self.scenarios = Some(scenarios);
        }
        Ok(())
    }
}

/// Materialize the task list: scenario-major, then app, strategy,
/// collectives, validation and fault count, in the spec's declared order.
/// Task indices are the positions in this list — the canonical aggregation
/// order, and the key the fleet's shard plans partition over
/// ([`crate::fleet::plan::ShardPlan`]).
pub fn build_tasks(spec: &CampaignSpec) -> Vec<CampaignTask> {
    let catalog: Vec<Scenario> = workfault::catalog(&campaign_matmul())
        .into_iter()
        .filter(|sc| match &spec.scenarios {
            None => true,
            Some(keep) => keep.contains(&sc.id),
        })
        .collect();
    let cells = spec.apps.len()
        * spec.strategies.len()
        * spec.collectives.len()
        * spec.validations.len()
        * spec.fault_counts.len()
        * spec.netfaults.len();
    let mut tasks = Vec::with_capacity(catalog.len() * cells);
    for sc in &catalog {
        for &app in &spec.apps {
            for &strategy in &spec.strategies {
                for &collectives in &spec.collectives {
                    for &validation in &spec.validations {
                        for &faults in &spec.fault_counts {
                            for &netfault in &spec.netfaults {
                                tasks.push(CampaignTask {
                                    index: tasks.len(),
                                    scenario: sc.clone(),
                                    app,
                                    strategy,
                                    collectives,
                                    validation,
                                    faults,
                                    netfault,
                                    seed: task_seed(
                                        spec.seed,
                                        sc.id,
                                        app,
                                        strategy,
                                        collectives,
                                        validation,
                                        faults,
                                        netfault,
                                    ),
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    tasks
}

/// Order-sensitive fingerprint of a sweep's canonical task list: folds the
/// campaign seed and every task's cell coordinates. Two sweeps agree on
/// this value iff they agree on seed, filters and axis order — the
/// identity every shard WAL header carries so `sedar merge` and WAL
/// resume can refuse to mix different sweeps even when seed and task
/// counts coincide.
pub fn sweep_fingerprint(seed: u64, tasks: &[CampaignTask]) -> u64 {
    // Domain tag bumped (…E9) when the netfault axis joined the fold set,
    // so v3-era files can never alias a current fingerprint.
    let mut h = fold(seed, 0x5EDA_F1E9);
    for t in tasks {
        h = fold(h, t.index as u64 + 1);
        h = fold(h, t.scenario.id as u64 + 1);
        h = fold(h, t.app.ordinal() + 1);
        h = fold(h, strategy_ordinal(t.strategy) + 1);
        h = fold(h, collective_ordinal(t.collectives) + 1);
        h = fold(h, validation_ordinal(t.validation) + 1);
        h = fold(h, t.faults as u64);
        h = fold(h, netfault_ordinal(t.netfault) + 1);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seed_of(
        campaign_seed: u64,
        scenario_id: u32,
        app: CampaignApp,
        strategy: Strategy,
    ) -> u64 {
        task_seed(
            campaign_seed,
            scenario_id,
            app,
            strategy,
            CollectiveImpl::PointToPoint,
            ValidationMode::Full,
            1,
            NetFaultMode::None,
        )
    }

    #[test]
    fn task_seed_depends_on_every_field() {
        let base = seed_of(42, 1, CampaignApp::Matmul, Strategy::SysCkpt);
        assert_ne!(base, seed_of(43, 1, CampaignApp::Matmul, Strategy::SysCkpt));
        assert_ne!(base, seed_of(42, 2, CampaignApp::Matmul, Strategy::SysCkpt));
        assert_ne!(base, seed_of(42, 1, CampaignApp::Jacobi, Strategy::SysCkpt));
        assert_ne!(base, seed_of(42, 1, CampaignApp::Matmul, Strategy::UserCkpt));
        // The collectives and beyond-paper axes are part of the fold set
        // too.
        assert_ne!(
            base,
            task_seed(
                42,
                1,
                CampaignApp::Matmul,
                Strategy::SysCkpt,
                CollectiveImpl::Native,
                ValidationMode::Full,
                1,
                NetFaultMode::None,
            )
        );
        assert_ne!(
            base,
            task_seed(
                42,
                1,
                CampaignApp::Matmul,
                Strategy::SysCkpt,
                CollectiveImpl::PointToPoint,
                ValidationMode::Sha256,
                1,
                NetFaultMode::None,
            )
        );
        assert_ne!(
            base,
            task_seed(
                42,
                1,
                CampaignApp::Matmul,
                Strategy::SysCkpt,
                CollectiveImpl::PointToPoint,
                ValidationMode::Full,
                2,
                NetFaultMode::None,
            )
        );
        assert_ne!(
            base,
            task_seed(
                42,
                1,
                CampaignApp::Matmul,
                Strategy::SysCkpt,
                CollectiveImpl::PointToPoint,
                ValidationMode::Full,
                1,
                NetFaultMode::Mixed,
            )
        );
        // And it is a pure function.
        assert_eq!(base, seed_of(42, 1, CampaignApp::Matmul, Strategy::SysCkpt));
    }

    #[test]
    fn full_sweep_is_1152_tasks() {
        let tasks = build_tasks(&CampaignSpec::new(7));
        assert_eq!(tasks.len(), 64 * 3 * 3 * 2);
        // Indices are dense and ordered, and both collective modes appear.
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(t.index, i);
        }
        for c in COLLECTIVES {
            assert!(tasks.iter().any(|t| t.collectives == c), "missing {c:?}");
        }
        // The default sweep stays fault-free: the netfault axis widens a
        // sweep only when a filter asks for it.
        assert!(tasks.iter().all(|t| t.netfault == NetFaultMode::None));
    }

    #[test]
    fn netfault_filter_widens_the_sweep() {
        let mut spec = CampaignSpec::new(7);
        spec.apply_filter(
            "app=matmul,strategy=sys,scenario=1-4,collectives=p2p,\
             netfault=none,netfault=mixed",
        )
        .unwrap();
        let tasks = build_tasks(&spec);
        // 4 scenarios × 1 app × 1 strategy × 1 collectives × 2 netfaults.
        assert_eq!(tasks.len(), 8);
        assert!(tasks.iter().any(|t| t.netfault == NetFaultMode::Mixed));
        // Distinct seeds everywhere — the axis is part of the fold set.
        let mut seeds: Vec<u64> = tasks.iter().map(|t| t.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 8);
    }

    #[test]
    fn filters_narrow_the_sweep() {
        let mut spec = CampaignSpec::new(7);
        spec.apply_filter("app=matmul,strategy=sys,scenario=1-8,collectives=p2p").unwrap();
        let tasks = build_tasks(&spec);
        assert_eq!(tasks.len(), 8);
        assert!(tasks.iter().all(|t| t.app == CampaignApp::Matmul));
        assert!(tasks.iter().all(|t| t.strategy == Strategy::SysCkpt));
        assert!(tasks.iter().all(|t| t.collectives == CollectiveImpl::PointToPoint));
        assert!(tasks.iter().all(|t| t.scenario.id <= 8));
        // Without the collectives term the same filter doubles: both modes.
        let mut both = CampaignSpec::new(7);
        both.apply_filter("app=matmul,strategy=sys,scenario=1-8").unwrap();
        assert_eq!(build_tasks(&both).len(), 16);
    }

    #[test]
    fn beyond_paper_axes_widen_the_sweep() {
        let mut spec = CampaignSpec::new(7);
        spec.apply_filter(
            "app=matmul,strategy=sys,scenario=1-4,collectives=p2p,\
             validation=full,validation=sha256,faults=1,faults=2",
        )
        .unwrap();
        let tasks = build_tasks(&spec);
        // 4 scenarios × 1 app × 1 strategy × 1 collectives × 2 validations
        // × 2 fault counts.
        assert_eq!(tasks.len(), 16);
        assert!(tasks.iter().any(|t| t.validation == ValidationMode::Sha256));
        assert!(tasks.iter().any(|t| t.faults == 2));
        // Every cell gets a distinct seed.
        let mut seeds: Vec<u64> = tasks.iter().map(|t| t.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 16);
    }

    #[test]
    fn filter_rejects_garbage() {
        let mut spec = CampaignSpec::new(7);
        assert!(spec.apply_filter("app").is_err());
        assert!(spec.apply_filter("app=nope").is_err());
        assert!(spec.apply_filter("color=red").is_err());
        assert!(spec.apply_filter("scenario=x").is_err());
        assert!(spec.apply_filter("scenario=8-1").is_err());
        assert!(spec.apply_filter("collectives=mpi").is_err());
        assert!(spec.apply_filter("validation=crc").is_err());
        assert!(spec.apply_filter("faults=0").is_err());
        assert!(spec.apply_filter("faults=99").is_err());
        assert!(spec.apply_filter("faults=two").is_err());
        assert!(spec.apply_filter("netfault=gamma-ray").is_err());
    }

    #[test]
    fn fingerprint_sees_seed_and_every_filter_axis() {
        let tasks_of = |seed: u64, filter: &str| {
            let mut spec = CampaignSpec::new(seed);
            spec.apply_filter(filter).unwrap();
            sweep_fingerprint(seed, &build_tasks(&spec))
        };
        let base = tasks_of(42, "scenario=1-12");
        assert_eq!(base, tasks_of(42, "scenario=1-12"));
        assert_ne!(base, tasks_of(43, "scenario=1-12"));
        // Same seed, same task COUNT, different cells — the drift the
        // fingerprint exists to catch.
        assert_ne!(base, tasks_of(42, "scenario=13-24"));
        assert_ne!(base, tasks_of(42, "scenario=1-12,collectives=native"));
        assert_ne!(base, tasks_of(42, "scenario=1-12,collectives=p2p"));
        assert_ne!(base, tasks_of(42, "scenario=1-12,validation=sha256"));
        assert_ne!(base, tasks_of(42, "scenario=1-12,faults=2"));
        assert_ne!(base, tasks_of(42, "scenario=1-12,netfault=drop"));
    }

    #[test]
    fn ordinal_roundtrips() {
        for app in CampaignApp::ALL {
            assert_eq!(CampaignApp::from_ordinal(app.ordinal()), Some(app));
        }
        for s in [
            Strategy::Baseline,
            Strategy::DetectOnly,
            Strategy::SysCkpt,
            Strategy::UserCkpt,
        ] {
            assert_eq!(strategy_from_ordinal(strategy_ordinal(s)), Some(s));
        }
        for v in [ValidationMode::Full, ValidationMode::Sha256] {
            assert_eq!(validation_from_ordinal(validation_ordinal(v)), Some(v));
        }
        for c in COLLECTIVES {
            assert_eq!(collective_from_ordinal(collective_ordinal(c)), Some(c));
        }
        assert_eq!(CampaignApp::from_ordinal(99), None);
        assert_eq!(strategy_from_ordinal(99), None);
        assert_eq!(validation_from_ordinal(99), None);
        assert_eq!(collective_from_ordinal(99), None);
    }

    /// One generic check per axis: ordinals roundtrip through the table,
    /// and every label is an accepted `parse` spelling (so report rows can
    /// be pasted straight back into filters).
    fn check_axis<T: Copy + PartialEq + std::fmt::Debug>(axis: &Axis<T>) {
        for &v in axis.domain {
            assert_eq!(axis.from_ordinal((axis.ordinal)(v)), Some(v));
            assert_eq!((axis.parse)((axis.label)(v)).unwrap(), v);
        }
        assert_eq!(axis.from_ordinal(u64::MAX), None);
        assert!((axis.parse)("no-such-value").is_err());
    }

    #[test]
    fn axis_tables_cover_their_domains() {
        check_axis(&APP_AXIS);
        check_axis(&STRATEGY_AXIS);
        check_axis(&COLLECTIVES_AXIS);
        check_axis(&VALIDATION_AXIS);
        check_axis(&NETFAULT_AXIS);
    }

    #[test]
    fn unknown_filter_key_lists_the_registry() {
        let mut spec = CampaignSpec::new(7);
        let err = match spec.apply_filter("color=red") {
            Err(e) => format!("{e}"),
            Ok(()) => panic!("bogus key accepted"),
        };
        assert!(
            err.contains("app|strategy|scenario|collectives|validation|faults|netfault"),
            "listing missing from: {err}"
        );
    }

    #[test]
    fn campaign_base_defaults_to_virtual_clock() {
        assert_eq!(CampaignSpec::new(7).base.clock, ClockMode::Virtual);
    }
}
