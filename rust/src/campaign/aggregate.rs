//! Campaign aggregation: merge per-task outcomes — in task order, never in
//! completion order — into the paper's Table-2-style report rows, a per-
//! (app × strategy) summary, and the campaign-level verdict.
//!
//! The rendered report is **deterministic by construction**: it contains no
//! wall-clock content, and every row derives from fields the shard computed
//! from seeds and dataflow alone. Two sweeps with the same spec must render
//! byte-identical reports whatever `--jobs` was.

use crate::error::FaultClass;
use crate::report::Table;

use super::shard::TaskOutcome;

/// The aggregated result of a campaign.
#[derive(Debug)]
pub struct CampaignReport {
    pub seed: u64,
    /// All task outcomes, sorted by task index.
    pub outcomes: Vec<TaskOutcome>,
}

/// Merge outcome shards (e.g. from partial sweeps run elsewhere) into the
/// canonical task order. Idempotent on already-sorted input.
pub fn merge(shards: Vec<Vec<TaskOutcome>>) -> Vec<TaskOutcome> {
    let mut all: Vec<TaskOutcome> = shards.into_iter().flatten().collect();
    all.sort_by_key(|o| o.index);
    all
}

impl CampaignReport {
    pub fn new(seed: u64, outcomes: Vec<TaskOutcome>) -> CampaignReport {
        let outcomes = merge(vec![outcomes]);
        CampaignReport { seed, outcomes }
    }

    pub fn passed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.pass).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.len() - self.passed()
    }

    /// Campaign-level verdict against the §4.1 oracle: every cell behaved.
    pub fn verdict(&self) -> bool {
        self.failed() == 0
    }

    /// One-line operator summary.
    pub fn summary_line(&self) -> String {
        format!(
            "campaign seed {}: {} task(s), {} passed, {} failed",
            self.seed,
            self.outcomes.len(),
            self.passed(),
            self.failed()
        )
    }

    /// Per-(app × strategy) rollup, in task order of first appearance.
    fn rollup(&self) -> Table {
        let mut keys: Vec<(String, String)> = Vec::new();
        for o in &self.outcomes {
            let k = (o.app.label().to_string(), o.strategy.label().to_string());
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let mut t = Table::new(&[
            "app", "strategy", "tasks", "passed", "failed", "TDC", "FSC", "TOE", "CKPT", "latent",
        ]);
        for (app, strategy) in keys {
            let cell: Vec<&TaskOutcome> = self
                .outcomes
                .iter()
                .filter(|o| o.app.label() == app && o.strategy.label() == strategy)
                .collect();
            let by_class = |c: FaultClass| {
                cell.iter()
                    .filter(|o| matches!(&o.first_detection, Some((got, _)) if *got == c))
                    .count()
            };
            let latent = cell.iter().filter(|o| o.first_detection.is_none()).count();
            t.row(&[
                app.clone(),
                strategy.clone(),
                cell.len().to_string(),
                cell.iter().filter(|o| o.pass).count().to_string(),
                cell.iter().filter(|o| !o.pass).count().to_string(),
                by_class(FaultClass::Tdc).to_string(),
                by_class(FaultClass::Fsc).to_string(),
                by_class(FaultClass::Toe).to_string(),
                by_class(FaultClass::CkptCorrupt).to_string(),
                latent.to_string(),
            ]);
        }
        t
    }

    /// Per-task observed rows (the Table-2/4/5 shape: scenario, cell,
    /// observed effect and site, recovery path, verdict).
    fn rows(&self) -> Table {
        let mut t = Table::new(&[
            "task", "sc", "app", "strategy", "observed", "site", "resume", "N_roll", "result",
            "verdict",
        ]);
        for o in &self.outcomes {
            let (class, site) = match &o.first_detection {
                Some((c, s)) => (c.to_string(), s.clone()),
                None => ("-".to_string(), "-".to_string()),
            };
            t.row(&[
                o.index.to_string(),
                o.scenario_id.to_string(),
                o.app.label().to_string(),
                o.strategy.label().to_string(),
                class,
                site,
                o.last_resume
                    .map(|r| r.to_string())
                    .unwrap_or_else(|| "-".to_string()),
                o.restarts.to_string(),
                match o.correct {
                    Some(true) => "correct",
                    Some(false) => "WRONG",
                    None => "n/a",
                }
                .to_string(),
                if o.pass { "OK" } else { "MISMATCH" }.to_string(),
            ]);
        }
        t
    }

    /// The full deterministic report (markdown). No wall-clock content.
    pub fn deterministic_report(&self) -> String {
        let mut s = format!(
            "# SEDAR campaign report\n\nseed: {}\ntasks: {}\npassed: {}\nfailed: {}\n\n\
             ## Per app × strategy\n\n{}\n## Per task\n\n{}",
            self.seed,
            self.outcomes.len(),
            self.passed(),
            self.failed(),
            self.rollup().markdown(),
            self.rows().markdown(),
        );
        let failures: Vec<&TaskOutcome> = self.outcomes.iter().filter(|o| !o.pass).collect();
        if !failures.is_empty() {
            s.push_str("\n## Mismatches\n\n");
            for o in failures {
                for m in &o.mismatches {
                    s.push_str(&format!(
                        "- task {} (sc{} {} × {}): {}\n",
                        o.index,
                        o.scenario_id,
                        o.app.label(),
                        o.strategy.label(),
                        m
                    ));
                }
            }
        }
        s
    }

    /// The per-task rows as CSV (same determinism contract).
    pub fn csv(&self) -> String {
        self.rows().csv()
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::campaign::CampaignApp;
    use crate::config::Strategy;

    fn outcome(index: usize, pass: bool) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: index as u32 + 1,
            app: CampaignApp::Matmul,
            strategy: Strategy::SysCkpt,
            completed: true,
            restarts: 1,
            injected: true,
            correct: Some(true),
            first_detection: Some((FaultClass::Tdc, "SCATTER".into())),
            last_resume: None,
            pass,
            mismatches: if pass { vec![] } else { vec!["boom".into()] },
            wall: Duration::from_millis(index as u64),
        }
    }

    #[test]
    fn merge_restores_task_order() {
        let merged = merge(vec![
            vec![outcome(3, true), outcome(1, true)],
            vec![outcome(0, true), outcome(2, true)],
        ]);
        let idx: Vec<usize> = merged.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn report_counts_and_verdict() {
        let r = CampaignReport::new(9, vec![outcome(0, true), outcome(1, false)]);
        assert_eq!(r.passed(), 1);
        assert_eq!(r.failed(), 1);
        assert!(!r.verdict());
        let text = r.deterministic_report();
        assert!(text.contains("## Mismatches"));
        assert!(text.contains("boom"));
        assert!(r.summary_line().contains("1 failed"));
    }

    #[test]
    fn report_excludes_wall_clock() {
        // Two outcomes identical but for wall time must render identically.
        let mut a = outcome(0, true);
        let mut b = outcome(0, true);
        a.wall = Duration::from_millis(1);
        b.wall = Duration::from_millis(999);
        let ra = CampaignReport::new(1, vec![a]).deterministic_report();
        let rb = CampaignReport::new(1, vec![b]).deterministic_report();
        assert_eq!(ra, rb);
        assert!(CampaignReport::new(1, vec![outcome(0, true)]).csv().contains("SCATTER"));
    }
}
