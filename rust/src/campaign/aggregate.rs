//! Campaign aggregation: merge per-task outcomes — in task order, never in
//! completion order — into the paper's Table-2-style report rows, a per-
//! (app × strategy) summary, and the campaign-level verdict.
//!
//! The rendered report is **deterministic by construction**: it contains no
//! wall-clock content, and every row derives from fields the shard computed
//! from seeds and dataflow alone. Two sweeps with the same spec must render
//! byte-identical reports whatever `--jobs` was.

use crate::config::{CollectiveImpl, Strategy};
use crate::error::{FaultClass, Result, SedarError};
use crate::metrics::{cost, MetricsSnapshot};
use crate::model::{self, PaperApp};
use crate::report::Table;

use super::shard::TaskOutcome;
use super::{collective_label, netfault_label, validation_label, CampaignApp};

/// The aggregated result of a campaign.
#[derive(Debug)]
pub struct CampaignReport {
    pub seed: u64,
    /// All task outcomes, sorted by task index.
    pub outcomes: Vec<TaskOutcome>,
}

/// Merge outcome shards (partial sweeps run in other processes or machines)
/// into the canonical task order. Sorting is stable and key-based, so the
/// merge is idempotent and commutative over shard order.
///
/// Overlapping shards are **rejected**, never deduplicated: a duplicate
/// task index means two shard files claim the same cell, and silently
/// keeping either (or worse, both — the pre-hardening behavior, which
/// double-counted rollup rows) would corrupt the merged verdict. The caller
/// fixes the shard set; the merge does not guess.
pub fn merge(shards: Vec<Vec<TaskOutcome>>) -> Result<Vec<TaskOutcome>> {
    let mut all: Vec<TaskOutcome> = shards.into_iter().flatten().collect();
    all.sort_by_key(|o| o.index);
    let mut dups: Vec<usize> = all
        .windows(2)
        .filter(|w| w[0].index == w[1].index)
        .map(|w| w[0].index)
        .collect();
    if !dups.is_empty() {
        dups.dedup();
        let shown: Vec<String> = dups.iter().take(8).map(|i| i.to_string()).collect();
        let suffix = if dups.len() > 8 { ", …" } else { "" };
        return Err(SedarError::Config(format!(
            "merge: {} duplicate task index(es) across shards ({}{suffix}) — \
             overlapping shard artifacts are rejected, not deduplicated",
            dups.len(),
            shown.join(", ")
        )));
    }
    Ok(all)
}

impl CampaignReport {
    /// Aggregate one sweep's outcomes (unique indices by construction — the
    /// scheduler fills one slot per task).
    pub fn new(seed: u64, mut outcomes: Vec<TaskOutcome>) -> CampaignReport {
        outcomes.sort_by_key(|o| o.index);
        debug_assert!(
            outcomes.windows(2).all(|w| w[0].index != w[1].index),
            "CampaignReport::new fed duplicate task indices; use from_shards"
        );
        CampaignReport { seed, outcomes }
    }

    /// Aggregate outcomes merged from several shards, rejecting overlaps.
    pub fn from_shards(seed: u64, shards: Vec<Vec<TaskOutcome>>) -> Result<CampaignReport> {
        Ok(CampaignReport {
            seed,
            outcomes: merge(shards)?,
        })
    }

    pub fn total(&self) -> usize {
        self.outcomes.len()
    }

    pub fn passed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.pass).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.len() - self.passed()
    }

    /// Campaign-level verdict against the §4.1 oracle: every cell behaved.
    pub fn verdict(&self) -> bool {
        self.failed() == 0
    }

    /// One-line operator summary.
    pub fn summary_line(&self) -> String {
        format!(
            "campaign seed {}: {} task(s), {} passed, {} failed",
            self.seed,
            self.outcomes.len(),
            self.passed(),
            self.failed()
        )
    }

    /// Per-(app × strategy × collectives) rollup, in task order of first
    /// appearance. The collectives axis gets its own rollup rows because
    /// the detection-class census is exactly what differs between modes
    /// (§4.2: FSC rows become TDC under native collectives) — folding both
    /// modes into one row would hide the effect the axis exists to show.
    fn rollup(&self) -> Table {
        let mut keys: Vec<(String, String, String)> = Vec::new();
        for o in &self.outcomes {
            let k = (
                o.app.label().to_string(),
                o.strategy.label().to_string(),
                collective_label(o.collectives).to_string(),
            );
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let mut t = Table::new(&[
            "app", "strategy", "coll", "tasks", "passed", "failed", "TDC", "FSC", "TOE", "CKPT",
            "latent",
        ]);
        for (app, strategy, coll) in keys {
            let cell: Vec<&TaskOutcome> = self
                .outcomes
                .iter()
                .filter(|o| {
                    o.app.label() == app
                        && o.strategy.label() == strategy
                        && collective_label(o.collectives) == coll
                })
                .collect();
            let by_class = |c: FaultClass| {
                cell.iter()
                    .filter(|o| matches!(&o.first_detection, Some((got, _)) if *got == c))
                    .count()
            };
            let latent = cell.iter().filter(|o| o.first_detection.is_none()).count();
            t.row(&[
                app.clone(),
                strategy.clone(),
                coll.clone(),
                cell.len().to_string(),
                cell.iter().filter(|o| o.pass).count().to_string(),
                cell.iter().filter(|o| !o.pass).count().to_string(),
                by_class(FaultClass::Tdc).to_string(),
                by_class(FaultClass::Fsc).to_string(),
                by_class(FaultClass::Toe).to_string(),
                by_class(FaultClass::CkptCorrupt).to_string(),
                latent.to_string(),
            ]);
        }
        t
    }

    /// Per-task observed rows (the Table-2/4/5 shape: scenario, cell,
    /// observed effect and site, recovery path, verdict).
    fn rows(&self) -> Table {
        let mut t = Table::new(&[
            "task", "sc", "app", "strategy", "coll", "val", "faults", "net", "observed", "site",
            "resume", "N_roll", "result", "verdict",
        ]);
        for o in &self.outcomes {
            let (class, site) = match &o.first_detection {
                Some((c, s)) => (c.to_string(), s.clone()),
                None => ("-".to_string(), "-".to_string()),
            };
            t.row(&[
                o.index.to_string(),
                o.scenario_id.to_string(),
                o.app.label().to_string(),
                o.strategy.label().to_string(),
                collective_label(o.collectives).to_string(),
                validation_label(o.validation).to_string(),
                o.faults.to_string(),
                netfault_label(o.netfault).to_string(),
                class,
                site,
                o.last_resume
                    .map(|r| r.to_string())
                    .unwrap_or_else(|| "-".to_string()),
                o.restarts.to_string(),
                match o.correct {
                    Some(true) => "correct",
                    Some(false) => "WRONG",
                    None => "n/a",
                }
                .to_string(),
                if o.pass { "OK" } else { "MISMATCH" }.to_string(),
            ]);
        }
        t
    }

    /// "Table 3 (measured vs model)": per (app × strategy × collectives)
    /// cell, the detection/checkpoint cost parameters of §5 measured from
    /// the sweep's work counters next to the analytical model's
    /// prediction. Measured values are **modeled ticks** — cost-model
    /// constants ([`crate::metrics::cost`]) times deterministic byte and
    /// event counts — never clock-elapsed time, so the section renders
    /// byte-identically across `--jobs`, shard splits and clock modes.
    fn table3_measured(&self) -> Table {
        let mut keys: Vec<(CampaignApp, Strategy, CollectiveImpl)> = Vec::new();
        for o in &self.outcomes {
            let k = (o.app, o.strategy, o.collectives);
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let mut t = Table::new(&[
            "app",
            "strategy",
            "coll",
            "execs",
            "cmp_bytes",
            "syncs",
            "t_cs",
            "t_ca",
            "f_d (meas)",
            "f_d (model)",
            "ovh (meas)",
            "ovh (model)",
        ]);
        for (app, strategy, coll) in keys {
            let mut m = MetricsSnapshot::default();
            for o in &self.outcomes {
                if (o.app, o.strategy, o.collectives) == (app, strategy, coll) {
                    m.merge(&o.metrics);
                }
            }
            let t_exec = m.execs * cost::EXEC_TICKS_PER_LAUNCH;
            let t_detect = m.compare_bytes * cost::COMPARE_TICKS_PER_BYTE
                + m.sync_events * cost::SYNC_TICKS_PER_EVENT;
            let t_cs_total = m.sys_ckpt_bytes * cost::CKPT_TICKS_PER_BYTE;
            let t_ca_total = m.user_ckpt_bytes * cost::CKPT_TICKS_PER_BYTE;
            let per_ckpt = |total: u64, n: u64| {
                if n > 0 {
                    (total / n).to_string()
                } else {
                    "-".to_string()
                }
            };
            let vs_exec = |num: u64| {
                if t_exec > 0 {
                    ratio6(num, t_exec)
                } else {
                    "-".to_string()
                }
            };
            let p = paper_app(app).paper_params();
            t.row(&[
                app.label().to_string(),
                strategy.label().to_string(),
                collective_label(coll).to_string(),
                m.execs.to_string(),
                m.compare_bytes.to_string(),
                m.sync_events.to_string(),
                per_ckpt(t_cs_total, m.sys_ckpts),
                per_ckpt(t_ca_total, m.user_ckpts),
                vs_exec(t_detect),
                format!("{:.6}", p.f_d),
                vs_exec(t_detect + t_cs_total + t_ca_total),
                format!("{:.6}", model_overhead(strategy, &p)),
            ]);
        }
        t
    }

    /// The full deterministic report (markdown). No wall-clock content.
    pub fn deterministic_report(&self) -> String {
        let mut s = format!(
            "# SEDAR campaign report\n\nseed: {}\ntasks: {}\npassed: {}\nfailed: {}\n\n\
             ## Per app × strategy\n\n{}\n## Per task\n\n{}",
            self.seed,
            self.outcomes.len(),
            self.passed(),
            self.failed(),
            self.rollup().markdown(),
            self.rows().markdown(),
        );
        let failures: Vec<&TaskOutcome> = self.outcomes.iter().filter(|o| !o.pass).collect();
        if !failures.is_empty() {
            s.push_str("\n## Mismatches\n\n");
            for o in failures {
                for m in &o.mismatches {
                    s.push_str(&format!(
                        "- task {} (sc{} {} × {}): {}\n",
                        o.index,
                        o.scenario_id,
                        o.app.label(),
                        o.strategy.label(),
                        m
                    ));
                }
            }
        }
        s.push_str(&format!(
            "\n## Table 3 (measured vs model)\n\n{}",
            self.table3_measured().markdown()
        ));
        s
    }

    /// The per-task rows as CSV (same determinism contract).
    pub fn csv(&self) -> String {
        self.rows().csv()
    }
}

/// Fixed-point `num / den` with six decimals — integer math only, so the
/// rendering is bit-stable across platforms.
fn ratio6(num: u64, den: u64) -> String {
    let q = (num as u128 * 1_000_000) / den as u128;
    format!("{}.{:06}", q / 1_000_000, q % 1_000_000)
}

/// The §5 model application a campaign app's measured row is compared to.
fn paper_app(app: CampaignApp) -> PaperApp {
    match app {
        CampaignApp::Matmul => PaperApp::Matmul,
        CampaignApp::Jacobi => PaperApp::Jacobi,
        CampaignApp::Sw => PaperApp::Sw,
    }
}

/// The model's predicted overhead for one strategy: the matching
/// fault-free equation over the baseline (Equation 1), minus one.
fn model_overhead(strategy: Strategy, p: &model::Params) -> f64 {
    let fa = match strategy {
        Strategy::Baseline => return 0.0,
        Strategy::DetectOnly => model::eq3_detect_fa(p),
        Strategy::SysCkpt => model::eq5_sys_fa(p),
        Strategy::UserCkpt => model::eq7_user_fa(p),
    };
    fa / model::eq1_baseline_fa(p) - 1.0
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::campaign::CampaignApp;
    use crate::config::Strategy;

    fn outcome(index: usize, pass: bool) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: index as u32 + 1,
            app: CampaignApp::Matmul,
            strategy: Strategy::SysCkpt,
            collectives: crate::config::CollectiveImpl::PointToPoint,
            validation: crate::detect::ValidationMode::Full,
            faults: 1,
            netfault: crate::faultnet::NetFaultMode::None,
            completed: true,
            restarts: 1,
            injected: true,
            correct: Some(true),
            first_detection: Some((FaultClass::Tdc, "SCATTER".into())),
            last_resume: None,
            pass,
            mismatches: if pass { vec![] } else { vec!["boom".into()] },
            wall: Duration::from_millis(index as u64),
            metrics: MetricsSnapshot {
                compare_bytes: 4096,
                sync_events: 8,
                sys_ckpt_bytes: 2048,
                sys_ckpts: 2,
                execs: 4,
                ..Default::default()
            },
        }
    }

    #[test]
    fn merge_restores_task_order() {
        let merged = merge(vec![
            vec![outcome(3, true), outcome(1, true)],
            vec![outcome(0, true), outcome(2, true)],
        ])
        .unwrap();
        let idx: Vec<usize> = merged.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn merge_rejects_overlapping_shards() {
        let err = merge(vec![
            vec![outcome(0, true), outcome(1, true)],
            vec![outcome(1, true), outcome(2, true)],
        ])
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("duplicate task index"), "got: {msg}");
        assert!(msg.contains('1'), "should name the colliding index: {msg}");
        // Even a byte-identical duplicate is rejected — the policy is
        // explicit rejection, not dedup.
        assert!(merge(vec![vec![outcome(5, true)], vec![outcome(5, true)]]).is_err());
        // And from_shards surfaces the same error.
        assert!(CampaignReport::from_shards(
            1,
            vec![vec![outcome(0, true)], vec![outcome(0, true)]]
        )
        .is_err());
    }

    #[test]
    fn merge_is_commutative_over_shard_order() {
        let a = vec![outcome(0, true), outcome(2, false)];
        let b = vec![outcome(1, true), outcome(3, true)];
        let ab = CampaignReport::from_shards(9, vec![a.clone(), b.clone()]).unwrap();
        let ba = CampaignReport::from_shards(9, vec![b, a]).unwrap();
        assert_eq!(ab.deterministic_report(), ba.deterministic_report());
    }

    #[test]
    fn report_counts_and_verdict() {
        let r = CampaignReport::new(9, vec![outcome(0, true), outcome(1, false)]);
        assert_eq!(r.passed(), 1);
        assert_eq!(r.failed(), 1);
        assert!(!r.verdict());
        let text = r.deterministic_report();
        assert!(text.contains("## Mismatches"));
        assert!(text.contains("boom"));
        assert!(r.summary_line().contains("1 failed"));
    }

    #[test]
    fn report_excludes_wall_clock() {
        // Two outcomes identical but for wall time must render identically.
        let mut a = outcome(0, true);
        let mut b = outcome(0, true);
        a.wall = Duration::from_millis(1);
        b.wall = Duration::from_millis(999);
        let ra = CampaignReport::new(1, vec![a]).deterministic_report();
        let rb = CampaignReport::new(1, vec![b]).deterministic_report();
        assert_eq!(ra, rb);
        assert!(CampaignReport::new(1, vec![outcome(0, true)]).csv().contains("SCATTER"));
    }

    #[test]
    fn report_excludes_clock_elapsed_ticks() {
        // Same work counters, wildly different clock-elapsed ticks (a wall
        // vs virtual run, say) must render identically — only the
        // deterministic work counters enter the measured table.
        let mut a = outcome(0, true);
        let mut b = outcome(0, true);
        a.metrics.compare_ticks = 1;
        a.metrics.sync_ticks = 5;
        b.metrics.compare_ticks = 999_999;
        b.metrics.exec_ticks = 777_777;
        let ra = CampaignReport::new(1, vec![a]).deterministic_report();
        let rb = CampaignReport::new(1, vec![b]).deterministic_report();
        assert_eq!(ra, rb);
    }

    #[test]
    fn table3_measured_prints_work_derived_parameters() {
        let r = CampaignReport::new(9, vec![outcome(0, true), outcome(1, true)]);
        let text = r.deterministic_report();
        assert!(text.contains("## Table 3 (measured vs model)"));
        // Two outcomes of one cell sum: T_exec = 8 execs × 1_000_000;
        // T_detect = 2 × (4096·1 + 8·2000) = 40_192 → f_d = 0.005024.
        assert!(text.contains("0.005024"), "measured f_d missing:\n{text}");
        // t_cs = (2 × 2048 × 4) / 4 sys checkpoints = 4096 ticks.
        assert!(text.contains("4096"), "measured t_cs missing:\n{text}");
        // Model columns render the §5 prediction next to the measured one.
        assert!(text.contains("f_d (model)"));
    }
}
