//! Campaign aggregation: merge per-task outcomes — in task order, never in
//! completion order — into the paper's Table-2-style report rows, a per-
//! (app × strategy) summary, and the campaign-level verdict.
//!
//! The rendered report is **deterministic by construction**: it contains no
//! wall-clock content, and every row derives from fields the shard computed
//! from seeds and dataflow alone. Two sweeps with the same spec must render
//! byte-identical reports whatever `--jobs` was.

use crate::error::{FaultClass, Result, SedarError};
use crate::report::Table;

use super::shard::TaskOutcome;
use super::{collective_label, validation_label};

/// The aggregated result of a campaign.
#[derive(Debug)]
pub struct CampaignReport {
    pub seed: u64,
    /// All task outcomes, sorted by task index.
    pub outcomes: Vec<TaskOutcome>,
}

/// Merge outcome shards (partial sweeps run in other processes or machines)
/// into the canonical task order. Sorting is stable and key-based, so the
/// merge is idempotent and commutative over shard order.
///
/// Overlapping shards are **rejected**, never deduplicated: a duplicate
/// task index means two shard files claim the same cell, and silently
/// keeping either (or worse, both — the pre-hardening behavior, which
/// double-counted rollup rows) would corrupt the merged verdict. The caller
/// fixes the shard set; the merge does not guess.
pub fn merge(shards: Vec<Vec<TaskOutcome>>) -> Result<Vec<TaskOutcome>> {
    let mut all: Vec<TaskOutcome> = shards.into_iter().flatten().collect();
    all.sort_by_key(|o| o.index);
    let mut dups: Vec<usize> = all
        .windows(2)
        .filter(|w| w[0].index == w[1].index)
        .map(|w| w[0].index)
        .collect();
    if !dups.is_empty() {
        dups.dedup();
        let shown: Vec<String> = dups.iter().take(8).map(|i| i.to_string()).collect();
        let suffix = if dups.len() > 8 { ", …" } else { "" };
        return Err(SedarError::Config(format!(
            "merge: {} duplicate task index(es) across shards ({}{suffix}) — \
             overlapping shard artifacts are rejected, not deduplicated",
            dups.len(),
            shown.join(", ")
        )));
    }
    Ok(all)
}

impl CampaignReport {
    /// Aggregate one sweep's outcomes (unique indices by construction — the
    /// scheduler fills one slot per task).
    pub fn new(seed: u64, mut outcomes: Vec<TaskOutcome>) -> CampaignReport {
        outcomes.sort_by_key(|o| o.index);
        debug_assert!(
            outcomes.windows(2).all(|w| w[0].index != w[1].index),
            "CampaignReport::new fed duplicate task indices; use from_shards"
        );
        CampaignReport { seed, outcomes }
    }

    /// Aggregate outcomes merged from several shards, rejecting overlaps.
    pub fn from_shards(seed: u64, shards: Vec<Vec<TaskOutcome>>) -> Result<CampaignReport> {
        Ok(CampaignReport {
            seed,
            outcomes: merge(shards)?,
        })
    }

    pub fn total(&self) -> usize {
        self.outcomes.len()
    }

    pub fn passed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.pass).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.len() - self.passed()
    }

    /// Campaign-level verdict against the §4.1 oracle: every cell behaved.
    pub fn verdict(&self) -> bool {
        self.failed() == 0
    }

    /// One-line operator summary.
    pub fn summary_line(&self) -> String {
        format!(
            "campaign seed {}: {} task(s), {} passed, {} failed",
            self.seed,
            self.outcomes.len(),
            self.passed(),
            self.failed()
        )
    }

    /// Per-(app × strategy × collectives) rollup, in task order of first
    /// appearance. The collectives axis gets its own rollup rows because
    /// the detection-class census is exactly what differs between modes
    /// (§4.2: FSC rows become TDC under native collectives) — folding both
    /// modes into one row would hide the effect the axis exists to show.
    fn rollup(&self) -> Table {
        let mut keys: Vec<(String, String, String)> = Vec::new();
        for o in &self.outcomes {
            let k = (
                o.app.label().to_string(),
                o.strategy.label().to_string(),
                collective_label(o.collectives).to_string(),
            );
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let mut t = Table::new(&[
            "app", "strategy", "coll", "tasks", "passed", "failed", "TDC", "FSC", "TOE", "CKPT",
            "latent",
        ]);
        for (app, strategy, coll) in keys {
            let cell: Vec<&TaskOutcome> = self
                .outcomes
                .iter()
                .filter(|o| {
                    o.app.label() == app
                        && o.strategy.label() == strategy
                        && collective_label(o.collectives) == coll
                })
                .collect();
            let by_class = |c: FaultClass| {
                cell.iter()
                    .filter(|o| matches!(&o.first_detection, Some((got, _)) if *got == c))
                    .count()
            };
            let latent = cell.iter().filter(|o| o.first_detection.is_none()).count();
            t.row(&[
                app.clone(),
                strategy.clone(),
                coll.clone(),
                cell.len().to_string(),
                cell.iter().filter(|o| o.pass).count().to_string(),
                cell.iter().filter(|o| !o.pass).count().to_string(),
                by_class(FaultClass::Tdc).to_string(),
                by_class(FaultClass::Fsc).to_string(),
                by_class(FaultClass::Toe).to_string(),
                by_class(FaultClass::CkptCorrupt).to_string(),
                latent.to_string(),
            ]);
        }
        t
    }

    /// Per-task observed rows (the Table-2/4/5 shape: scenario, cell,
    /// observed effect and site, recovery path, verdict).
    fn rows(&self) -> Table {
        let mut t = Table::new(&[
            "task", "sc", "app", "strategy", "coll", "val", "faults", "observed", "site", "resume",
            "N_roll", "result", "verdict",
        ]);
        for o in &self.outcomes {
            let (class, site) = match &o.first_detection {
                Some((c, s)) => (c.to_string(), s.clone()),
                None => ("-".to_string(), "-".to_string()),
            };
            t.row(&[
                o.index.to_string(),
                o.scenario_id.to_string(),
                o.app.label().to_string(),
                o.strategy.label().to_string(),
                collective_label(o.collectives).to_string(),
                validation_label(o.validation).to_string(),
                o.faults.to_string(),
                class,
                site,
                o.last_resume
                    .map(|r| r.to_string())
                    .unwrap_or_else(|| "-".to_string()),
                o.restarts.to_string(),
                match o.correct {
                    Some(true) => "correct",
                    Some(false) => "WRONG",
                    None => "n/a",
                }
                .to_string(),
                if o.pass { "OK" } else { "MISMATCH" }.to_string(),
            ]);
        }
        t
    }

    /// The full deterministic report (markdown). No wall-clock content.
    pub fn deterministic_report(&self) -> String {
        let mut s = format!(
            "# SEDAR campaign report\n\nseed: {}\ntasks: {}\npassed: {}\nfailed: {}\n\n\
             ## Per app × strategy\n\n{}\n## Per task\n\n{}",
            self.seed,
            self.outcomes.len(),
            self.passed(),
            self.failed(),
            self.rollup().markdown(),
            self.rows().markdown(),
        );
        let failures: Vec<&TaskOutcome> = self.outcomes.iter().filter(|o| !o.pass).collect();
        if !failures.is_empty() {
            s.push_str("\n## Mismatches\n\n");
            for o in failures {
                for m in &o.mismatches {
                    s.push_str(&format!(
                        "- task {} (sc{} {} × {}): {}\n",
                        o.index,
                        o.scenario_id,
                        o.app.label(),
                        o.strategy.label(),
                        m
                    ));
                }
            }
        }
        s
    }

    /// The per-task rows as CSV (same determinism contract).
    pub fn csv(&self) -> String {
        self.rows().csv()
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::campaign::CampaignApp;
    use crate::config::Strategy;

    fn outcome(index: usize, pass: bool) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: index as u32 + 1,
            app: CampaignApp::Matmul,
            strategy: Strategy::SysCkpt,
            collectives: crate::config::CollectiveImpl::PointToPoint,
            validation: crate::detect::ValidationMode::Full,
            faults: 1,
            completed: true,
            restarts: 1,
            injected: true,
            correct: Some(true),
            first_detection: Some((FaultClass::Tdc, "SCATTER".into())),
            last_resume: None,
            pass,
            mismatches: if pass { vec![] } else { vec!["boom".into()] },
            wall: Duration::from_millis(index as u64),
        }
    }

    #[test]
    fn merge_restores_task_order() {
        let merged = merge(vec![
            vec![outcome(3, true), outcome(1, true)],
            vec![outcome(0, true), outcome(2, true)],
        ])
        .unwrap();
        let idx: Vec<usize> = merged.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn merge_rejects_overlapping_shards() {
        let err = merge(vec![
            vec![outcome(0, true), outcome(1, true)],
            vec![outcome(1, true), outcome(2, true)],
        ])
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("duplicate task index"), "got: {msg}");
        assert!(msg.contains('1'), "should name the colliding index: {msg}");
        // Even a byte-identical duplicate is rejected — the policy is
        // explicit rejection, not dedup.
        assert!(merge(vec![vec![outcome(5, true)], vec![outcome(5, true)]]).is_err());
        // And from_shards surfaces the same error.
        assert!(CampaignReport::from_shards(
            1,
            vec![vec![outcome(0, true)], vec![outcome(0, true)]]
        )
        .is_err());
    }

    #[test]
    fn merge_is_commutative_over_shard_order() {
        let a = vec![outcome(0, true), outcome(2, false)];
        let b = vec![outcome(1, true), outcome(3, true)];
        let ab = CampaignReport::from_shards(9, vec![a.clone(), b.clone()]).unwrap();
        let ba = CampaignReport::from_shards(9, vec![b, a]).unwrap();
        assert_eq!(ab.deterministic_report(), ba.deterministic_report());
    }

    #[test]
    fn report_counts_and_verdict() {
        let r = CampaignReport::new(9, vec![outcome(0, true), outcome(1, false)]);
        assert_eq!(r.passed(), 1);
        assert_eq!(r.failed(), 1);
        assert!(!r.verdict());
        let text = r.deterministic_report();
        assert!(text.contains("## Mismatches"));
        assert!(text.contains("boom"));
        assert!(r.summary_line().contains("1 failed"));
    }

    #[test]
    fn report_excludes_wall_clock() {
        // Two outcomes identical but for wall time must render identically.
        let mut a = outcome(0, true);
        let mut b = outcome(0, true);
        a.wall = Duration::from_millis(1);
        b.wall = Duration::from_millis(999);
        let ra = CampaignReport::new(1, vec![a]).deterministic_report();
        let rb = CampaignReport::new(1, vec![b]).deterministic_report();
        assert_eq!(ra, rb);
        assert!(CampaignReport::new(1, vec![outcome(0, true)]).csv().contains("SCATTER"));
    }
}
