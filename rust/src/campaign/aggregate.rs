//! Campaign aggregation: merge per-task outcomes — in task order, never in
//! completion order — into the paper's Table-2-style report rows, a per-
//! (app × strategy) summary, and the campaign-level verdict.
//!
//! The rendered report is **deterministic by construction**: it contains no
//! wall-clock content, and every row derives from fields the shard computed
//! from seeds and dataflow alone. Two sweeps with the same spec must render
//! byte-identical reports whatever `--jobs` was.
//!
//! Two merge shapes live here:
//!
//! * [`merge`] — the one-shot barrier merge of complete outcome sets;
//! * [`IncrementalMerger`] — the streaming union that consumes shard WAL
//!   snapshots ([`crate::fleet::wal`]) *as they land*: the fleet supervisor
//!   feeds it re-reads of live, still-growing logs, and the same object
//!   renders the final report, so the live partial aggregate at completion
//!   **is** the final report rather than merely agreeing with it.

use std::collections::BTreeMap;

use crate::config::{CollectiveImpl, Strategy};
use crate::error::{FaultClass, Result, SedarError};
use crate::metrics::{cost, MetricsSnapshot};
use crate::model::{self, PaperApp};
use crate::report::Table;

use super::shard::TaskOutcome;
use super::{collective_label, netfault_label, validation_label, CampaignApp};

/// The aggregated result of a campaign.
#[derive(Debug)]
pub struct CampaignReport {
    pub seed: u64,
    /// All task outcomes, sorted by task index.
    pub outcomes: Vec<TaskOutcome>,
}

/// Merge outcome shards (partial sweeps run in other processes or machines)
/// into the canonical task order. Sorting is stable and key-based, so the
/// merge is idempotent and commutative over shard order.
///
/// Overlapping shards are **rejected**, never deduplicated: a duplicate
/// task index means two shard files claim the same cell, and silently
/// keeping either (or worse, both — the pre-hardening behavior, which
/// double-counted rollup rows) would corrupt the merged verdict. The caller
/// fixes the shard set; the merge does not guess.
pub fn merge(shards: Vec<Vec<TaskOutcome>>) -> Result<Vec<TaskOutcome>> {
    let mut all: Vec<TaskOutcome> = shards.into_iter().flatten().collect();
    all.sort_by_key(|o| o.index);
    let mut dups: Vec<usize> = all
        .windows(2)
        .filter(|w| w[0].index == w[1].index)
        .map(|w| w[0].index)
        .collect();
    if !dups.is_empty() {
        dups.dedup();
        let shown: Vec<String> = dups.iter().take(8).map(|i| i.to_string()).collect();
        let suffix = if dups.len() > 8 { ", …" } else { "" };
        return Err(SedarError::Config(format!(
            "merge: {} duplicate task index(es) across shards ({}{suffix}) — \
             overlapping shard slices are rejected, not deduplicated",
            dups.len(),
            shown.join(", ")
        )));
    }
    Ok(all)
}

/// Identity of a shard's slice of a sweep: which sweep it belongs to and
/// which slice it claims. `total_tasks` is the canonical task-list length
/// of the sweep (after filters), so a merge can tell "complete" from
/// "partial"; `spec_hash` ([`crate::campaign::sweep_fingerprint`]) pins the
/// exact cell list, so shards of same-seed, same-width but
/// differently-filtered sweeps can never be silently mixed. Persisted as
/// the header of every shard WAL ([`crate::fleet::wal`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMeta {
    pub seed: u64,
    /// 0-based member index of the producing
    /// [`crate::fleet::plan::ShardPlan`].
    pub shard_index: u32,
    pub shard_count: u32,
    pub total_tasks: u64,
    /// Fingerprint of the sweep's canonical task list (seed + filters).
    pub spec_hash: u64,
}

impl ShardMeta {
    /// Render the identity fields for merge diagnostics (shard shown
    /// 1-based, as operators typed it).
    pub fn describe(&self) -> String {
        format!(
            "seed={} shard={}/{} tasks={} fingerprint={:#018x}",
            self.seed,
            self.shard_index + 1,
            self.shard_count,
            self.total_tasks,
            self.spec_hash
        )
    }
}

/// The streaming merge: a union of shard outcome sets that can be fed
/// repeatedly while the shards are still running.
///
/// Each [`ingest`](IncrementalMerger::ingest) **replaces** that shard's
/// previous contribution, so re-reading a live WAL is idempotent by
/// construction — the supervisor tails growing logs without bookkeeping.
/// Identity drift (another seed, task total or spec fingerprint) is
/// rejected at ingest; *overlap* between different shards (two slices
/// claiming one task index) is rejected when the union is materialized
/// ([`merged`](IncrementalMerger::merged)), same policy as [`merge`].
pub struct IncrementalMerger {
    first: ShardMeta,
    shards: BTreeMap<u32, Vec<TaskOutcome>>,
}

impl IncrementalMerger {
    /// A merger expecting shards of `first`'s sweep (any slice of it).
    pub fn new(first: ShardMeta) -> IncrementalMerger {
        IncrementalMerger {
            first,
            shards: BTreeMap::new(),
        }
    }

    /// Fold in one shard's current outcome set (complete or mid-flight),
    /// replacing whatever that shard contributed before.
    pub fn ingest(&mut self, meta: &ShardMeta, outcomes: Vec<TaskOutcome>) -> Result<()> {
        if meta.seed != self.first.seed {
            return Err(SedarError::Config(format!(
                "merge: shard seeds differ ({} vs {}) — WALs from different sweeps",
                self.first.seed, meta.seed
            )));
        }
        if meta.total_tasks != self.first.total_tasks {
            return Err(SedarError::Config(format!(
                "merge: shard task totals differ ({} vs {}) — WALs from different \
                 filters or specs",
                self.first.total_tasks, meta.total_tasks
            )));
        }
        if meta.spec_hash != self.first.spec_hash {
            // Decode both headers into the error so the operator can see
            // *which* identity component disagrees without a hex dump:
            // same seed + same task total but different fingerprints means
            // a different --filter set (the netfault axis included).
            return Err(SedarError::Config(format!(
                "merge: shard spec fingerprints differ — WALs were produced \
                 under different --filter sets and cannot be combined\n  first: {}\n  other: {}",
                self.first.describe(),
                meta.describe(),
            )));
        }
        self.shards.insert(meta.shard_index, outcomes);
        Ok(())
    }

    pub fn seed(&self) -> u64 {
        self.first.seed
    }

    /// The sweep's canonical task count (the denominator of progress).
    pub fn total_tasks(&self) -> u64 {
        self.first.total_tasks
    }

    /// Pass verdict per distinct task index currently in the union (an
    /// overlapping index is counted once here; it becomes a hard error
    /// when the union is materialized).
    fn verdicts(&self) -> BTreeMap<usize, bool> {
        let mut v = BTreeMap::new();
        for outcomes in self.shards.values() {
            for o in outcomes {
                v.entry(o.index).or_insert(o.pass);
            }
        }
        v
    }

    /// Distinct task indices the union currently covers.
    pub fn done(&self) -> usize {
        self.verdicts().len()
    }

    pub fn passed(&self) -> usize {
        self.verdicts().values().filter(|p| **p).count()
    }

    pub fn failed(&self) -> usize {
        let v = self.verdicts();
        v.len() - v.values().filter(|p| **p).count()
    }

    /// Whether the union covers the whole sweep.
    pub fn is_complete(&self) -> bool {
        self.done() as u64 == self.first.total_tasks
    }

    /// Per-shard coverage, ascending shard index: `(index, outcome count)`.
    pub fn shard_progress(&self) -> Vec<(u32, usize)> {
        self.shards.iter().map(|(i, o)| (*i, o.len())).collect()
    }

    /// Materialize the union in canonical task order, rejecting overlaps
    /// (same policy and message as [`merge`]).
    pub fn merged(&self) -> Result<Vec<TaskOutcome>> {
        merge(self.shards.values().cloned().collect())
    }

    /// Render the current union as a campaign report. Mid-flight this is
    /// the *partial* report (fewer rows than `total_tasks`); at completion
    /// it is byte-identical to the single-process run's, because the rows
    /// are a pure function of the outcome set.
    pub fn report(&self) -> Result<CampaignReport> {
        Ok(CampaignReport::new(self.first.seed, self.merged()?))
    }
}

impl CampaignReport {
    /// Aggregate one sweep's outcomes (unique indices by construction — the
    /// scheduler fills one slot per task).
    pub fn new(seed: u64, mut outcomes: Vec<TaskOutcome>) -> CampaignReport {
        outcomes.sort_by_key(|o| o.index);
        debug_assert!(
            outcomes.windows(2).all(|w| w[0].index != w[1].index),
            "CampaignReport::new fed duplicate task indices; use from_shards"
        );
        CampaignReport { seed, outcomes }
    }

    /// Aggregate outcomes merged from several shards, rejecting overlaps.
    pub fn from_shards(seed: u64, shards: Vec<Vec<TaskOutcome>>) -> Result<CampaignReport> {
        Ok(CampaignReport {
            seed,
            outcomes: merge(shards)?,
        })
    }

    pub fn total(&self) -> usize {
        self.outcomes.len()
    }

    pub fn passed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.pass).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.len() - self.passed()
    }

    /// Campaign-level verdict against the §4.1 oracle: every cell behaved.
    pub fn verdict(&self) -> bool {
        self.failed() == 0
    }

    /// One-line operator summary.
    pub fn summary_line(&self) -> String {
        format!(
            "campaign seed {}: {} task(s), {} passed, {} failed",
            self.seed,
            self.outcomes.len(),
            self.passed(),
            self.failed()
        )
    }

    /// Per-(app × strategy × collectives) rollup, in task order of first
    /// appearance. The collectives axis gets its own rollup rows because
    /// the detection-class census is exactly what differs between modes
    /// (§4.2: FSC rows become TDC under native collectives) — folding both
    /// modes into one row would hide the effect the axis exists to show.
    fn rollup(&self) -> Table {
        let mut keys: Vec<(String, String, String)> = Vec::new();
        for o in &self.outcomes {
            let k = (
                o.app.label().to_string(),
                o.strategy.label().to_string(),
                collective_label(o.collectives).to_string(),
            );
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let mut t = Table::new(&[
            "app", "strategy", "coll", "tasks", "passed", "failed", "TDC", "FSC", "TOE", "CKPT",
            "latent",
        ]);
        for (app, strategy, coll) in keys {
            let cell: Vec<&TaskOutcome> = self
                .outcomes
                .iter()
                .filter(|o| {
                    o.app.label() == app
                        && o.strategy.label() == strategy
                        && collective_label(o.collectives) == coll
                })
                .collect();
            let by_class = |c: FaultClass| {
                cell.iter()
                    .filter(|o| matches!(&o.first_detection, Some((got, _)) if *got == c))
                    .count()
            };
            let latent = cell.iter().filter(|o| o.first_detection.is_none()).count();
            t.row(&[
                app.clone(),
                strategy.clone(),
                coll.clone(),
                cell.len().to_string(),
                cell.iter().filter(|o| o.pass).count().to_string(),
                cell.iter().filter(|o| !o.pass).count().to_string(),
                by_class(FaultClass::Tdc).to_string(),
                by_class(FaultClass::Fsc).to_string(),
                by_class(FaultClass::Toe).to_string(),
                by_class(FaultClass::CkptCorrupt).to_string(),
                latent.to_string(),
            ]);
        }
        t
    }

    /// Per-task observed rows (the Table-2/4/5 shape: scenario, cell,
    /// observed effect and site, recovery path, verdict).
    fn rows(&self) -> Table {
        let mut t = Table::new(&[
            "task", "sc", "app", "strategy", "coll", "val", "faults", "net", "observed", "site",
            "resume", "N_roll", "result", "verdict",
        ]);
        for o in &self.outcomes {
            let (class, site) = match &o.first_detection {
                Some((c, s)) => (c.to_string(), s.clone()),
                None => ("-".to_string(), "-".to_string()),
            };
            t.row(&[
                o.index.to_string(),
                o.scenario_id.to_string(),
                o.app.label().to_string(),
                o.strategy.label().to_string(),
                collective_label(o.collectives).to_string(),
                validation_label(o.validation).to_string(),
                o.faults.to_string(),
                netfault_label(o.netfault).to_string(),
                class,
                site,
                o.last_resume
                    .map(|r| r.to_string())
                    .unwrap_or_else(|| "-".to_string()),
                o.restarts.to_string(),
                match o.correct {
                    Some(true) => "correct",
                    Some(false) => "WRONG",
                    None => "n/a",
                }
                .to_string(),
                if o.pass { "OK" } else { "MISMATCH" }.to_string(),
            ]);
        }
        t
    }

    /// "Table 3 (measured vs model)": per (app × strategy × collectives)
    /// cell, the detection/checkpoint cost parameters of §5 measured from
    /// the sweep's work counters next to the analytical model's
    /// prediction. Measured values are **modeled ticks** — cost-model
    /// constants ([`crate::metrics::cost`]) times deterministic byte and
    /// event counts — never clock-elapsed time, so the section renders
    /// byte-identically across `--jobs`, shard splits and clock modes.
    fn table3_measured(&self) -> Table {
        let mut keys: Vec<(CampaignApp, Strategy, CollectiveImpl)> = Vec::new();
        for o in &self.outcomes {
            let k = (o.app, o.strategy, o.collectives);
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let mut t = Table::new(&[
            "app",
            "strategy",
            "coll",
            "execs",
            "cmp_bytes",
            "syncs",
            "t_cs",
            "t_ca",
            "f_d (meas)",
            "f_d (model)",
            "ovh (meas)",
            "ovh (model)",
        ]);
        for (app, strategy, coll) in keys {
            let mut m = MetricsSnapshot::default();
            for o in &self.outcomes {
                if (o.app, o.strategy, o.collectives) == (app, strategy, coll) {
                    m.merge(&o.metrics);
                }
            }
            let t_exec = m.execs * cost::EXEC_TICKS_PER_LAUNCH;
            let t_detect = m.compare_bytes * cost::COMPARE_TICKS_PER_BYTE
                + m.sync_events * cost::SYNC_TICKS_PER_EVENT;
            let t_cs_total = m.sys_ckpt_bytes * cost::CKPT_TICKS_PER_BYTE;
            let t_ca_total = m.user_ckpt_bytes * cost::CKPT_TICKS_PER_BYTE;
            let per_ckpt = |total: u64, n: u64| {
                if n > 0 {
                    (total / n).to_string()
                } else {
                    "-".to_string()
                }
            };
            let vs_exec = |num: u64| {
                if t_exec > 0 {
                    ratio6(num, t_exec)
                } else {
                    "-".to_string()
                }
            };
            let p = paper_app(app).paper_params();
            t.row(&[
                app.label().to_string(),
                strategy.label().to_string(),
                collective_label(coll).to_string(),
                m.execs.to_string(),
                m.compare_bytes.to_string(),
                m.sync_events.to_string(),
                per_ckpt(t_cs_total, m.sys_ckpts),
                per_ckpt(t_ca_total, m.user_ckpts),
                vs_exec(t_detect),
                format!("{:.6}", p.f_d),
                vs_exec(t_detect + t_cs_total + t_ca_total),
                format!("{:.6}", model_overhead(strategy, &p)),
            ]);
        }
        t
    }

    /// The full deterministic report (markdown). No wall-clock content.
    pub fn deterministic_report(&self) -> String {
        let mut s = format!(
            "# SEDAR campaign report\n\nseed: {}\ntasks: {}\npassed: {}\nfailed: {}\n\n\
             ## Per app × strategy\n\n{}\n## Per task\n\n{}",
            self.seed,
            self.outcomes.len(),
            self.passed(),
            self.failed(),
            self.rollup().markdown(),
            self.rows().markdown(),
        );
        let failures: Vec<&TaskOutcome> = self.outcomes.iter().filter(|o| !o.pass).collect();
        if !failures.is_empty() {
            s.push_str("\n## Mismatches\n\n");
            for o in failures {
                for m in &o.mismatches {
                    s.push_str(&format!(
                        "- task {} (sc{} {} × {}): {}\n",
                        o.index,
                        o.scenario_id,
                        o.app.label(),
                        o.strategy.label(),
                        m
                    ));
                }
            }
        }
        s.push_str(&format!(
            "\n## Table 3 (measured vs model)\n\n{}",
            self.table3_measured().markdown()
        ));
        s
    }

    /// The per-task rows as CSV (same determinism contract).
    pub fn csv(&self) -> String {
        self.rows().csv()
    }
}

/// Fixed-point `num / den` with six decimals — integer math only, so the
/// rendering is bit-stable across platforms.
fn ratio6(num: u64, den: u64) -> String {
    let q = (num as u128 * 1_000_000) / den as u128;
    format!("{}.{:06}", q / 1_000_000, q % 1_000_000)
}

/// The §5 model application a campaign app's measured row is compared to.
fn paper_app(app: CampaignApp) -> PaperApp {
    match app {
        CampaignApp::Matmul => PaperApp::Matmul,
        CampaignApp::Jacobi => PaperApp::Jacobi,
        CampaignApp::Sw => PaperApp::Sw,
    }
}

/// The model's predicted overhead for one strategy: the matching
/// fault-free equation over the baseline (Equation 1), minus one.
fn model_overhead(strategy: Strategy, p: &model::Params) -> f64 {
    let fa = match strategy {
        Strategy::Baseline => return 0.0,
        Strategy::DetectOnly => model::eq3_detect_fa(p),
        Strategy::SysCkpt => model::eq5_sys_fa(p),
        Strategy::UserCkpt => model::eq7_user_fa(p),
    };
    fa / model::eq1_baseline_fa(p) - 1.0
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::campaign::CampaignApp;
    use crate::config::Strategy;

    fn outcome(index: usize, pass: bool) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: index as u32 + 1,
            app: CampaignApp::Matmul,
            strategy: Strategy::SysCkpt,
            collectives: crate::config::CollectiveImpl::PointToPoint,
            validation: crate::detect::ValidationMode::Full,
            faults: 1,
            netfault: crate::faultnet::NetFaultMode::None,
            completed: true,
            restarts: 1,
            injected: true,
            correct: Some(true),
            first_detection: Some((FaultClass::Tdc, "SCATTER".into())),
            last_resume: None,
            pass,
            mismatches: if pass { vec![] } else { vec!["boom".into()] },
            wall: Duration::from_millis(index as u64),
            metrics: MetricsSnapshot {
                compare_bytes: 4096,
                sync_events: 8,
                sys_ckpt_bytes: 2048,
                sys_ckpts: 2,
                execs: 4,
                ..Default::default()
            },
        }
    }

    #[test]
    fn merge_restores_task_order() {
        let merged = merge(vec![
            vec![outcome(3, true), outcome(1, true)],
            vec![outcome(0, true), outcome(2, true)],
        ])
        .unwrap();
        let idx: Vec<usize> = merged.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn merge_rejects_overlapping_shards() {
        let err = merge(vec![
            vec![outcome(0, true), outcome(1, true)],
            vec![outcome(1, true), outcome(2, true)],
        ])
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("duplicate task index"), "got: {msg}");
        assert!(msg.contains('1'), "should name the colliding index: {msg}");
        // Even a byte-identical duplicate is rejected — the policy is
        // explicit rejection, not dedup.
        assert!(merge(vec![vec![outcome(5, true)], vec![outcome(5, true)]]).is_err());
        // And from_shards surfaces the same error.
        assert!(CampaignReport::from_shards(
            1,
            vec![vec![outcome(0, true)], vec![outcome(0, true)]]
        )
        .is_err());
    }

    #[test]
    fn merge_is_commutative_over_shard_order() {
        let a = vec![outcome(0, true), outcome(2, false)];
        let b = vec![outcome(1, true), outcome(3, true)];
        let ab = CampaignReport::from_shards(9, vec![a.clone(), b.clone()]).unwrap();
        let ba = CampaignReport::from_shards(9, vec![b, a]).unwrap();
        assert_eq!(ab.deterministic_report(), ba.deterministic_report());
    }

    #[test]
    fn report_counts_and_verdict() {
        let r = CampaignReport::new(9, vec![outcome(0, true), outcome(1, false)]);
        assert_eq!(r.passed(), 1);
        assert_eq!(r.failed(), 1);
        assert!(!r.verdict());
        let text = r.deterministic_report();
        assert!(text.contains("## Mismatches"));
        assert!(text.contains("boom"));
        assert!(r.summary_line().contains("1 failed"));
    }

    #[test]
    fn report_excludes_wall_clock() {
        // Two outcomes identical but for wall time must render identically.
        let mut a = outcome(0, true);
        let mut b = outcome(0, true);
        a.wall = Duration::from_millis(1);
        b.wall = Duration::from_millis(999);
        let ra = CampaignReport::new(1, vec![a]).deterministic_report();
        let rb = CampaignReport::new(1, vec![b]).deterministic_report();
        assert_eq!(ra, rb);
        assert!(CampaignReport::new(1, vec![outcome(0, true)]).csv().contains("SCATTER"));
    }

    #[test]
    fn report_excludes_clock_elapsed_ticks() {
        // Same work counters, wildly different clock-elapsed ticks (a wall
        // vs virtual run, say) must render identically — only the
        // deterministic work counters enter the measured table.
        let mut a = outcome(0, true);
        let mut b = outcome(0, true);
        a.metrics.compare_ticks = 1;
        a.metrics.sync_ticks = 5;
        b.metrics.compare_ticks = 999_999;
        b.metrics.exec_ticks = 777_777;
        let ra = CampaignReport::new(1, vec![a]).deterministic_report();
        let rb = CampaignReport::new(1, vec![b]).deterministic_report();
        assert_eq!(ra, rb);
    }

    fn meta(shard_index: u32) -> ShardMeta {
        ShardMeta {
            seed: 9,
            shard_index,
            shard_count: 2,
            total_tasks: 4,
            spec_hash: 0xAAAA,
        }
    }

    #[test]
    fn incremental_merger_streams_idempotently_to_the_final_report() {
        let mut m = IncrementalMerger::new(meta(0));
        assert_eq!((m.done(), m.passed(), m.failed()), (0, 0, 0));
        assert!(!m.is_complete());

        // Shard 0 lands mid-flight with one outcome…
        m.ingest(&meta(0), vec![outcome(0, true)]).unwrap();
        assert_eq!(m.done(), 1);
        let partial = m.report().unwrap().deterministic_report();

        // …then again with more: a live re-read REPLACES, never duplicates.
        m.ingest(&meta(0), vec![outcome(0, true), outcome(2, false)])
            .unwrap();
        m.ingest(&meta(0), vec![outcome(0, true), outcome(2, false)])
            .unwrap();
        m.ingest(&meta(1), vec![outcome(1, true), outcome(3, true)])
            .unwrap();
        assert_eq!((m.done(), m.passed(), m.failed()), (4, 3, 1));
        assert!(m.is_complete());
        assert_eq!(m.shard_progress(), vec![(0, 2), (1, 2)]);

        // The streaming union at completion IS the barrier merge's report,
        // and every row of the mid-flight partial is a row of the final.
        let final_report = m.report().unwrap().deterministic_report();
        let barrier = CampaignReport::from_shards(
            9,
            vec![
                vec![outcome(0, true), outcome(2, false)],
                vec![outcome(1, true), outcome(3, true)],
            ],
        )
        .unwrap()
        .deterministic_report();
        assert_eq!(final_report, barrier);
        let row_of = |r: &str, needle: &str| {
            r.lines().find(|l| l.contains(needle)).map(String::from)
        };
        assert_eq!(
            row_of(&partial, "| 0 "),
            row_of(&final_report, "| 0 "),
            "partial rows must be a prefix of the final report's"
        );
    }

    #[test]
    fn incremental_merger_rejects_identity_drift_and_overlap() {
        let mut m = IncrementalMerger::new(meta(0));
        m.ingest(&meta(0), vec![outcome(0, true)]).unwrap();

        let err = m
            .ingest(&ShardMeta { seed: 10, ..meta(1) }, vec![])
            .unwrap_err()
            .to_string();
        assert!(err.contains("seeds differ"), "{err}");
        let err = m
            .ingest(&ShardMeta { total_tasks: 5, ..meta(1) }, vec![])
            .unwrap_err()
            .to_string();
        assert!(err.contains("task totals differ"), "{err}");
        // Fingerprint drift names BOTH decoded headers.
        let err = m
            .ingest(&ShardMeta { spec_hash: 0xBBBB, ..meta(1) }, vec![])
            .unwrap_err()
            .to_string();
        for needle in ["0x000000000000aaaa", "0x000000000000bbbb", "shard=1/2", "shard=2/2"] {
            assert!(err.contains(needle), "missing {needle}: {err}");
        }

        // Two DIFFERENT shards claiming one index: accepted at ingest
        // (live tails may be mid-write), rejected when materialized.
        m.ingest(&meta(1), vec![outcome(0, true)]).unwrap();
        let err = m.merged().unwrap_err().to_string();
        assert!(err.contains("duplicate task index"), "{err}");
    }

    #[test]
    fn table3_measured_prints_work_derived_parameters() {
        let r = CampaignReport::new(9, vec![outcome(0, true), outcome(1, true)]);
        let text = r.deterministic_report();
        assert!(text.contains("## Table 3 (measured vs model)"));
        // Two outcomes of one cell sum: T_exec = 8 execs × 1_000_000;
        // T_detect = 2 × (4096·1 + 8·2000) = 40_192 → f_d = 0.005024.
        assert!(text.contains("0.005024"), "measured f_d missing:\n{text}");
        // t_cs = (2 × 2048 × 4) / 4 sys checkpoints = 4096 ticks.
        assert!(text.contains("4096"), "measured t_cs missing:\n{text}");
        // Model columns render the §5 prediction next to the measured one.
        assert!(text.contains("f_d (model)"));
    }
}
