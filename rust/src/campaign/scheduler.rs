//! The campaign worker pool.
//!
//! `jobs` workers pull tasks from a shared queue (an atomic cursor over the
//! canonical task list — idle workers steal whatever work is left, so the
//! pool load-balances without any per-worker partitioning). Every worker
//! runs one isolated world at a time, all borrowing the same injected
//! engine deps; results land in per-index slots, which is what makes the
//! aggregate independent of completion order.
//!
//! [`run_tasks`] is the reusable core: it executes an arbitrary task list —
//! the full sweep, or one shard's slice of it ([`crate::fleet`]) — and
//! reports each finished task through a caller-supplied sink (journaling,
//! live status, …). [`run_campaign`] stays the one-call full sweep.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coordinator::RunDeps;
use crate::error::{Result, SedarError};

use super::aggregate::CampaignReport;
use super::shard::{self, CampaignTask, TaskOutcome};
use super::{build_tasks, CampaignSpec};

/// Called after each finished task with `(done_so_far, total, outcome)`.
/// Invoked from worker threads — implementations must be `Sync` and are
/// responsible for their own locking (e.g. a mutex around a journal file).
pub type TaskSink<'a> = &'a (dyn Fn(usize, usize, &TaskOutcome) + Sync);

/// A sink that ignores every event.
pub fn null_sink() -> impl Fn(usize, usize, &TaskOutcome) + Sync {
    |_, _, _| {}
}

/// Run the whole campaign described by `spec` and aggregate the outcomes.
pub fn run_campaign(spec: &CampaignSpec) -> Result<CampaignReport> {
    let tasks = build_tasks(spec);
    if tasks.is_empty() {
        return Err(SedarError::Config(
            "campaign filter selects no tasks".into(),
        ));
    }
    let outcomes = run_tasks(spec, &tasks, &null_sink())?;
    Ok(CampaignReport::new(spec.seed, outcomes))
}

/// Execute `tasks` (any subset of the spec's canonical task list, e.g. one
/// shard's slice) over the worker pool. Outcomes come back ordered by the
/// tasks' positions in the given slice; their `index` fields keep the
/// canonical campaign indices.
pub fn run_tasks(
    spec: &CampaignSpec,
    tasks: &[CampaignTask],
    sink: TaskSink,
) -> Result<Vec<TaskOutcome>> {
    if tasks.is_empty() {
        return Ok(Vec::new());
    }
    let jobs = spec.jobs.clamp(1, tasks.len());

    // One shared engine process for every world in the sweep (runs borrow
    // deps, they do not own engines). Warming is all-or-nothing across the
    // union of the swept apps' artifacts: one missing artifact degrades the
    // whole sweep to the pure-rust fallback, which keeps every cell on the
    // same (deterministic) compute path.
    let artifacts: Vec<String> = spec
        .apps
        .iter()
        .flat_map(|a| a.instantiate().artifacts())
        .collect();
    let (deps, _engine) = RunDeps::start(spec.base.use_xla, &spec.base.artifact_dir, &artifacts);

    let root = spec.base.run_dir.clone();
    std::fs::create_dir_all(&root)?;
    if let Some(dir) = &spec.trace_out {
        std::fs::create_dir_all(dir)?;
    }

    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<TaskOutcome>>> =
        tasks.iter().map(|_| Mutex::new(None)).collect();

    std::thread::scope(|s| {
        for w in 0..jobs {
            let slots = &slots;
            let next = &next;
            let done = &done;
            let root = &root;
            let worker_deps = deps.clone();
            let base = &spec.base;
            let echo = spec.echo;
            let trace_out = spec.trace_out.as_deref();
            s.spawn(move || {
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= tasks.len() {
                        break;
                    }
                    let task = &tasks[i];
                    let out = shard::run_task(task, root, &worker_deps, base, trace_out);
                    let finished = done.fetch_add(1, Ordering::SeqCst) + 1;
                    if echo {
                        eprintln!(
                            "[w{w}] {:>4}/{} t{:04} sc{:02} {:>6} × {:<11} {:<6} → {}",
                            finished,
                            tasks.len(),
                            task.index,
                            task.scenario.id,
                            task.app.label(),
                            task.strategy.label(),
                            task.collectives.label(),
                            if out.pass { "OK" } else { "MISMATCH" }
                        );
                    }
                    sink(finished, tasks.len(), &out);
                    *slots[i].lock().unwrap() = Some(out);
                }
            });
        }
    });

    let outcomes: Vec<TaskOutcome> = slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("campaign slot mutex poisoned")
                .expect("every task slot filled when the pool drains")
        })
        .collect();

    Ok(outcomes)
}
