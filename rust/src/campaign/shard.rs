//! One campaign task = one isolated `SedarRun` world.
//!
//! A task is a (scenario × app × strategy) cell of the sweep. The shard
//! materializes the scenario's injection for the task's application,
//! executes it in a private run directory, and grades the outcome:
//!
//! * **matmul × sys-ckpt** — the full §4.1 prediction-oracle check (every
//!   Table 2 column: effect, `P_det`, `P_rec`, `N_roll`);
//! * **matmul × detect-only** — effect and detection site must match the
//!   oracle; recovery is the paper's §3.1 response (one relaunch from
//!   scratch), so `N_roll` is 1 for any detected fault and 0 for LE;
//! * **matmul × user-ckpt** — Algorithm 2's guarantee: completion with a
//!   correct result after **at most one** rollback (detection may fire
//!   early, at a checkpoint hash validation, so the site is not pinned);
//! * **jacobi / sw × any** — the scenario is transplanted onto the app's
//!   own dataflow (a seed-derived bit-flip into one of the rank's
//!   significant variables); the verdict is end-to-end: the run completes
//!   and the final result matches the sequential oracle.

use std::path::Path;
use std::time::Duration;

use crate::config::{CollectiveImpl, RunConfig, Strategy};
use crate::coordinator::{RunDeps, RunOutcome, SedarRun};
use crate::detect::ValidationMode;
use crate::error::FaultClass;
use crate::faultnet::NetFaultMode;
use crate::inject::{InjectKind, InjectPoint, InjectionSpec};
use crate::recovery::ResumeFrom;
use crate::util::prng::SplitMix64;
use crate::workfault::{self, Scenario};

use super::{campaign_matmul, CampaignApp};

/// One (scenario × app × strategy × collectives × validation × faults ×
/// netfault) cell of the sweep.
#[derive(Debug, Clone)]
pub struct CampaignTask {
    /// Position in the canonical task order (the aggregation key).
    pub index: usize,
    pub scenario: Scenario,
    pub app: CampaignApp,
    pub strategy: Strategy,
    /// Collective implementation the cell runs under (§4.2 axis: the
    /// detection coverage at scatter/gather roots differs between modes,
    /// so each mode is its own verified cell).
    pub collectives: CollectiveImpl,
    /// Message-validation mode the cell runs under (beyond-paper axis).
    pub validation: ValidationMode,
    /// How many independent faults the cell arms (1 = the paper's sweep).
    pub faults: u32,
    /// Network-fault family the cell's transport runs under
    /// ([`crate::faultnet`]; `None` = clean transport, the paper's sweep).
    pub netfault: NetFaultMode,
    /// `hash(campaign_seed, scenario, app, strategy, collectives,
    /// validation, faults, netfault)` — drives the workload, the
    /// transplanted injection sites, nothing else.
    pub seed: u64,
}

/// What the aggregator keeps from a finished task. Wall-clock time is
/// carried for operator curiosity only — it never enters the deterministic
/// report.
#[derive(Debug, Clone)]
pub struct TaskOutcome {
    pub index: usize,
    pub scenario_id: u32,
    pub app: CampaignApp,
    pub strategy: Strategy,
    pub collectives: CollectiveImpl,
    pub validation: ValidationMode,
    pub faults: u32,
    pub netfault: NetFaultMode,
    pub completed: bool,
    pub restarts: u32,
    pub injected: bool,
    pub correct: Option<bool>,
    /// Class and site of the first detection, if any.
    pub first_detection: Option<(FaultClass, String)>,
    pub last_resume: Option<ResumeFrom>,
    pub pass: bool,
    pub mismatches: Vec<String>,
    /// Informational only: excluded from the deterministic report.
    pub wall: Duration,
    /// The run's tick-based work counters — the "Table 3 (measured)"
    /// inputs. Only the deterministic work counters (bytes, event counts)
    /// enter the report; the clock-elapsed `*_ticks` fields are carried for
    /// trace tooling.
    pub metrics: crate::metrics::MetricsSnapshot,
}

/// Transplant a matmul-catalog scenario onto another application: a
/// bit-flip into one of the target rank's significant variables, at a
/// phase boundary — var, element and phase all derived from the task seed
/// (the scenario id shapes the seed, so each scenario lands elsewhere).
pub fn generic_injection(
    task: &CampaignTask,
    app: &dyn crate::apps::spec::AppSpec,
) -> InjectionSpec {
    seeded_injection(task, app, task.seed, 0)
}

/// The `k`-th extra armed fault of a multi-fault cell: the same
/// seed-derived bit-flip construction as [`generic_injection`], drawn from
/// a per-fault sub-seed so every armed fault lands independently.
pub fn extra_injection(
    task: &CampaignTask,
    app: &dyn crate::apps::spec::AppSpec,
    k: u32,
) -> InjectionSpec {
    let sub_seed = SplitMix64::new(task.seed ^ (0xFA17_0000 + k as u64)).next_u64();
    seeded_injection(task, app, sub_seed, k)
}

fn seeded_injection(
    task: &CampaignTask,
    app: &dyn crate::apps::spec::AppSpec,
    seed: u64,
    fault_no: u32,
) -> InjectionSpec {
    let mut rng = SplitMix64::new(seed);
    let rank = task.scenario.rank % app.nranks();
    let store = app.init_store(rank, task.seed);
    let vars: Vec<String> = app
        .significant_vars(rank)
        .into_iter()
        .filter(|v| store.get(v).is_ok())
        .collect();
    let var = vars[rng.below(vars.len() as u64) as usize].clone();
    let numel = store.get(&var).expect("filtered above").numel();
    let elem = rng.below(numel as u64) as usize;
    // Any phase after INIT is a valid window; latent landings are part of
    // the sweep, exactly as in the matmul catalog.
    let phase = 1 + rng.below(app.n_phases() - 1);
    InjectionSpec {
        name: format!(
            "campaign-{}-sc{}-f{fault_no}",
            app.name(),
            task.scenario.id
        ),
        point: InjectPoint::BeforePhase(phase),
        rank,
        replica: 1,
        kind: InjectKind::BitFlip { var, elem, bit: 30 },
    }
}

/// Execute one task in an isolated world under `root`, borrowing the
/// campaign's shared engine deps. Run errors become failed outcomes, never
/// panics — one broken world must not take the pool down.
pub fn run_task(
    task: &CampaignTask,
    root: &Path,
    deps: &RunDeps,
    base: &RunConfig,
    trace_out: Option<&Path>,
) -> TaskOutcome {
    let cfg = RunConfig {
        strategy: task.strategy,
        collectives: task.collectives,
        validation: task.validation,
        netfault: task.netfault,
        seed: task.seed,
        run_dir: root.join(format!(
            "t{:04}-sc{}-{}-{}-{}",
            task.index,
            task.scenario.id,
            task.app.label(),
            task.strategy.label(),
            task.collectives.label()
        )),
        ..base.clone()
    };

    let (app, mut specs) = match task.app {
        CampaignApp::Matmul => {
            let m = campaign_matmul();
            let spec = workfault::injection_for(&m, &task.scenario, &cfg);
            (task.app.instantiate(), vec![spec])
        }
        _ => {
            let app = task.app.instantiate();
            let spec = generic_injection(task, app.as_ref());
            (app, vec![spec])
        }
    };
    // Beyond-paper multi-fault cells arm extra independent bit-flips on top
    // of the scenario's canonical fault (§3.2's discussion: recovery stays
    // correct, possibly at sub-optimal rollback cost).
    for k in 1..task.faults {
        specs.push(extra_injection(task, app.as_ref(), k));
    }

    let run = SedarRun::new_multi(app, cfg, specs);
    // A panicking world (a poisoned assertion deep in a replica path, say)
    // must surface as one failed cell, not abort the pool and discard every
    // completed outcome.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run.run_with(deps).and_then(|outcome| {
            if let Some(dir) = trace_out {
                let path = dir.join(format!("task-{:04}.trace", task.index));
                crate::obs::write_log(&path, &outcome.events, &outcome.spans)?;
            }
            Ok(grade(task, &outcome))
        })
    }));
    match result {
        Ok(Ok(outcome)) => outcome,
        Ok(Err(e)) if task.netfault != NetFaultMode::None => {
            // Fail-safe stop: under a perturbed transport a typed error
            // that safe-stops the world is an acceptable outcome — the
            // safety oracle only forbids hangs, panics and silently
            // accepted wrong results. The note is kept for diagnostics but
            // the cell passes.
            failsafe_outcome(task, format!("fail-safe stop: {e}"))
        }
        Ok(Err(e)) => failed_outcome(task, format!("run error: {e}")),
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            failed_outcome(task, format!("world panicked: {msg}"))
        }
    }
}

fn failed_outcome(task: &CampaignTask, mismatch: String) -> TaskOutcome {
    TaskOutcome {
        index: task.index,
        scenario_id: task.scenario.id,
        app: task.app,
        strategy: task.strategy,
        collectives: task.collectives,
        validation: task.validation,
        faults: task.faults,
        netfault: task.netfault,
        completed: false,
        restarts: 0,
        injected: false,
        correct: None,
        first_detection: None,
        last_resume: None,
        pass: false,
        mismatches: vec![mismatch],
        wall: Duration::ZERO,
        metrics: Default::default(),
    }
}

/// A netfault cell that safe-stopped with a typed error instead of an
/// outcome: graded a pass (the fail-safe half of the safety oracle), with
/// the stop reason carried as a diagnostic note.
fn failsafe_outcome(task: &CampaignTask, note: String) -> TaskOutcome {
    TaskOutcome {
        pass: true,
        ..failed_outcome(task, note)
    }
}

/// Grade an observed outcome per the task's cell. Paper cells (full
/// validation, single fault) are held to the strict §4.1 oracle / §3.x
/// strategy guarantees — with the prediction columns taken **under the
/// cell's collectives mode** ([`workfault::scenario_under`]): native
/// collectives close the FSC window at scatter/gather roots, so the same
/// scenario legitimately grades as a different class/site/rollback there.
/// Beyond-paper cells (sha256 validation or multi-fault) have no Table-2
/// prediction, so the verdict is end-to-end with the recovery-cost bounds
/// the algorithms still guarantee.
fn grade(task: &CampaignTask, outcome: &RunOutcome) -> TaskOutcome {
    let sc = &task.scenario;
    let beyond_paper = task.validation != ValidationMode::Full || task.faults != 1;
    let mut mismatches = if task.netfault != NetFaultMode::None {
        grade_netfault(outcome)
    } else if beyond_paper {
        grade_beyond_paper(task, outcome)
    } else {
        let effective = workfault::scenario_under(task.collectives, sc);
        match (task.app, task.strategy) {
            (CampaignApp::Matmul, Strategy::SysCkpt) => {
                workfault::check_prediction(&effective, outcome)
            }
            (CampaignApp::Matmul, Strategy::DetectOnly) => {
                grade_matmul_detect_only(&effective, outcome)
            }
            (CampaignApp::Matmul, Strategy::UserCkpt) => {
                grade_matmul_user(&effective, outcome)
            }
            _ => grade_end_to_end(task.strategy, outcome),
        }
    };
    // Universal floor for every clean-transport cell: a task that gave up
    // is a failure. Netfault cells are exempt — their oracle accepts a
    // fail-safe stop with a detection ([`grade_netfault`]).
    if task.netfault == NetFaultMode::None && !outcome.completed && mismatches.is_empty() {
        mismatches.push("run did not complete".into());
    }
    TaskOutcome {
        index: task.index,
        scenario_id: sc.id,
        app: task.app,
        strategy: task.strategy,
        collectives: task.collectives,
        validation: task.validation,
        faults: task.faults,
        netfault: task.netfault,
        completed: outcome.completed,
        restarts: outcome.restarts,
        injected: outcome.injected,
        correct: outcome.result_correct,
        first_detection: outcome
            .detections
            .first()
            .map(|d| (d.class, d.site.clone())),
        last_resume: outcome.resume_history.last().copied(),
        pass: mismatches.is_empty(),
        mismatches,
        wall: outcome.wall,
        metrics: outcome.metrics.clone(),
    }
}

/// §3.1: detection + notification, then one relaunch from the beginning.
fn grade_matmul_detect_only(sc: &Scenario, o: &RunOutcome) -> Vec<String> {
    let mut m = Vec::new();
    if !o.completed {
        m.push("run did not complete".into());
    }
    if o.result_correct != Some(true) {
        m.push(format!("final result not correct: {:?}", o.result_correct));
    }
    if sc.effect == FaultClass::Le {
        if let Some(ev) = o.detections.first() {
            m.push(format!("predicted LE but detected {} at {}", ev.class, ev.site));
        }
        if o.restarts != 0 {
            m.push(format!("LE scenario restarted {} time(s)", o.restarts));
        }
        return m;
    }
    if !o.injected {
        m.push("injection never fired".into());
    }
    match o.detections.first() {
        None => m.push(format!("predicted {} but nothing detected", sc.effect)),
        Some(ev) => {
            if ev.class != sc.effect {
                m.push(format!("effect: predicted {}, observed {}", sc.effect, ev.class));
            }
            if let Some(site) = sc.p_det {
                if ev.site != site {
                    m.push(format!("P_det: predicted {site}, observed {}", ev.site));
                }
            }
        }
    }
    if o.restarts != 1 {
        m.push(format!("detect-only N_roll: expected 1, observed {}", o.restarts));
    }
    if !matches!(o.resume_history.last(), Some(ResumeFrom::Scratch)) {
        m.push(format!(
            "detect-only resumes from scratch, observed {:?}",
            o.resume_history.last()
        ));
    }
    m
}

/// §3.3 / Algorithm 2: at most one rollback, always to a validated
/// checkpoint (or scratch), always ending correct. Detection may fire
/// earlier than the oracle's `P_det` — a corrupted candidate is caught at
/// the checkpoint hash validation — so class/site are not pinned here.
fn grade_matmul_user(sc: &Scenario, o: &RunOutcome) -> Vec<String> {
    let mut m = Vec::new();
    if !o.completed {
        m.push("run did not complete".into());
    }
    if o.result_correct != Some(true) {
        m.push(format!("final result not correct: {:?}", o.result_correct));
    }
    if o.restarts > 1 {
        m.push(format!(
            "user-ckpt rolled back {} times (Algorithm 2 bounds it to 1)",
            o.restarts
        ));
    }
    if sc.effect != FaultClass::Le {
        if !o.injected {
            m.push("injection never fired".into());
        }
        if o.detections.is_empty() {
            m.push(format!("predicted {} but nothing detected", sc.effect));
        }
        if o.restarts != 1 {
            m.push(format!("user-ckpt N_roll: expected 1, observed {}", o.restarts));
        }
    }
    m
}

/// Transplanted scenarios (jacobi / sw): the verdict is end-to-end — the
/// protected run absorbs the fault and finishes with the oracle's answer.
fn grade_end_to_end(strategy: Strategy, o: &RunOutcome) -> Vec<String> {
    let mut m = Vec::new();
    if !o.completed {
        m.push("run did not complete".into());
    }
    if o.result_correct != Some(true) {
        m.push(format!("final result not correct: {:?}", o.result_correct));
    }
    if !o.injected {
        m.push("injection never fired".into());
    }
    // Single latched fault ⇒ detect-only and user-ckpt recover in at most
    // one restart (scratch relaunch / single validated rollback).
    if matches!(strategy, Strategy::DetectOnly | Strategy::UserCkpt) && o.restarts > 1 {
        m.push(format!(
            "{}: expected at most 1 restart, observed {}",
            strategy.label(),
            o.restarts
        ));
    }
    m
}

/// The safety oracle for perturbed-transport cells ([`crate::faultnet`]):
/// the Table-2 prediction no longer applies — transport faults add their
/// own detections and retries on top of the armed workfault — so the
/// verdict is the fail-safe contract:
///
/// * **completed** ⇒ the accepted result must be correct. A silently
///   wrong answer under a corrupt/reorder plan is the one unforgivable
///   outcome (duplicates and reorders must be absorbed byte-identically;
///   corruption must be caught by the transport CRC before acceptance).
/// * **not completed** ⇒ the world must have stopped for a *named*
///   reason: a detection (TDC from the transport CRC, TOE from a dropped
///   message's modeled timeout). Stopping with nothing detected fails
///   the cell. Hangs cannot reach this grader at all — the fault layer
///   bounds every receive, and CI bounds the slice's wall time.
fn grade_netfault(o: &RunOutcome) -> Vec<String> {
    let mut m = Vec::new();
    if o.completed {
        if o.result_correct != Some(true) {
            m.push(format!(
                "netfault cell accepted a wrong/unvalidated result: {:?}",
                o.result_correct
            ));
        }
    } else if o.detections.is_empty() {
        m.push("netfault cell stopped without a detection".into());
    }
    m
}

/// Beyond-paper cells (sha256 validation and/or multiple armed faults):
/// the sweep asserts SEDAR's end-to-end promise — the protected run absorbs
/// whatever was armed and finishes with the oracle's answer — plus the
/// recovery-cost bounds that survive multiple faults: detect-only relaunches
/// at most once per fault, user-ckpt rolls back at most once per fault
/// (Algorithm 2 applied fault-by-fault; see `rust/tests/multi_fault.rs`).
/// Sys-ckpt's `N_roll` may legitimately exceed the fault count (Algorithm 1
/// walks the checkpoint chain), so it carries no restart bound here.
fn grade_beyond_paper(task: &CampaignTask, o: &RunOutcome) -> Vec<String> {
    let mut m = Vec::new();
    if !o.completed {
        m.push("run did not complete".into());
    }
    if o.result_correct != Some(true) {
        m.push(format!("final result not correct: {:?}", o.result_correct));
    }
    // `injected` is all-latches-fired; a matmul LE scenario's canonical
    // fault may legitimately never fire (its window can be unreachable), so
    // only non-LE paper scenarios pin it. Transplanted and extra faults
    // always fire at reachable phase boundaries.
    let le_scenario = task.app == CampaignApp::Matmul && task.scenario.effect == FaultClass::Le;
    if !o.injected && !le_scenario {
        m.push("not every armed injection fired".into());
    }
    if matches!(task.strategy, Strategy::DetectOnly | Strategy::UserCkpt)
        && o.restarts > task.faults
    {
        m.push(format!(
            "{}: expected at most {} restart(s) for {} armed fault(s), observed {}",
            task.strategy.label(),
            task.faults,
            task.faults,
            o.restarts
        ));
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::{build_tasks, CampaignSpec};

    #[test]
    fn generic_injections_target_live_vars() {
        let mut spec = CampaignSpec::new(11);
        spec.apply_filter("app=jacobi,app=sw,strategy=sys").unwrap();
        for task in build_tasks(&spec) {
            let app = task.app.instantiate();
            let inj = generic_injection(&task, app.as_ref());
            let InjectKind::BitFlip { var, elem, .. } = &inj.kind else {
                panic!("generic injection must be a bit-flip");
            };
            let store = app.init_store(inj.rank, task.seed);
            let v = store.get(var).expect("target var exists on that rank");
            assert!(*elem < v.numel(), "elem {} out of range for {var}", elem);
            let InjectPoint::BeforePhase(p) = inj.point else {
                panic!("generic injection fires at a phase boundary");
            };
            assert!(p >= 1 && p < app.n_phases());
        }
    }

    #[test]
    fn generic_injection_is_a_pure_function_of_the_task() {
        let mut spec = CampaignSpec::new(3);
        spec.apply_filter("app=sw,strategy=user,scenario=5").unwrap();
        let task = build_tasks(&spec).remove(0);
        let app = task.app.instantiate();
        let a = generic_injection(&task, app.as_ref());
        let b = generic_injection(&task, app.as_ref());
        assert_eq!(format!("{:?}", a.kind), format!("{:?}", b.kind));
        assert_eq!(a.rank, b.rank);
    }
}
