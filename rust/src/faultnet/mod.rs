//! `faultnet` — deterministic network-fault injection inside vmpi.
//!
//! The 64-scenario workfault catalog corrupts *application state*; this
//! layer perturbs the *transport*: for every message a seed-derived plan
//! picks one of `deliver | drop | duplicate | reorder-delay(d ticks) |
//! corrupt-payload-bit` (the NA-0090 idiom: `k = hash(seed, msg_idx);
//! k % N → action`). The plan is a pure function of
//! `(seed, src, dst, seq)` where `seq` is the per-(src, dst) send
//! sequence number — program order on the sending thread — so the same
//! seed perturbs the same messages whatever the thread interleaving, and
//! two runs of one cell stay byte-identical (`sedar conform` proves it).
//!
//! Detection semantics (the safety oracle the campaign grades against):
//!
//! * **corrupt** — the sender stamps a CRC-32 of the payload *before* the
//!   fault layer may flip a bit (the link-level checksum every real
//!   interconnect carries). The receiver verifies on take; a mismatch is
//!   [`SedarError::NetCorrupt`], which the replica layer classifies as a
//!   **TDC** at the receiving site — transmitted data corruption caught
//!   at the next validation point.
//! * **drop** — the message is never queued. The fault layer imposes a
//!   default receive deadline (the configured TOE lapse) on every
//!   otherwise-unbounded receive, so a dropped delivery surfaces as a
//!   **TOE** within the modeled timeout — never a hang, on either clock.
//! * **duplicate** — a second copy (same `seq`) is queued, bounded by the
//!   per-(src, tag) redelivery cap; the mailbox's dedup window absorbs it
//!   at take. Final stores stay byte-identical.
//! * **reorder-delay** — delivery is postponed `d` modeled ticks on the
//!   PR-6 virtual clock (no wall time in campaigns). Per-(src, tag)
//!   FIFO is preserved — MPI's non-overtaking guarantee, which SEDAR's
//!   protocol is entitled to assume — so a delay reorders deliveries
//!   *across* pairs and tags, never within one stream: absorbed, or a
//!   TOE if the delay outlives the lapse.
//!
//! Every non-deliver action is recorded as a typed
//! [`EventKind::NetFault`](crate::obs::EventKind) event and drained into
//! the run's trace log by the coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::error::{Result, SedarError};
use crate::obs::{Event, EventKind};
use crate::util::clock::Tick;
use crate::util::prng::SplitMix64;

/// The campaign's `netfault=` axis values: which perturbation family a
/// world's plan draws from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NetFaultMode {
    /// No fault layer installed (the default; zero transport overhead).
    None,
    /// Messages vanish in flight (graded: TOE within the modeled lapse).
    Drop,
    /// Messages arrive twice (graded: absorbed byte-identically).
    Dup,
    /// Deliveries are delayed d modeled ticks (graded: absorbed or TOE).
    Reorder,
    /// One payload bit flips in flight (graded: TDC at the next recv).
    Corrupt,
    /// All four families mixed in one plan.
    Mixed,
}

impl NetFaultMode {
    pub const ALL: [NetFaultMode; 6] = [
        NetFaultMode::None,
        NetFaultMode::Drop,
        NetFaultMode::Dup,
        NetFaultMode::Reorder,
        NetFaultMode::Corrupt,
        NetFaultMode::Mixed,
    ];

    pub fn parse(s: &str) -> Result<NetFaultMode> {
        Ok(match s {
            "none" => NetFaultMode::None,
            "drop" => NetFaultMode::Drop,
            "dup" | "duplicate" => NetFaultMode::Dup,
            "reorder" => NetFaultMode::Reorder,
            "corrupt" => NetFaultMode::Corrupt,
            "mixed" => NetFaultMode::Mixed,
            other => {
                return Err(SedarError::Config(format!(
                    "unknown netfault mode '{other}' (expected \
                     none|drop|dup|reorder|corrupt|mixed)"
                )))
            }
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            NetFaultMode::None => "none",
            NetFaultMode::Drop => "drop",
            NetFaultMode::Dup => "dup",
            NetFaultMode::Reorder => "reorder",
            NetFaultMode::Corrupt => "corrupt",
            NetFaultMode::Mixed => "mixed",
        }
    }

    /// Stable ordinal, persisted in shard WAL records and folded into
    /// task seeds — frozen once released.
    pub fn ordinal(self) -> u8 {
        match self {
            NetFaultMode::None => 0,
            NetFaultMode::Drop => 1,
            NetFaultMode::Dup => 2,
            NetFaultMode::Reorder => 3,
            NetFaultMode::Corrupt => 4,
            NetFaultMode::Mixed => 5,
        }
    }

    /// Inverse of [`NetFaultMode::ordinal`] (WAL record decoding).
    pub fn from_ordinal(ord: u8) -> Option<NetFaultMode> {
        NetFaultMode::ALL.iter().copied().find(|m| m.ordinal() == ord)
    }
}

/// What the plan decided for one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Pass through untouched (the overwhelming majority).
    Deliver,
    /// Never queue the message.
    Drop,
    /// Queue a second copy with the same sequence number.
    Duplicate,
    /// Queue with delivery postponed this many modeled ticks.
    Delay(Tick),
    /// Flip payload bit `k % (payload_bits)`; the raw `k` is carried so
    /// the apply site can reduce it against the actual payload length.
    CorruptBit(u64),
}

impl FaultAction {
    /// Short label for event details and counters.
    pub fn label(&self) -> &'static str {
        match self {
            FaultAction::Deliver => "deliver",
            FaultAction::Drop => "drop",
            FaultAction::Duplicate => "dup",
            FaultAction::Delay(_) => "delay",
            FaultAction::CorruptBit(_) => "corrupt",
        }
    }
}

/// Maximum reorder delay, in ticks (1 ms modeled). Deliberately well
/// under the default TOE lapse so plain reorder cells are absorbed, not
/// timed out — the timeout path belongs to the drop family.
pub const MAX_DELAY_TICKS: Tick = 1_000_000;

/// SplitMix64 seed-fold (the same chain the campaign uses for task
/// seeds): order-sensitive, avalanching.
fn fold(h: u64, v: u64) -> u64 {
    SplitMix64::new(h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

/// A world's perturbation plan: a pure function of `(seed, src, dst,
/// seq)`. Copy-cheap and lock-free — evaluation is a handful of
/// multiplies per message (`sedar bench --json`, group `faultnet`).
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    mode: NetFaultMode,
    seed: u64,
}

impl FaultPlan {
    pub fn new(mode: NetFaultMode, seed: u64) -> FaultPlan {
        FaultPlan { mode, seed }
    }

    pub fn mode(&self) -> NetFaultMode {
        self.mode
    }

    /// The NA-0090 mapping: `k = hash(seed, msg); k % N → action`.
    ///
    /// Per-family fault rates (out of 16 slots): drop 2, dup 4, reorder
    /// 4, corrupt 2; `mixed` spends 6 slots across all four. The
    /// remaining slots deliver — most traffic must flow or every cell
    /// degenerates to the same TOE.
    pub fn action(&self, src: usize, dst: usize, seq: u64) -> FaultAction {
        if self.mode == NetFaultMode::None {
            return FaultAction::Deliver;
        }
        let mut k = self.seed;
        k = fold(k, src as u64);
        k = fold(k, dst as u64);
        k = fold(k, seq);
        let slot = k % 16;
        let delay = 1 + (k >> 8) % MAX_DELAY_TICKS;
        match self.mode {
            NetFaultMode::None => FaultAction::Deliver,
            NetFaultMode::Drop if slot < 2 => FaultAction::Drop,
            NetFaultMode::Dup if slot < 4 => FaultAction::Duplicate,
            NetFaultMode::Reorder if slot < 4 => FaultAction::Delay(delay),
            NetFaultMode::Corrupt if slot < 2 => FaultAction::CorruptBit(k >> 8),
            NetFaultMode::Mixed => match slot {
                0 => FaultAction::Drop,
                1 | 2 => FaultAction::Duplicate,
                3 | 4 => FaultAction::Delay(delay),
                5 => FaultAction::CorruptBit(k >> 8),
                _ => FaultAction::Deliver,
            },
            _ => FaultAction::Deliver,
        }
    }
}

/// Per-action counters, exposed for tests and the bench suite.
#[derive(Debug, Default)]
pub struct FaultCounters {
    pub drops: AtomicU64,
    pub dups: AtomicU64,
    pub delays: AtomicU64,
    pub corrupts: AtomicU64,
}

/// The installed fault layer of one network: the plan, the default
/// receive deadline it imposes (so drops become TOEs, not hangs), and
/// the typed-event sink the coordinator drains after the attempt.
pub struct FaultLayer {
    plan: FaultPlan,
    /// 1-based attempt the layer belongs to (stamped on events).
    attempt: u32,
    /// Deadline applied to receives that would otherwise block forever.
    /// `None` keeps the substrate's native behavior (virtual-clock
    /// worlds then end in the all-blocked poison error — see
    /// `rust/tests/faultnet.rs`).
    recv_deadline: Option<Duration>,
    pub counters: FaultCounters,
    events: Mutex<Vec<Event>>,
}

impl FaultLayer {
    pub fn new(
        plan: FaultPlan,
        attempt: u32,
        recv_deadline: Option<Duration>,
    ) -> FaultLayer {
        FaultLayer {
            plan,
            attempt,
            recv_deadline,
            counters: FaultCounters::default(),
            events: Mutex::new(Vec::new()),
        }
    }

    /// The layer a coordinator attempt installs: plan seeded from the
    /// run seed *and the attempt number* — soft errors are transient, so
    /// a re-execution must not replay the identical perturbations (that
    /// is what lets checkpoint recovery actually succeed under faults).
    pub fn for_attempt(
        mode: NetFaultMode,
        run_seed: u64,
        attempt: u32,
        recv_deadline: Duration,
    ) -> Option<FaultLayer> {
        if mode == NetFaultMode::None {
            return None;
        }
        let seed = fold(fold(run_seed, 0x5EDA_0F17), attempt as u64);
        Some(FaultLayer::new(
            FaultPlan::new(mode, seed),
            attempt,
            Some(recv_deadline),
        ))
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn recv_deadline(&self) -> Option<Duration> {
        self.recv_deadline
    }

    /// Record one non-deliver action as a typed event (tick-stamped by
    /// the caller, which holds the world clock).
    pub fn record(
        &self,
        tick: Tick,
        src: usize,
        dst: usize,
        tag: u32,
        seq: u64,
        action: &FaultAction,
    ) {
        let ctr = match action {
            FaultAction::Deliver => return,
            FaultAction::Drop => &self.counters.drops,
            FaultAction::Duplicate => &self.counters.dups,
            FaultAction::Delay(_) => &self.counters.delays,
            FaultAction::CorruptBit(_) => &self.counters.corrupts,
        };
        ctr.fetch_add(1, Ordering::Relaxed);
        let detail = match action {
            FaultAction::Delay(d) => format!(
                "netfault: delay {d} ticks src={src} dst={dst} tag={tag} seq={seq}"
            ),
            other => format!(
                "netfault: {} src={src} dst={dst} tag={tag} seq={seq}",
                other.label()
            ),
        };
        self.events.lock().unwrap().push(Event {
            tick,
            rank: src as u32,
            replica: 0,
            attempt: self.attempt,
            kind: EventKind::NetFault,
            detail,
        });
    }

    /// Total non-deliver actions applied so far.
    pub fn faults_applied(&self) -> u64 {
        self.counters.drops.load(Ordering::Relaxed)
            + self.counters.dups.load(Ordering::Relaxed)
            + self.counters.delays.load(Ordering::Relaxed)
            + self.counters.corrupts.load(Ordering::Relaxed)
    }

    /// Drain the typed events recorded so far (coordinator, post-join).
    pub fn take_events(&self) -> Vec<Event> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_label_ordinal_roundtrip() {
        for m in NetFaultMode::ALL {
            assert_eq!(NetFaultMode::parse(m.label()).unwrap(), m);
            assert_eq!(NetFaultMode::from_ordinal(m.ordinal()), Some(m));
        }
        assert_eq!(NetFaultMode::parse("duplicate").unwrap(), NetFaultMode::Dup);
        assert!(NetFaultMode::parse("gamma-ray").is_err());
        assert_eq!(NetFaultMode::from_ordinal(99), None);
    }

    #[test]
    fn plan_is_a_pure_function_of_seed_and_message() {
        let a = FaultPlan::new(NetFaultMode::Mixed, 7);
        let b = FaultPlan::new(NetFaultMode::Mixed, 7);
        for seq in 0..500 {
            assert_eq!(a.action(0, 1, seq), b.action(0, 1, seq));
        }
        // Different seeds must disagree somewhere.
        let c = FaultPlan::new(NetFaultMode::Mixed, 8);
        assert!((0..500).any(|seq| a.action(0, 1, seq) != c.action(0, 1, seq)));
        // Different pairs draw independent streams.
        assert!((0..500).any(|seq| a.action(0, 1, seq) != a.action(1, 0, seq)));
    }

    #[test]
    fn mixed_plan_covers_every_action_family() {
        let p = FaultPlan::new(NetFaultMode::Mixed, 42);
        let mut seen = [false; 5];
        for seq in 0..2000 {
            let idx = match p.action(0, 1, seq) {
                FaultAction::Deliver => 0,
                FaultAction::Drop => 1,
                FaultAction::Duplicate => 2,
                FaultAction::Delay(d) => {
                    assert!(d >= 1 && d <= MAX_DELAY_TICKS);
                    3
                }
                FaultAction::CorruptBit(_) => 4,
            };
            seen[idx] = true;
        }
        assert_eq!(seen, [true; 5], "mixed plan missed an action family");
    }

    #[test]
    fn single_family_plans_emit_only_their_action() {
        for (mode, want) in [
            (NetFaultMode::Drop, "drop"),
            (NetFaultMode::Dup, "dup"),
            (NetFaultMode::Reorder, "delay"),
            (NetFaultMode::Corrupt, "corrupt"),
        ] {
            let p = FaultPlan::new(mode, 3);
            let mut faulted = 0u32;
            for seq in 0..2000 {
                let a = p.action(0, 1, seq);
                if a != FaultAction::Deliver {
                    assert_eq!(a.label(), want, "{mode:?} produced {a:?}");
                    faulted += 1;
                }
            }
            assert!(faulted > 0, "{mode:?} plan never faulted in 2000 msgs");
            assert!(
                faulted < 1000,
                "{mode:?} plan faulted {faulted}/2000 — most traffic must flow"
            );
        }
        let none = FaultPlan::new(NetFaultMode::None, 3);
        assert!((0..100).all(|s| none.action(0, 1, s) == FaultAction::Deliver));
    }

    #[test]
    fn layer_records_typed_events_and_counters() {
        let layer = FaultLayer::new(FaultPlan::new(NetFaultMode::Mixed, 1), 2, None);
        layer.record(10, 0, 1, 7, 3, &FaultAction::Drop);
        layer.record(20, 1, 0, 8, 4, &FaultAction::Delay(500));
        layer.record(30, 0, 1, 7, 5, &FaultAction::Deliver); // not recorded
        assert_eq!(layer.faults_applied(), 2);
        let events = layer.take_events();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, EventKind::NetFault);
        assert_eq!(events[0].attempt, 2);
        assert!(events[0].detail.contains("drop src=0 dst=1 tag=7 seq=3"));
        assert!(events[1].detail.contains("delay 500 ticks"));
        // Drained: a second take is empty.
        assert!(layer.take_events().is_empty());
    }

    #[test]
    fn for_attempt_varies_across_attempts_and_skips_none() {
        assert!(FaultLayer::for_attempt(
            NetFaultMode::None,
            7,
            1,
            Duration::from_secs(2)
        )
        .is_none());
        let a1 =
            FaultLayer::for_attempt(NetFaultMode::Mixed, 7, 1, Duration::from_secs(2)).unwrap();
        let a2 =
            FaultLayer::for_attempt(NetFaultMode::Mixed, 7, 2, Duration::from_secs(2)).unwrap();
        // Transient faults: attempt 2 must not replay attempt 1's plan.
        assert!((0..500).any(|s| a1.plan().action(0, 1, s) != a2.plan().action(0, 1, s)));
        assert_eq!(a1.recv_deadline(), Some(Duration::from_secs(2)));
    }
}
