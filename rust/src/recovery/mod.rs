//! Rollback decision logic — Algorithms 1 and 2 of the paper.
//!
//! The mechanics are deliberately file-based, like the paper's prototype
//! (§4.2): `failures.txt` counts how many times the (same) fault has been
//! detected across re-executions, and — because it lives **outside** the
//! checkpointed state — survives rollbacks. Algorithm 1 turns that counter
//! plus the chain length into the checkpoint number to restart from,
//! walking one step further back on every re-detection until the fault no
//! longer manifests (or the beginning of the program is reached).

use std::path::{Path, PathBuf};

use crate::config::Strategy;
use crate::error::{Result, SedarError};

/// Where an execution attempt (re)starts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResumeFrom {
    /// Fresh start (first attempt, or no usable checkpoint remains).
    Scratch,
    /// `dmtcp_restart` from system-level checkpoint `k` (Algorithm 1).
    SysCkpt(u64),
    /// Restore the single valid user-level checkpoint `k` (Algorithm 2).
    UserCkpt(u64),
}

impl std::fmt::Display for ResumeFrom {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResumeFrom::Scratch => write!(f, "scratch"),
            ResumeFrom::SysCkpt(k) => write!(f, "sys-ck{k}"),
            ResumeFrom::UserCkpt(k) => write!(f, "user-ck{k}"),
        }
    }
}

/// The `failures.txt` external rollback counter of §4.2 — `extern_counter`
/// in Algorithm 1. Persisted so it is independent of checkpoint storage.
pub struct ExternCounter {
    path: PathBuf,
}

impl ExternCounter {
    pub fn at(dir: &Path) -> Result<ExternCounter> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join("failures.txt");
        if !path.exists() {
            std::fs::write(&path, "0")?;
        }
        Ok(ExternCounter { path })
    }

    pub fn read(&self) -> Result<u32> {
        std::fs::read_to_string(&self.path)?
            .trim()
            .parse()
            .map_err(|e| SedarError::Checkpoint(format!("bad failures.txt: {e}")))
    }

    /// `extern_counter++` (Algorithm 1 line 10). Returns the new value.
    pub fn increment(&self) -> Result<u32> {
        let v = self.read()? + 1;
        std::fs::write(&self.path, v.to_string())?;
        Ok(v)
    }

    pub fn reset(&self) -> Result<()> {
        std::fs::write(&self.path, "0")?;
        Ok(())
    }
}

/// Algorithm 1 line 14: `ckpt_no = ckpt_count - extern_counter`.
///
/// `ckpt_count` is the number of checkpoints stored by the last execution;
/// the first detection (`extern_counter == 1`) restarts from the last one,
/// each further detection walks one step back. `None` = the chain is
/// exhausted: relaunch from the beginning (the Figure 2(b) worst case).
pub fn algorithm1_target(ckpt_count: u64, extern_counter: u32) -> Option<u64> {
    let t = ckpt_count as i64 - extern_counter as i64;
    if t >= 0 {
        Some(t as u64)
    } else {
        None
    }
}

/// The per-strategy resume decision after a detection.
pub fn decide_resume(
    strategy: Strategy,
    sys_count: Option<u64>,
    extern_counter: u32,
    user_latest: Option<u64>,
) -> ResumeFrom {
    match strategy {
        // §3.1: safe stop + notify; the modeled response (Equation 4)
        // relaunches from the beginning.
        Strategy::Baseline | Strategy::DetectOnly => ResumeFrom::Scratch,
        Strategy::SysCkpt => match algorithm1_target(sys_count.unwrap_or(0), extern_counter) {
            Some(k) => ResumeFrom::SysCkpt(k),
            None => ResumeFrom::Scratch,
        },
        // Algorithm 2: the latest valid checkpoint is by construction the
        // only one on disk; if none was ever validated, start over.
        Strategy::UserCkpt => match user_latest {
            Some(k) => ResumeFrom::UserCkpt(k),
            None => ResumeFrom::Scratch,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm1_walks_backwards() {
        // 4 checkpoints stored (ck0..ck3).
        assert_eq!(algorithm1_target(4, 1), Some(3)); // last
        assert_eq!(algorithm1_target(4, 2), Some(2)); // last-but-one
        assert_eq!(algorithm1_target(4, 4), Some(0)); // first
        assert_eq!(algorithm1_target(4, 5), None); // from scratch
        assert_eq!(algorithm1_target(0, 1), None); // nothing stored yet
    }

    #[test]
    fn extern_counter_persists() {
        let dir = std::env::temp_dir().join(format!(
            "sedar-ec-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let c = ExternCounter::at(&dir).unwrap();
        assert_eq!(c.read().unwrap(), 0);
        assert_eq!(c.increment().unwrap(), 1);
        assert_eq!(c.increment().unwrap(), 2);
        // Re-open (process restart): value survives.
        let c2 = ExternCounter::at(&dir).unwrap();
        assert_eq!(c2.read().unwrap(), 2);
        c2.reset().unwrap();
        assert_eq!(c2.read().unwrap(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_decisions_per_strategy() {
        use Strategy::*;
        assert_eq!(
            decide_resume(DetectOnly, None, 1, None),
            ResumeFrom::Scratch
        );
        assert_eq!(
            decide_resume(SysCkpt, Some(3), 1, None),
            ResumeFrom::SysCkpt(2)
        );
        assert_eq!(
            decide_resume(SysCkpt, Some(3), 4, None),
            ResumeFrom::Scratch
        );
        assert_eq!(
            decide_resume(UserCkpt, None, 1, Some(5)),
            ResumeFrom::UserCkpt(5)
        );
        assert_eq!(decide_resume(UserCkpt, None, 1, None), ResumeFrom::Scratch);
    }
}
