//! Multicore-cluster topology model and replica placement.
//!
//! The paper's testbed is a Blade cluster: nodes with two quad-core Xeon
//! sockets in which **pairs of cores share an L2 cache**. SEDAR places each
//! replica thread on the cache-sharing sibling of its original process's
//! core, so replica comparisons are resolved inside the shared cache (§3.1,
//! Figure 1).
//!
//! Our ranks are in-process threads, so placement cannot change physical
//! cache residency; the model is still load-bearing in three ways:
//!
//! * it *validates* requested rank counts against available core pairs, the
//!   same capacity constraint a real deployment has;
//! * it computes the mapping tables the reports print (which core runs which
//!   replica, which pairs share cache), mirroring the paper's mapping
//!   discussion (§4.3: 8 MPI processes, ≤4 per node, siblings on free cores);
//! * the baseline strategy uses it to express "two independent instances,
//!   each with half the cores" (§3, baseline).

/// One core of the modeled machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoreId {
    pub node: usize,
    pub socket: usize,
    pub core: usize,
}

impl std::fmt::Display for CoreId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}s{}c{}", self.node, self.socket, self.core)
    }
}

/// Cluster shape: `nodes × sockets/node × cores/socket`, with cores grouped
/// in cache-sharing pairs (consecutive even/odd core ids share a cache, like
/// the Xeon e5405's 2×6MB L2 shared between pairs of cores).
#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: usize,
    pub sockets_per_node: usize,
    pub cores_per_socket: usize,
}

impl Topology {
    /// The paper's testbed: 8 nodes × 2 sockets × 4 cores (quad-core Xeon
    /// e5405), cache shared between pairs of cores.
    pub fn paper_testbed() -> Self {
        Topology {
            nodes: 8,
            sockets_per_node: 2,
            cores_per_socket: 4,
        }
    }

    /// A small model for unit tests / local runs.
    pub fn small(nodes: usize) -> Self {
        Topology {
            nodes,
            sockets_per_node: 1,
            cores_per_socket: 4,
        }
    }

    pub fn total_cores(&self) -> usize {
        self.nodes * self.sockets_per_node * self.cores_per_socket
    }

    /// Number of cache-sharing core *pairs* (each pair hosts one rank: the
    /// leading thread plus its replica).
    pub fn replica_slots(&self) -> usize {
        self.total_cores() / 2
    }

    /// Enumerate all cores in deterministic order.
    pub fn cores(&self) -> Vec<CoreId> {
        let mut v = Vec::with_capacity(self.total_cores());
        for node in 0..self.nodes {
            for socket in 0..self.sockets_per_node {
                for core in 0..self.cores_per_socket {
                    v.push(CoreId { node, socket, core });
                }
            }
        }
        v
    }

    /// The cache-sharing sibling of a core (pairing consecutive cores within
    /// a socket: 0↔1, 2↔3).
    pub fn cache_sibling(&self, c: CoreId) -> CoreId {
        CoreId {
            node: c.node,
            socket: c.socket,
            core: c.core ^ 1,
        }
    }

    pub fn shares_cache(&self, a: CoreId, b: CoreId) -> bool {
        a.node == b.node && a.socket == b.socket && (a.core ^ 1) == b.core
    }
}

/// Where the two replicas of one rank run.
#[derive(Debug, Clone, Copy)]
pub struct RankPlacement {
    pub rank: usize,
    /// Core of the leading thread (replica 0).
    pub lead: CoreId,
    /// Core of the replica thread (replica 1) — always the cache sibling.
    pub replica: CoreId,
}

/// Placement of a whole SEDAR job.
#[derive(Debug, Clone)]
pub struct Placement {
    pub ranks: Vec<RankPlacement>,
}

#[derive(Debug)]
pub struct PlacementError {
    pub requested: usize,
    pub available: usize,
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "placement: requested {} ranks but topology has only {} replica slots",
            self.requested, self.available
        )
    }
}

impl std::error::Error for PlacementError {}

impl Placement {
    /// SEDAR placement (§3.1): rank *r*'s leading thread goes on the even
    /// core of pair *r*; its replica goes on the cache-sharing odd sibling.
    /// Pairs are filled node-major so ranks spread across nodes last, like
    /// the paper's "maximum of four processes mapped in each node".
    pub fn sedar(topo: &Topology, nranks: usize) -> Result<Placement, PlacementError> {
        if nranks > topo.replica_slots() {
            return Err(PlacementError {
                requested: nranks,
                available: topo.replica_slots(),
            });
        }
        let cores = topo.cores();
        let mut ranks = Vec::with_capacity(nranks);
        // Even-indexed cores are pair leaders.
        let leaders: Vec<CoreId> = cores.iter().copied().filter(|c| c.core % 2 == 0).collect();
        for (rank, lead) in leaders.into_iter().take(nranks).enumerate() {
            ranks.push(RankPlacement {
                rank,
                lead,
                replica: topo.cache_sibling(lead),
            });
        }
        Ok(Placement { ranks })
    }

    /// Baseline placement (§3): two independent application instances, each
    /// using half of the cores, same rank mapping for both instances.
    /// Instance 0 takes even cores, instance 1 takes odd cores.
    pub fn baseline(
        topo: &Topology,
        nranks: usize,
    ) -> Result<(Placement, Placement), PlacementError> {
        let p = Self::sedar(topo, nranks)?;
        let inst0 = Placement {
            ranks: p
                .ranks
                .iter()
                .map(|r| RankPlacement {
                    rank: r.rank,
                    lead: r.lead,
                    replica: r.lead, // no replication in the baseline
                })
                .collect(),
        };
        let inst1 = Placement {
            ranks: p
                .ranks
                .iter()
                .map(|r| RankPlacement {
                    rank: r.rank,
                    lead: r.replica,
                    replica: r.replica,
                })
                .collect(),
        };
        Ok((inst0, inst1))
    }

    /// Human-readable mapping table (printed by run reports).
    pub fn table(&self) -> String {
        let mut s = String::from("| rank | lead core | replica core |\n|---|---|---|\n");
        for r in &self.ranks {
            s.push_str(&format!("| {} | {} | {} |\n", r.rank, r.lead, r.replica));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_capacity() {
        let t = Topology::paper_testbed();
        assert_eq!(t.total_cores(), 64);
        assert_eq!(t.replica_slots(), 32);
    }

    #[test]
    fn siblings_share_cache() {
        let t = Topology::paper_testbed();
        for c in t.cores() {
            let s = t.cache_sibling(c);
            assert!(t.shares_cache(c, s));
            assert_eq!(t.cache_sibling(s), c);
        }
    }

    #[test]
    fn sedar_placement_uses_sibling_pairs() {
        let t = Topology::small(2);
        let p = Placement::sedar(&t, 4).unwrap();
        assert_eq!(p.ranks.len(), 4);
        for r in &p.ranks {
            assert!(t.shares_cache(r.lead, r.replica));
        }
    }

    #[test]
    fn paper_mapping_four_ranks_per_node() {
        // §4.3: 8 MPI processes, max 4 per node → replicas fill the node's
        // remaining cores.
        let t = Topology::paper_testbed();
        let p = Placement::sedar(&t, 8).unwrap();
        let on_node0 = p.ranks.iter().filter(|r| r.lead.node == 0).count();
        assert_eq!(on_node0, 4);
        let on_node1 = p.ranks.iter().filter(|r| r.lead.node == 1).count();
        assert_eq!(on_node1, 4);
    }

    #[test]
    fn placement_rejects_oversubscription() {
        let t = Topology::small(1); // 4 cores → 2 slots
        assert!(Placement::sedar(&t, 3).is_err());
    }

    #[test]
    fn baseline_instances_disjoint() {
        let t = Topology::small(2);
        let (a, b) = Placement::baseline(&t, 4).unwrap();
        for (ra, rb) in a.ranks.iter().zip(&b.ranks) {
            assert_ne!(ra.lead, rb.lead);
        }
    }
}
