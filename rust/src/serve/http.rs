//! The gateway's request layer: bounded std-only HTTP/1.0, both sides.
//!
//! The status server ([`crate::fleet::status`]) only ever needed the
//! request *line* — every route is a GET with no body. The gateway adds
//! `POST /submit`, so this module reads full requests (head + body) under
//! hard caps ([`MAX_HEAD`], [`MAX_BODY`]) and a per-request deadline, and
//! provides the matching client half ([`http_post`]) built on the same
//! hardened deadline-bounded response reader as
//! [`crate::fleet::status::http_get`]. Same wire format as the status
//! server: `HTTP/1.0`, `Connection: close`, explicit `Content-Length`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::fleet::status::{parse_ok_body, read_response, MAX_RESPONSE};

/// Cap on the request head (request line + headers).
pub(crate) const MAX_HEAD: usize = 8 * 1024;
/// Cap on a request body. Submission bodies are a few hundred bytes of
/// `key=value` lines; 64 KiB is generous.
pub(crate) const MAX_BODY: usize = 64 * 1024;
/// A whole request must arrive within this window.
const REQUEST_DEADLINE: Duration = Duration::from_secs(2);

/// One parsed request.
pub(crate) struct Request {
    pub method: String,
    /// The raw request target (`/sweep/sweep-0001/json?x=1`).
    pub target: String,
    pub body: String,
}

/// Read from `conn` until `want(buf)` yields, under `deadline`. Treats
/// per-read timeouts as retries so a segmented request still parses, but
/// the overall deadline is hard.
fn read_until<T>(
    conn: &mut TcpStream,
    deadline: Instant,
    cap: usize,
    buf: &mut Vec<u8>,
    mut want: impl FnMut(&[u8]) -> Option<T>,
) -> std::io::Result<Option<T>> {
    let mut chunk = [0u8; 1024];
    loop {
        if let Some(t) = want(buf) {
            return Ok(Some(t));
        }
        if buf.len() >= cap {
            return Err(std::io::Error::other(format!(
                "request exceeds {cap} byte cap"
            )));
        }
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request deadline exceeded",
            ));
        }
        conn.set_read_timeout(Some(
            left.min(Duration::from_millis(250))
                .max(Duration::from_millis(1)),
        ))?;
        match conn.read(&mut chunk) {
            Ok(0) => return Ok(None), // peer closed early
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e),
        }
    }
}

/// Position just past the `\r\n\r\n` head/body break, if present.
fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Case-insensitive `Content-Length` lookup in the head.
fn content_length(head: &str) -> usize {
    head.lines()
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse().ok())
        .unwrap_or(0)
}

/// Read one whole request (head, then `Content-Length` bytes of body)
/// under the caps and deadline.
pub(crate) fn read_request(conn: &mut TcpStream) -> std::io::Result<Request> {
    let deadline = Instant::now() + REQUEST_DEADLINE;
    let mut buf: Vec<u8> = Vec::new();
    let Some(head_len) = read_until(conn, deadline, MAX_HEAD, &mut buf, head_end)? else {
        return Err(std::io::Error::other("connection closed mid-request"));
    };
    let head = String::from_utf8_lossy(&buf[..head_len]).into_owned();
    let mut first = head.lines().next().unwrap_or("").split_whitespace();
    let method = first.next().unwrap_or("").to_string();
    let target = first.next().unwrap_or("/").to_string();
    let want = content_length(&head);
    if want > MAX_BODY {
        return Err(std::io::Error::other(format!(
            "request body of {want} bytes exceeds the {MAX_BODY} byte cap"
        )));
    }
    let need = head_len + want;
    if read_until(conn, deadline, need, &mut buf, |b| {
        (b.len() >= need).then_some(())
    })?
    .is_none()
        && buf.len() < need
    {
        return Err(std::io::Error::other("connection closed mid-body"));
    }
    let body = String::from_utf8_lossy(&buf[head_len..need]).into_owned();
    Ok(Request {
        method,
        target,
        body,
    })
}

/// Write one HTTP/1.0 response (the status server's exact wire shape).
pub(crate) fn respond(
    conn: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let response = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(response.as_bytes())?;
    conn.flush()
}

/// Std-only HTTP POST: one request, the whole response read to EOF under
/// `timeout` and the shared response cap, the body returned iff the
/// status line says 200.
pub fn http_post(
    addr: SocketAddr,
    path: &str,
    body: &str,
    timeout: Duration,
) -> std::io::Result<String> {
    let deadline = Instant::now() + timeout;
    let mut conn = TcpStream::connect_timeout(&addr, timeout)?;
    conn.set_write_timeout(Some(timeout))?;
    conn.write_all(
        format!(
            "POST {path} HTTP/1.0\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    let raw = read_response(&mut conn, deadline, MAX_RESPONSE)?;
    parse_ok_body(&String::from_utf8_lossy(&raw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn one_request(write_script: impl FnOnce(&mut TcpStream) + Send + 'static) -> std::io::Result<Request> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            write_script(&mut conn);
            // Keep the socket open long enough for the server side to
            // finish parsing (close is the client's EOF signal).
            std::thread::sleep(Duration::from_millis(100));
        });
        let (mut conn, _) = listener.accept().unwrap();
        let req = read_request(&mut conn);
        client.join().unwrap();
        req
    }

    #[test]
    fn parses_a_segmented_post() {
        let req = one_request(|conn| {
            conn.write_all(b"POST /sub").unwrap();
            conn.flush().unwrap();
            std::thread::sleep(Duration::from_millis(30));
            conn.write_all(b"mit HTTP/1.0\r\nContent-Length: 17\r\n\r\nseed=7\n")
                .unwrap();
            conn.flush().unwrap();
            std::thread::sleep(Duration::from_millis(30));
            conn.write_all(b"filter=a=b").unwrap();
            conn.flush().unwrap();
        })
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/submit");
        assert_eq!(req.body, "seed=7\nfilter=a=b");
    }

    #[test]
    fn rejects_an_oversized_body_by_declared_length() {
        let err = one_request(|conn| {
            conn.write_all(
                format!("POST /submit HTTP/1.0\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1)
                    .as_bytes(),
            )
            .unwrap();
        })
        .unwrap_err();
        assert!(err.to_string().contains("byte cap"), "got: {err}");
    }

    #[test]
    fn get_requests_have_empty_bodies() {
        let req = one_request(|conn| {
            conn.write_all(b"GET /sweeps HTTP/1.0\r\n\r\n").unwrap();
        })
        .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/sweeps");
        assert!(req.body.is_empty());
    }
}
