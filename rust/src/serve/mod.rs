//! Campaign as a service: the `sedar serve` gateway.
//!
//! The paper frames SEDAR as a methodology for *users* of scientific
//! applications — plural — and its overhead guidelines only pay off when
//! many small what-if sweeps are cheap to run against a warm system. This
//! module promotes the one-shot `fleet launch` driver into a long-running
//! daemon that multiplexes **many** users' sweeps onto **one** pooled
//! worker fleet:
//!
//! * **Ingress** ([`http`]): `POST /submit` with a `key=value` body
//!   (`user`, `seed`, `shards`, `jobs`, `filter`, `scenario`) accepts a
//!   sweep; `GET /sweeps` lists all of them, `GET /sweep/ID/json` serves
//!   a sweep's live aggregate, `GET /sweep/ID/report` its final merged
//!   report, `GET /metrics` the gateway's Prometheus counters. All
//!   std-only, all bounded (request caps + deadlines).
//! * **Admission** ([`queue`]): a per-client token bucket (`--rate`,
//!   `--burst`) rejects submission floods with 429s, and a per-user cap
//!   on queued+running sweeps (`--queue-cap`) bounds any one user's
//!   standing claim on the fleet.
//! * **Scheduling**: `--workers W` is the pooled budget of concurrent
//!   shard processes. A round-robin cursor hands free slots to active
//!   sweeps one shard at a time — fair-share across submissions rather
//!   than FIFO head-of-line blocking, so a 4-shard sweep and a 64-shard
//!   sweep make proportional progress.
//! * **Durability** ([`manifest`]): every accepted submission is
//!   journaled (CRC-framed, synced before the 200) and every merge
//!   recorded. A daemon killed at any instant and restarted over the same
//!   `--dir` replays the manifest, kills any orphaned shard processes,
//!   re-adopts every sweep over its existing WAL directory (the PR-9
//!   lenient reader) and resumes — crash recovery for the service is the
//!   same code path as crash recovery for a shard.
//!
//! The invariant that makes the service trustworthy is inherited, not
//! re-proven: each sweep is a [`crate::fleet::sweep::Sweep`], so its
//! merged report is byte-identical to the equivalent standalone
//! `sedar campaign` run — regardless of pooling, interleaving, restarts
//! or daemon crashes (CI `serve-smoke` byte-diffs both, across a SIGKILL).

pub mod http;
pub mod manifest;
pub mod queue;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::{Result, SedarError};
use crate::fleet::status::StatusSource;
use crate::fleet::supervisor::{LocalSpawner, Spawner, SupervisorConfig};
use crate::fleet::sweep::{Sweep, SweepConfig, SweepState};

use http::{read_request, respond, Request};
use manifest::{Manifest, Submission};
use queue::Admission;

/// How the daemon runs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Listen port (`0` = OS-assigned; pair with `--addr-file`).
    pub port: u16,
    /// Pooled budget of concurrent shard processes across all sweeps.
    pub workers: usize,
    /// Service directory: the submission manifest plus one sweep
    /// directory (WALs, logs, report) per submission.
    pub dir: PathBuf,
    /// Scheduler/poll cadence.
    pub poll_interval: Duration,
    /// Per-shard stall timeout (as in `fleet launch`).
    pub stall_timeout: Duration,
    /// Per-shard relaunch budget (as in `fleet launch`).
    pub max_restarts: usize,
    /// Token-bucket refill rate per client, submissions/second.
    pub rate: f64,
    /// Token-bucket burst capacity per client.
    pub burst: f64,
    /// Max queued+running sweeps per user.
    pub queue_cap: usize,
    /// After binding, atomically write the actual listen address here
    /// (the same handshake fleet shards use).
    pub addr_file: Option<PathBuf>,
    /// The `sedar` binary to spawn for shards (`None` = this executable).
    pub bin: Option<PathBuf>,
    /// Suppress per-tick progress chatter (adoption/merge notices still
    /// print).
    pub quiet: bool,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            port: 0,
            workers: 4,
            dir: PathBuf::from("runs/serve"),
            poll_interval: Duration::from_millis(50),
            stall_timeout: Duration::from_secs(300),
            max_restarts: 3,
            rate: 5.0,
            burst: 10.0,
            queue_cap: 8,
            addr_file: None,
            bin: None,
            quiet: false,
        }
    }
}

/// One tracked submission: its identity plus the live [`Sweep`].
struct Entry {
    id: String,
    user: String,
    sweep: Sweep,
    /// The manifest already holds this sweep's DONE record (restart
    /// adoption of an already-merged sweep must not journal it twice).
    journaled_done: bool,
}

/// The gateway: submission table, admission control, scheduler state and
/// the journal. Single-threaded by design — one [`Gateway::tick`] drains
/// the listener, schedules shard starts and polls every active sweep; the
/// heavy work (the campaigns themselves) lives in child processes.
pub struct Gateway {
    opts: ServeOptions,
    bin: PathBuf,
    spawner: Arc<dyn Spawner>,
    entries: Vec<Entry>,
    admission: Admission,
    manifest: Manifest,
    next_id: u64,
    /// Round-robin fair-share cursor over `entries`.
    cursor: usize,
    submitted: u64,
    rejected: u64,
    merged: u64,
    failed: u64,
}

/// Best-effort `kill -9` of shard pids recorded under `dir` — a SIGKILLed
/// daemon orphans its running shard children, and a restarted daemon must
/// not race a live writer on the same WAL. Pid reuse could in principle
/// kill an innocent process; the window (daemon crash → restart, pid files
/// removed right after) is accepted for this operational tool.
fn kill_stale_pids(dir: &std::path::Path) {
    #[cfg(unix)]
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name.starts_with("shard-") && name.ends_with(".pid") {
                if let Ok(pid) = std::fs::read_to_string(e.path()) {
                    let pid = pid.trim().to_string();
                    if !pid.is_empty() && pid.chars().all(|c| c.is_ascii_digit()) {
                        let _ = std::process::Command::new("kill")
                            .arg("-9")
                            .arg(&pid)
                            .status();
                    }
                }
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
}

/// Parse a `POST /submit` body: `key=value` lines (`user`, `seed`,
/// `shards`, `jobs`, `filter`, `scenario`), unknown keys rejected so a
/// typo cannot silently submit the wrong sweep.
fn parse_submission(body: &str) -> Result<(String, SweepConfig)> {
    let mut user = "anon".to_string();
    let mut cfg = SweepConfig {
        seed: 42,
        shards: 1,
        jobs: 0,
        filter: None,
        scenario: None,
    };
    for line in body.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(SedarError::Config(format!(
                "submit: malformed line '{line}' (expected key=value)"
            )));
        };
        // `value` keeps any embedded '=' — filters like `app=matmul`
        // depend on it.
        match key {
            "user" => user = value.to_string(),
            "seed" => {
                cfg.seed = value
                    .parse()
                    .map_err(|_| SedarError::Config(format!("submit: bad seed '{value}'")))?
            }
            "shards" => {
                cfg.shards = value
                    .parse()
                    .map_err(|_| SedarError::Config(format!("submit: bad shards '{value}'")))?
            }
            "jobs" => {
                cfg.jobs = value
                    .parse()
                    .map_err(|_| SedarError::Config(format!("submit: bad jobs '{value}'")))?
            }
            "filter" if !value.is_empty() => cfg.filter = Some(value.to_string()),
            "filter" => {}
            "scenario" if !value.is_empty() => cfg.scenario = Some(value.to_string()),
            "scenario" => {}
            other => {
                return Err(SedarError::Config(format!(
                    "submit: unknown key '{other}' (user, seed, shards, jobs, filter, scenario)"
                )))
            }
        }
    }
    if cfg.shards == 0 {
        return Err(SedarError::Config("submit: shards must be >= 1".into()));
    }
    Ok((user, cfg))
}

impl Gateway {
    /// Open (or re-open) the service over `opts.dir`: replay the
    /// manifest, kill orphaned shard processes, and re-adopt every
    /// journaled sweep over its existing directory.
    pub fn new(opts: &ServeOptions) -> Result<Gateway> {
        std::fs::create_dir_all(&opts.dir)?;
        let (manifest, replay) = Manifest::open(&opts.dir.join("serve.manifest"))?;
        let bin = match &opts.bin {
            Some(b) => b.clone(),
            None => std::env::current_exe()?,
        };
        let spawner: Arc<dyn Spawner> = Arc::new(LocalSpawner);
        let mut entries = Vec::new();
        let mut next_id: u64 = 1;
        for (sub, done) in replay {
            if let Some(n) = sub
                .id
                .strip_prefix("sweep-")
                .and_then(|s| s.parse::<u64>().ok())
            {
                next_id = next_id.max(n + 1);
            }
            let sweep_dir = opts.dir.join(&sub.id);
            if !done {
                // A SIGKILLed daemon orphans running shard children that
                // keep appending; two concurrent writers on one WAL is
                // the one thing the resume path cannot tolerate.
                kill_stale_pids(&sweep_dir);
            }
            let cfg = SweepConfig {
                seed: sub.seed,
                shards: sub.shards as usize,
                jobs: sub.jobs as usize,
                filter: sub.filter.clone(),
                scenario: sub.scenario.clone(),
            };
            match Sweep::new(
                cfg,
                sweep_dir,
                Some(bin.clone()),
                SupervisorConfig {
                    max_restarts: opts.max_restarts,
                    stall_timeout: opts.stall_timeout,
                },
                spawner.clone(),
            ) {
                Ok(sweep) => {
                    eprintln!(
                        "serve: adopted sweep {} (user {}, {} task(s){})",
                        sub.id,
                        sub.user,
                        sweep.total(),
                        if done { ", already merged" } else { "" }
                    );
                    entries.push(Entry {
                        id: sub.id,
                        user: sub.user,
                        sweep,
                        journaled_done: done,
                    });
                }
                // An unadoptable journal entry (e.g. the filter grammar
                // changed across versions) must not take the service
                // down with it.
                Err(e) => eprintln!("serve: cannot adopt sweep {}: {e}", sub.id),
            }
        }
        Ok(Gateway {
            opts: opts.clone(),
            bin,
            spawner,
            entries,
            admission: Admission::new(opts.rate, opts.burst),
            manifest,
            next_id,
            cursor: 0,
            submitted: 0,
            rejected: 0,
            merged: 0,
            failed: 0,
        })
    }

    /// One scheduler turn: drain pending connections, hand free worker
    /// slots to sweeps (fair-share round-robin), poll active sweeps, and
    /// finalize any that completed. Request/scheduling errors are
    /// reported per sweep or per connection — the daemon itself keeps
    /// running.
    pub fn tick(&mut self, listener: &TcpListener) {
        loop {
            match listener.accept() {
                Ok((mut stream, _)) => {
                    let _ = stream.set_nonblocking(false);
                    if let Err(e) = self.serve_client(&mut stream) {
                        if !self.opts.quiet {
                            eprintln!("serve: request error: {e}");
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    if !self.opts.quiet {
                        eprintln!("serve: accept error: {e}");
                    }
                    break;
                }
            }
        }
        self.schedule();
        self.poll_sweeps();
    }

    /// Live shard processes across every sweep (the pooled budget's
    /// denominator).
    fn running(&self) -> usize {
        self.entries.iter().map(|e| e.sweep.running()).sum()
    }

    /// Hand free worker slots to sweeps, one shard per sweep per pass —
    /// the round-robin cursor makes the shares fair across active
    /// submissions instead of FIFO head-of-line.
    fn schedule(&mut self) {
        loop {
            if self.running() >= self.opts.workers {
                return;
            }
            let n = self.entries.len();
            if n == 0 {
                return;
            }
            let mut started = false;
            for k in 0..n {
                let i = (self.cursor + k) % n;
                let e = &mut self.entries[i];
                let eligible = matches!(
                    e.sweep.state(),
                    SweepState::Queued | SweepState::Running
                ) && e.sweep.unstarted() > 0;
                if !eligible {
                    continue;
                }
                match e.sweep.start_one() {
                    Ok(true) => {
                        self.cursor = (i + 1) % n;
                        started = true;
                        break;
                    }
                    Ok(false) => {}
                    Err(err) => {
                        let why = err.to_string();
                        eprintln!("serve: sweep {} failed to start a shard: {why}", e.id);
                        e.sweep.fail(why);
                        self.failed += 1;
                    }
                }
            }
            if !started {
                return;
            }
        }
    }

    /// Poll every running sweep; finalize (merge + journal) the ones
    /// whose every slice is durable.
    fn poll_sweeps(&mut self) {
        for e in self.entries.iter_mut() {
            if *e.sweep.state() != SweepState::Running {
                continue;
            }
            if let Err(err) = e.sweep.poll() {
                let why = err.to_string();
                eprintln!("serve: sweep {} failed: {why}", e.id);
                e.sweep.fail(why);
                self.failed += 1;
                continue;
            }
            if !e.sweep.done() {
                continue;
            }
            match e.sweep.finalize() {
                Ok(report) => {
                    let path = e.sweep.dir().join("report.md");
                    let write = std::fs::write(&path, report.deterministic_report())
                        .map_err(SedarError::from)
                        .and_then(|()| {
                            if e.journaled_done {
                                Ok(())
                            } else {
                                self.manifest.record_done(&e.id)
                            }
                        });
                    match write {
                        Ok(()) => {
                            self.merged += 1;
                            eprintln!(
                                "serve: sweep {} merged — {} task(s), report {}",
                                e.id,
                                report.total(),
                                path.display()
                            );
                        }
                        Err(err) => {
                            let why = format!("cannot persist merge: {err}");
                            eprintln!("serve: sweep {} failed: {why}", e.id);
                            e.sweep.fail(why);
                            self.failed += 1;
                        }
                    }
                }
                Err(err) => {
                    let why = err.to_string();
                    eprintln!("serve: sweep {} failed to merge: {why}", e.id);
                    e.sweep.fail(why);
                    self.failed += 1;
                }
            }
        }
    }

    fn serve_client(&mut self, stream: &mut TcpStream) -> std::io::Result<()> {
        let req = read_request(stream)?;
        // Route on the path component alone (`/sweeps?x=1` is /sweeps).
        let path = req.target.split(['?', '#']).next().unwrap_or("/");
        match (req.method.as_str(), path) {
            ("POST", "/submit") => self.handle_submit(stream, &req),
            ("GET", "/sweeps") => {
                let rows: Vec<String> = self
                    .entries
                    .iter()
                    .map(|e| {
                        format!(
                            "{{\"sweep\":\"{}\",\"user\":\"{}\",\"state\":\"{}\",\
                             \"total\":{},\"done\":{},\"running\":{}}}",
                            e.id,
                            crate::report::json_escape(&e.user),
                            e.sweep.state().label(),
                            e.sweep.total(),
                            e.sweep.aggregate().done(),
                            e.sweep.running()
                        )
                    })
                    .collect();
                respond(
                    stream,
                    "200 OK",
                    "application/json",
                    &format!("[{}]", rows.join(",")),
                )
            }
            ("GET", "/metrics") => {
                respond(stream, "200 OK", "text/plain; version=0.0.4", &self.metrics())
            }
            ("GET", "/") => {
                let mut s = format!(
                    "SEDAR serve: {} sweep(s), {}/{} worker slot(s) busy\n",
                    self.entries.len(),
                    self.running(),
                    self.opts.workers
                );
                for e in &self.entries {
                    s.push_str(&format!(
                        "  {} [{}] user {} — {}/{} task(s)\n",
                        e.id,
                        e.sweep.state().label(),
                        e.user,
                        e.sweep.aggregate().done(),
                        e.sweep.total()
                    ));
                }
                respond(stream, "200 OK", "text/plain; charset=utf-8", &s)
            }
            ("GET", p) => {
                if let Some(rest) = p.strip_prefix("/sweep/") {
                    if let Some((id, tail)) = rest.split_once('/') {
                        return self.handle_sweep_get(stream, id, tail);
                    }
                }
                respond(
                    stream,
                    "404 Not Found",
                    "text/plain; charset=utf-8",
                    &format!(
                        "no such path: {p} (try /, /sweeps, /sweep/ID/json, \
                         /sweep/ID/report or /metrics)\n"
                    ),
                )
            }
            (m, p) => respond(
                stream,
                "405 Method Not Allowed",
                "text/plain; charset=utf-8",
                &format!("cannot {m} {p}\n"),
            ),
        }
    }

    fn handle_submit(&mut self, stream: &mut TcpStream, req: &Request) -> std::io::Result<()> {
        let (user, cfg) = match parse_submission(&req.body) {
            Ok(x) => x,
            Err(e) => {
                self.rejected += 1;
                return respond(
                    stream,
                    "400 Bad Request",
                    "text/plain; charset=utf-8",
                    &format!("{e}\n"),
                );
            }
        };
        if !self.admission.admit(&user) {
            self.rejected += 1;
            return respond(
                stream,
                "429 Too Many Requests",
                "text/plain; charset=utf-8",
                &format!("rate limited: client '{user}' is over its submission budget\n"),
            );
        }
        let active = self
            .entries
            .iter()
            .filter(|e| {
                e.user == user
                    && matches!(e.sweep.state(), SweepState::Queued | SweepState::Running)
            })
            .count();
        if active >= self.opts.queue_cap {
            self.rejected += 1;
            return respond(
                stream,
                "429 Too Many Requests",
                "text/plain; charset=utf-8",
                &format!(
                    "queue full: client '{user}' already has {active} queued/running sweep(s) \
                     (cap {})\n",
                    self.opts.queue_cap
                ),
            );
        }
        let id = format!("sweep-{:04}", self.next_id);
        let sweep = match Sweep::new(
            cfg.clone(),
            self.opts.dir.join(&id),
            Some(self.bin.clone()),
            SupervisorConfig {
                max_restarts: self.opts.max_restarts,
                stall_timeout: self.opts.stall_timeout,
            },
            self.spawner.clone(),
        ) {
            Ok(s) => s,
            Err(e) => {
                self.rejected += 1;
                return respond(
                    stream,
                    "400 Bad Request",
                    "text/plain; charset=utf-8",
                    &format!("{e}\n"),
                );
            }
        };
        // Journal before acknowledging: a 200 means the submission
        // survives a daemon crash.
        let sub = Submission {
            id: id.clone(),
            user: user.clone(),
            seed: cfg.seed,
            shards: cfg.shards as u32,
            jobs: cfg.jobs as u32,
            filter: cfg.filter.clone(),
            scenario: cfg.scenario.clone(),
        };
        if let Err(e) = self.manifest.record_submit(&sub) {
            return respond(
                stream,
                "500 Internal Server Error",
                "text/plain; charset=utf-8",
                &format!("cannot journal submission: {e}\n"),
            );
        }
        self.next_id += 1;
        self.submitted += 1;
        let body = format!(
            "{{\"sweep\":\"{id}\",\"state\":\"queued\",\"total\":{},\"shards\":{}}}",
            sweep.total(),
            cfg.shards
        );
        self.entries.push(Entry {
            id,
            user,
            sweep,
            journaled_done: false,
        });
        respond(stream, "200 OK", "application/json", &body)
    }

    fn handle_sweep_get(
        &mut self,
        stream: &mut TcpStream,
        id: &str,
        tail: &str,
    ) -> std::io::Result<()> {
        let Some(e) = self.entries.iter().find(|e| e.id == id) else {
            return respond(
                stream,
                "404 Not Found",
                "text/plain; charset=utf-8",
                &format!("no such sweep: {id}\n"),
            );
        };
        match tail {
            "json" => respond(
                stream,
                "200 OK",
                "application/json",
                &e.sweep.aggregate().json_snapshot(),
            ),
            "report" => {
                if *e.sweep.state() != SweepState::Merged {
                    return respond(
                        stream,
                        "404 Not Found",
                        "text/plain; charset=utf-8",
                        &format!("sweep {id} not merged yet (state: {})\n", e.sweep.state().label()),
                    );
                }
                match std::fs::read_to_string(e.sweep.dir().join("report.md")) {
                    Ok(report) => respond(stream, "200 OK", "text/markdown; charset=utf-8", &report),
                    Err(err) => respond(
                        stream,
                        "500 Internal Server Error",
                        "text/plain; charset=utf-8",
                        &format!("cannot read report for {id}: {err}\n"),
                    ),
                }
            }
            other => respond(
                stream,
                "404 Not Found",
                "text/plain; charset=utf-8",
                &format!("no such sweep view: {other} (try json or report)\n"),
            ),
        }
    }

    fn metrics(&self) -> String {
        let mut s = String::new();
        let mut metric = |name: &str, kind: &str, help: &str, value: String| {
            s.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        };
        metric(
            "sedar_serve_submissions_total",
            "counter",
            "Submissions accepted (journaled) since this daemon started.",
            self.submitted.to_string(),
        );
        metric(
            "sedar_serve_rejected_total",
            "counter",
            "Submissions rejected (parse, rate limit, queue cap).",
            self.rejected.to_string(),
        );
        metric(
            "sedar_serve_sweeps_merged_total",
            "counter",
            "Sweeps whose final report merged and persisted.",
            self.merged.to_string(),
        );
        metric(
            "sedar_serve_sweeps_failed_total",
            "counter",
            "Sweeps that failed (restart budget, identity drift, ...).",
            self.failed.to_string(),
        );
        let active = self
            .entries
            .iter()
            .filter(|e| {
                matches!(e.sweep.state(), SweepState::Queued | SweepState::Running)
            })
            .count();
        metric(
            "sedar_serve_sweeps_active",
            "gauge",
            "Sweeps currently queued or running.",
            active.to_string(),
        );
        metric(
            "sedar_serve_shards_running",
            "gauge",
            "Live shard processes across all sweeps.",
            self.running().to_string(),
        );
        metric(
            "sedar_serve_worker_slots",
            "gauge",
            "The pooled concurrent shard budget (--workers).",
            self.opts.workers.to_string(),
        );
        s
    }
}

fn bind(opts: &ServeOptions) -> Result<TcpListener> {
    let listener = TcpListener::bind(("127.0.0.1", opts.port)).map_err(|e| {
        SedarError::Config(format!("serve: --port {}: cannot bind: {e}", opts.port))
    })?;
    listener.set_nonblocking(true)?;
    Ok(listener)
}

fn write_addr_file(opts: &ServeOptions, addr: SocketAddr) -> Result<()> {
    if let Some(path) = &opts.addr_file {
        // Write-then-rename: a watcher polling for this file must never
        // observe a half-written address.
        let tmp = path.with_extension("addr-tmp");
        std::fs::write(&tmp, format!("{addr}\n"))?;
        std::fs::rename(&tmp, path)?;
    }
    Ok(())
}

/// Run the daemon in the foreground until killed. This is `sedar serve`.
pub fn run_serve(opts: &ServeOptions) -> Result<()> {
    let mut gw = Gateway::new(opts)?;
    let listener = bind(opts)?;
    let addr = listener.local_addr()?;
    eprintln!(
        "serve: gateway on http://{addr}/ — POST /submit, GET /sweeps, \
         /sweep/ID/json, /sweep/ID/report, /metrics"
    );
    eprintln!(
        "serve: {} pooled shard slot(s), dir {}",
        opts.workers,
        opts.dir.display()
    );
    write_addr_file(opts, addr)?;
    loop {
        gw.tick(&listener);
        std::thread::sleep(opts.poll_interval);
    }
}

/// An in-process daemon for tests and benches: the same gateway loop on a
/// background thread, stopped (and joined) on drop.
pub struct Daemon {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Daemon {
    pub fn spawn(opts: ServeOptions) -> Result<Daemon> {
        let mut gw = Gateway::new(&opts)?;
        let listener = bind(&opts)?;
        let addr = listener.local_addr()?;
        write_addr_file(&opts, addr)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name("sedar-serve".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    gw.tick(&listener);
                    std::thread::sleep(opts.poll_interval);
                }
            })?;
        Ok(Daemon {
            addr,
            stop,
            thread: Some(thread),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::status::http_get;
    use http::http_post;

    const T: Duration = Duration::from_secs(5);

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "sedar-serve-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// An ingress-only daemon: `workers: 0` means nothing ever spawns, so
    /// these tests exercise submission, admission, journaling and every
    /// GET route without depending on a `sedar` binary (under `cargo
    /// test`, `current_exe` is the test runner, not `sedar`).
    fn ingress_opts(dir: PathBuf) -> ServeOptions {
        ServeOptions {
            workers: 0,
            dir,
            poll_interval: Duration::from_millis(5),
            rate: 0.0,
            burst: 2.0,
            queue_cap: 8,
            quiet: true,
            ..ServeOptions::default()
        }
    }

    #[test]
    fn submissions_rate_limits_and_views() {
        let dir = tmp("ingress");
        let daemon = Daemon::spawn(ingress_opts(dir.clone())).unwrap();
        let addr = daemon.addr();

        // Two submissions fit alice's burst of 2.
        let a = http_post(addr, "/submit", "user=alice\nseed=7\nshards=2\nfilter=scenario=1-4", T)
            .unwrap();
        assert!(a.contains("\"sweep\":\"sweep-0001\""), "got: {a}");
        assert!(a.contains("\"state\":\"queued\""), "got: {a}");
        assert!(a.contains("\"shards\":2"), "got: {a}");
        let b = http_post(addr, "/submit", "user=alice\nseed=7\nshards=1\nscenario=5-8", T)
            .unwrap();
        assert!(b.contains("\"sweep\":\"sweep-0002\""), "got: {b}");
        // The third is rate limited (rate 0.0: the bucket never refills).
        let err = http_post(addr, "/submit", "user=alice\nseed=7", T).unwrap_err();
        assert!(err.to_string().contains("429"), "got: {err}");
        // …but bob has his own bucket.
        let c = http_post(addr, "/submit", "user=bob\nseed=9\nscenario=1-2", T).unwrap();
        assert!(c.contains("\"sweep\":\"sweep-0003\""), "got: {c}");

        // Malformed submissions are 400s, not 500s or accepts.
        for bad in ["seed=nope", "shards=0", "color=red", "no equals sign"] {
            let err = http_post(addr, "/submit", bad, T).unwrap_err();
            assert!(err.to_string().contains("400"), "body {bad}: got {err}");
        }

        // /sweeps lists all three, queued (workers: 0 ⇒ never started).
        let sweeps = http_get(addr, "/sweeps", T).unwrap();
        assert!(sweeps.contains("\"sweep\":\"sweep-0001\""), "got: {sweeps}");
        assert!(sweeps.contains("\"sweep\":\"sweep-0003\""), "got: {sweeps}");
        assert!(sweeps.contains("\"user\":\"bob\""), "got: {sweeps}");
        assert!(sweeps.contains("\"state\":\"queued\""), "got: {sweeps}");

        // Per-sweep live aggregate json; report 404s before the merge.
        let json = http_get(addr, "/sweep/sweep-0001/json", T).unwrap();
        assert!(json.contains("\"done\":0"), "got: {json}");
        assert!(json.contains("\"complete\":false"), "got: {json}");
        let err = http_get(addr, "/sweep/sweep-0001/report", T).unwrap_err();
        assert!(err.to_string().contains("404"), "got: {err}");
        let err = http_get(addr, "/sweep/sweep-9999/json", T).unwrap_err();
        assert!(err.to_string().contains("404"), "got: {err}");

        // Gateway metrics count what happened.
        let m = http_get(addr, "/metrics", T).unwrap();
        assert!(m.contains("sedar_serve_submissions_total 3"), "got: {m}");
        assert!(m.contains("sedar_serve_sweeps_active 3"), "got: {m}");
        assert!(m.contains("sedar_serve_worker_slots 0"), "got: {m}");
        // 1 rate-limit + 4 malformed.
        assert!(m.contains("sedar_serve_rejected_total 5"), "got: {m}");

        // Unknown paths and bad methods answer without wedging the loop.
        assert!(http_get(addr, "/nope", T).unwrap_err().to_string().contains("404"));

        drop(daemon);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn queue_cap_bounds_one_user() {
        let dir = tmp("cap");
        let mut opts = ingress_opts(dir.clone());
        opts.burst = 100.0;
        opts.queue_cap = 2;
        let daemon = Daemon::spawn(opts).unwrap();
        let addr = daemon.addr();
        assert!(http_post(addr, "/submit", "user=carol\nscenario=1-2", T).is_ok());
        assert!(http_post(addr, "/submit", "user=carol\nscenario=1-2", T).is_ok());
        let err = http_post(addr, "/submit", "user=carol\nscenario=1-2", T).unwrap_err();
        assert!(err.to_string().contains("429"), "got: {err}");
        drop(daemon);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restart_over_the_same_dir_adopts_journaled_sweeps() {
        let dir = tmp("adopt");
        {
            let daemon = Daemon::spawn(ingress_opts(dir.clone())).unwrap();
            http_post(daemon.addr(), "/submit", "user=alice\nseed=7\nshards=2\nscenario=1-4", T)
                .unwrap();
        } // daemon dropped — "crash"

        let daemon = Daemon::spawn(ingress_opts(dir.clone())).unwrap();
        let addr = daemon.addr();
        // The journaled sweep is back, same id, still queued.
        let sweeps = http_get(addr, "/sweeps", T).unwrap();
        assert!(sweeps.contains("\"sweep\":\"sweep-0001\""), "got: {sweeps}");
        assert!(sweeps.contains("\"user\":\"alice\""), "got: {sweeps}");
        // New ids continue past the adopted ones.
        let next =
            http_post(addr, "/submit", "user=alice\nseed=9\nscenario=1-2", T).unwrap();
        assert!(next.contains("\"sweep\":\"sweep-0002\""), "got: {next}");
        drop(daemon);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parse_submission_defaults_and_filters() {
        let (user, cfg) = parse_submission("").unwrap();
        assert_eq!(user, "anon");
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.shards, 1);
        let (_, cfg) =
            parse_submission("filter=app=matmul,strategy=sys\nseed=11\njobs=3").unwrap();
        // Embedded '=' survives: the filter value is everything after the
        // first separator.
        assert_eq!(cfg.filter.as_deref(), Some("app=matmul,strategy=sys"));
        assert_eq!(cfg.seed, 11);
        assert_eq!(cfg.jobs, 3);
        assert!(parse_submission("shards=0").is_err());
        assert!(parse_submission("unknown=1").is_err());
    }
}
