//! The gateway's submission journal: `serve.manifest`.
//!
//! One append-only CRC-framed file (the same [`crate::util::frame`] codec
//! the shard WALs use) holding one record per accepted submission and one
//! per merged sweep. Together with the per-sweep WAL directories this is
//! the daemon's *entire* durable state: a restarted `sedar serve` over the
//! same `--dir` replays the manifest, re-creates every sweep over its
//! existing directory, and resumes — crash recovery for the service is
//! the same code path as crash recovery for a shard.
//!
//! Format (`SDMF` v1): the first record's body is the magic `SDMF1`;
//! every later record starts with a tag byte — [`TAG_SUBMIT`] carries the
//! submission (id, user, seed, shards, jobs, filter, scenario),
//! [`TAG_DONE`] marks a sweep merged (its report is durable). Replay is
//! lenient like the WAL reader: a torn tail (daemon killed mid-append) is
//! dropped, never an error — at worst the daemon forgets the very last
//! accepted submission, which the client never got a 200 for anyway,
//! because [`Manifest::record_submit`] syncs *before* the gateway
//! acknowledges.

use std::fs::OpenOptions;
use std::path::Path;

use crate::error::{Result, SedarError};
use crate::util::frame::{next_record, push_string, write_record, ByteReader};

/// Magic body of the first record.
const MAGIC: &[u8] = b"SDMF1";
/// Record tag: one accepted submission.
const TAG_SUBMIT: u8 = 1;
/// Record tag: the named sweep merged its final report.
const TAG_DONE: u8 = 2;

/// One journaled submission, exactly as accepted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Submission {
    pub id: String,
    pub user: String,
    pub seed: u64,
    pub shards: u32,
    pub jobs: u32,
    pub filter: Option<String>,
    pub scenario: Option<String>,
}

/// The open journal (append handle). Reading happens once, at
/// [`Manifest::open`]; everything after is append-and-sync.
pub struct Manifest {
    file: std::fs::File,
}

fn opt_string(out: &mut Vec<u8>, s: &Option<String>) {
    push_string(out, s.as_deref().unwrap_or(""));
}

fn parse_submission(body: &[u8]) -> Result<Submission> {
    let mut r = ByteReader::new(body, "serve manifest submission");
    let id = r.string()?;
    let user = r.string()?;
    let seed = r.u64()?;
    let shards = r.u32()?;
    let jobs = r.u32()?;
    let none_if_empty = |s: String| if s.is_empty() { None } else { Some(s) };
    let filter = none_if_empty(r.string()?);
    let scenario = none_if_empty(r.string()?);
    Ok(Submission {
        id,
        user,
        seed,
        shards,
        jobs,
        filter,
        scenario,
    })
}

impl Manifest {
    /// Open (or create) the journal at `path` and replay it: every
    /// submission in acceptance order, each paired with whether a
    /// [`TAG_DONE`] record followed it.
    pub fn open(path: &Path) -> Result<(Manifest, Vec<(Submission, bool)>)> {
        let existing = std::fs::read(path).unwrap_or_default();
        let mut replay: Vec<(Submission, bool)> = Vec::new();
        if !existing.is_empty() {
            let (first, mut pos) = next_record(&existing, 0).ok_or_else(|| {
                SedarError::Config(format!(
                    "{}: not a serve manifest (torn or foreign header)",
                    path.display()
                ))
            })?;
            if first != MAGIC {
                return Err(SedarError::Config(format!(
                    "{}: not a serve manifest (expected SDMF1 magic)",
                    path.display()
                )));
            }
            // Lenient replay: stop at the first torn/corrupt frame — the
            // records before it are intact by CRC.
            while let Some((body, next)) = next_record(&existing, pos) {
                pos = next;
                match body.first() {
                    Some(&TAG_SUBMIT) => {
                        let sub = parse_submission(&body[1..])?;
                        replay.push((sub, false));
                    }
                    Some(&TAG_DONE) => {
                        let mut r = ByteReader::new(&body[1..], "serve manifest done mark");
                        let id = r.string()?;
                        if let Some(e) = replay.iter_mut().find(|(s, _)| s.id == id) {
                            e.1 = true;
                        }
                    }
                    _ => {
                        return Err(SedarError::Config(format!(
                            "{}: unknown manifest record tag",
                            path.display()
                        )))
                    }
                }
            }
        }
        let mut file = OpenOptions::new().create(true).append(true).open(path)?;
        if existing.is_empty() {
            write_record(&mut file, MAGIC)?;
            crate::fleet::sync_parent_dir(path)?;
        }
        Ok((Manifest { file }, replay))
    }

    /// Journal one accepted submission. Synced before returning — the
    /// gateway must not acknowledge a submission the journal could lose.
    pub fn record_submit(&mut self, sub: &Submission) -> Result<()> {
        let mut body = vec![TAG_SUBMIT];
        push_string(&mut body, &sub.id);
        push_string(&mut body, &sub.user);
        body.extend_from_slice(&sub.seed.to_le_bytes());
        body.extend_from_slice(&sub.shards.to_le_bytes());
        body.extend_from_slice(&sub.jobs.to_le_bytes());
        opt_string(&mut body, &sub.filter);
        opt_string(&mut body, &sub.scenario);
        write_record(&mut self.file, &body)
    }

    /// Journal that a sweep merged (its report file is durable).
    pub fn record_done(&mut self, id: &str) -> Result<()> {
        let mut body = vec![TAG_DONE];
        push_string(&mut body, id);
        write_record(&mut self.file, &body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "sedar-manifest-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sub(id: &str, filter: Option<&str>) -> Submission {
        Submission {
            id: id.into(),
            user: "alice".into(),
            seed: 7,
            shards: 2,
            jobs: 1,
            filter: filter.map(str::to_string),
            scenario: None,
        }
    }

    #[test]
    fn round_trips_submissions_and_done_marks() {
        let dir = tmp("roundtrip");
        let path = dir.join("serve.manifest");
        {
            let (mut m, replay) = Manifest::open(&path).unwrap();
            assert!(replay.is_empty());
            m.record_submit(&sub("sweep-0001", Some("scenario=1-4"))).unwrap();
            m.record_submit(&sub("sweep-0002", None)).unwrap();
            m.record_done("sweep-0001").unwrap();
        }
        let (_m, replay) = Manifest::open(&path).unwrap();
        assert_eq!(replay.len(), 2);
        assert_eq!(replay[0].0, sub("sweep-0001", Some("scenario=1-4")));
        assert!(replay[0].1, "sweep-0001 is done");
        assert_eq!(replay[1].0, sub("sweep-0002", None));
        assert!(!replay[1].1, "sweep-0002 is in flight");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_drops_only_the_last_record() {
        let dir = tmp("torn");
        let path = dir.join("serve.manifest");
        {
            let (mut m, _) = Manifest::open(&path).unwrap();
            m.record_submit(&sub("sweep-0001", None)).unwrap();
            m.record_submit(&sub("sweep-0002", None)).unwrap();
        }
        // Tear the file mid-record (a daemon SIGKILLed mid-append).
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let (_m, replay) = Manifest::open(&path).unwrap();
        assert_eq!(replay.len(), 1, "torn tail dropped, prefix kept");
        assert_eq!(replay[0].0.id, "sweep-0001");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_files_are_refused_by_name() {
        let dir = tmp("foreign");
        let path = dir.join("serve.manifest");
        std::fs::write(&path, b"SDWL1 something else entirely........").unwrap();
        let err = Manifest::open(&path).unwrap_err().to_string();
        assert!(err.contains("not a serve manifest"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
