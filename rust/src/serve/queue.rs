//! Gateway admission control: per-client token buckets and queue caps.
//!
//! The daemon is the one component of the crate that faces *traffic*
//! rather than a single operator, so it rations two things per client
//! name: submission **rate** (a classic token bucket — `rate` tokens per
//! second refill, `burst` capacity, one token per submission) and
//! **queue depth** (the gateway separately caps how many queued/running
//! sweeps one user may hold; that check lives in the gateway because it
//! needs the sweep table). Rejections are cheap 429s before any spec
//! building, journaling or process spawning happens.
//!
//! The refill arithmetic is driven by an explicit [`Admission::advance`]
//! so tests pace time deterministically; the production entry point
//! [`Admission::admit`] feeds it real elapsed wall time (the daemon is
//! operational machinery, exempt from the virtual-clock rule that governs
//! world execution).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One client's bucket: `tokens` available now, refilled at `rate`/s up
/// to `burst`.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    tokens: f64,
    rate: f64,
    burst: f64,
}

impl TokenBucket {
    /// A fresh bucket starts full: a new client gets its burst allowance
    /// immediately.
    pub fn new(rate: f64, burst: f64) -> TokenBucket {
        TokenBucket {
            tokens: burst,
            rate,
            burst,
        }
    }

    /// Refill for `dt` of elapsed time, capped at the burst size.
    pub fn advance(&mut self, dt: Duration) {
        self.tokens = (self.tokens + self.rate * dt.as_secs_f64()).min(self.burst);
    }

    /// Spend one token if available.
    pub fn try_take(&mut self) -> bool {
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Per-client admission: one [`TokenBucket`] per client name, refilled
/// lazily from one shared elapsed-time watermark.
pub struct Admission {
    rate: f64,
    burst: f64,
    buckets: BTreeMap<String, TokenBucket>,
    last: Instant,
}

impl Admission {
    pub fn new(rate: f64, burst: f64) -> Admission {
        Admission {
            rate,
            burst,
            buckets: BTreeMap::new(),
            last: Instant::now(),
        }
    }

    /// Refill every bucket for `dt` of elapsed time.
    pub fn advance(&mut self, dt: Duration) {
        for b in self.buckets.values_mut() {
            b.advance(dt);
        }
    }

    /// Spend one of `user`'s tokens if available (no refill — pair with
    /// [`Admission::advance`]; tests drive the pair deterministically).
    pub fn try_take(&mut self, user: &str) -> bool {
        self.buckets
            .entry(user.to_string())
            .or_insert_with(|| TokenBucket::new(self.rate, self.burst))
            .try_take()
    }

    /// The production path: refill by real elapsed time, then take.
    pub fn admit(&mut self, user: &str) -> bool {
        let now = Instant::now();
        let dt = now.saturating_duration_since(self.last);
        self.last = now;
        self.advance(dt);
        self.try_take(user)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_spends_burst_then_refills_at_rate() {
        let mut b = TokenBucket::new(2.0, 3.0);
        // The burst is available immediately…
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(b.try_take());
        // …then the bucket is dry…
        assert!(!b.try_take());
        // …and refills at 2 tokens/s: 250 ms buys half a token, not one.
        b.advance(Duration::from_millis(250));
        assert!(!b.try_take());
        b.advance(Duration::from_millis(250));
        assert!(b.try_take());
        // Refill never exceeds the burst cap.
        b.advance(Duration::from_secs(3600));
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(!b.try_take());
    }

    #[test]
    fn admission_isolates_clients() {
        let mut a = Admission::new(0.0, 2.0);
        // alice exhausting her burst must not cost bob anything.
        assert!(a.try_take("alice"));
        assert!(a.try_take("alice"));
        assert!(!a.try_take("alice"));
        assert!(a.try_take("bob"));
        assert!(a.try_take("bob"));
        assert!(!a.try_take("bob"));
        // rate 0.0: no amount of elapsed time refills anyone.
        a.advance(Duration::from_secs(3600));
        assert!(!a.try_take("alice"));
        assert!(!a.try_take("bob"));
    }
}
