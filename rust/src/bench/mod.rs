//! `sedar bench` — the in-binary performance suite behind the
//! machine-readable bench trajectory (`BENCH_*.json`).
//!
//! The sections cover the hot paths the perf PRs optimize, so successive
//! PRs diff numbers instead of re-guessing them:
//!
//! 1. **msg_validation** — per-message detection cost by payload size:
//!    borrowed full-contents token construction (allocation-free),
//!    replica-buffer comparison, and SHA-256 digest tokens;
//! 2. **p2p / bcast** — vmpi transport latency/throughput by payload size
//!    (payloads are shared buffers: a send moves a reference);
//! 3. **ckpt_frame** — single-pass checkpoint frame write/read MB/s by
//!    codec (`Raw`, `Deflate(1)`, `Deflate(6)`);
//! 4. **faultnet** — per-message fault-plan evaluation cost (the tax every
//!    delivery pays when a [`crate::faultnet`] plan is installed) and the
//!    end-to-end overhead of a perturbed vs clean p2p stream;
//! 5. **persistence** — shard durability MB/s: the unified WAL (synced
//!    per-outcome appends + periodic snapshot compaction + replay) against
//!    an emulation of the retired dual write (per-record journal appends
//!    plus a whole-shard artifact frame);
//! 6. **gateway** — `sedar serve` front-door cost per HTTP round-trip
//!    (submit parse + journal-before-ack fsync, sweep listing, metrics
//!    scrape) against an ingress-only in-process daemon; with `--campaign`
//!    and `SEDAR_BIN` set, also the end-to-end wall time of four sweeps
//!    run sequentially as standalone campaigns vs multiplexed onto one
//!    pooled daemon;
//! 7. **campaign** — end-to-end wall time of the 1152-task injection sweep
//!    (64 scenarios × 3 apps × 3 strategies × 2 collectives modes — the
//!    system-level number everything above feeds, and the sweep the
//!    pooled-world arena keeps allocation-flat).
//!
//! `--json` renders the `sedar-bench/1` document
//! ([`crate::report::benchkit::JsonReport`]); `--quick` (or
//! `SEDAR_BENCH_QUICK=1`) shrinks sizes and iteration counts to
//! CI-friendly scale. Human-readable tables go to stdout unless JSON is
//! requested on stdout; progress lines go to stderr.

use std::time::Instant;

use crate::campaign::{run_campaign, CampaignSpec};
use crate::checkpoint::snapshot::{read_frame, write_frame, Codec};
use crate::detect::{buffers_equal, Token, ValidationMode};
use crate::error::Result;
use crate::report::benchkit::{bench, black_box, print_table, JsonReport, Stats};
use crate::state::{Var, VarStore};
use crate::util::prng::SplitMix64;
use crate::vmpi::Network;

/// What to run and how big.
pub struct BenchOpts {
    /// CI-friendly scale (also set by `SEDAR_BENCH_QUICK=1`).
    pub quick: bool,
    /// Include the end-to-end campaign section (the slow one).
    pub campaign: bool,
    /// Worker threads for the campaign section.
    pub jobs: usize,
    /// Campaign master seed.
    pub seed: u64,
    /// Print human-readable tables to stdout as sections finish.
    pub echo: bool,
}

fn rand_bytes(seed: u64, n: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

fn size_label(n: usize) -> String {
    crate::util::human_bytes(n as u64)
}

fn print_section(echo: bool, title: &str, rows: &[(Stats, Option<usize>)]) {
    if echo {
        print_table(title, rows);
    }
}

/// Run the suite; returns the populated JSON report (rendered or not by the
/// caller).
pub fn run_suite(opts: &BenchOpts) -> Result<JsonReport> {
    let mut jr = JsonReport::new();
    jr.meta("quick", if opts.quick { "true" } else { "false" });
    jr.meta(
        "cores",
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .to_string(),
    );
    jr.meta("os", format!("\"{}\"", crate::report::json_escape(std::env::consts::OS)));

    msg_validation_section(opts, &mut jr);
    transport_section(opts, &mut jr);
    ckpt_frame_section(opts, &mut jr);
    faultnet_section(opts, &mut jr);
    persistence_section(opts, &mut jr);
    gateway_section(opts, &mut jr);
    if opts.campaign {
        campaign_section(opts, &mut jr)?;
    }
    Ok(jr)
}

/// Per-message detection cost: what every validated send pays, by size and
/// validation mode (ns/MiB is the headline column of the trajectory).
fn msg_validation_section(opts: &BenchOpts, jr: &mut JsonReport) {
    eprintln!("bench: msg_validation");
    let iters = if opts.quick { 20 } else { 200 };
    let sizes: &[usize] = if opts.quick {
        &[1 << 16, 1 << 20]
    } else {
        &[1 << 16, 1 << 20, 1 << 22]
    };
    let mut rows = Vec::new();
    for &size in sizes {
        let msg = rand_bytes(1, size);
        let peer = msg.clone();
        let label = size_label(size);
        // Borrowed full token: the send-path cost of "building" the
        // comparison token in Full mode — must be O(1), no allocation.
        rows.push((
            bench(&format!("token full {label}"), 3, iters, || {
                black_box(Token::new(ValidationMode::Full, &msg).len());
            }),
            Some(size),
        ));
        // The lead's in-place comparison against the sibling's shared view.
        rows.push((
            bench(&format!("compare equal {label}"), 3, iters, || {
                black_box(buffers_equal(&msg, &peer));
            }),
            Some(size),
        ));
        // Digest-mode token (32-byte wire form, compute-bound).
        rows.push((
            bench(&format!("token sha256 {label}"), 3, iters.min(100), || {
                black_box(Token::new(ValidationMode::Sha256, &msg).len());
            }),
            Some(size),
        ));
    }
    for (s, b) in &rows {
        jr.push_stats("msg_validation", s, *b);
    }
    print_section(opts.echo, "message validation (per-send detection cost)", &rows);
}

/// vmpi transport: point-to-point and broadcast by payload size. Payload
/// buffers are shared, so these numbers are queue/rendezvous overhead —
/// the bytes column reports *delivered* payload bytes.
fn transport_section(opts: &BenchOpts, jr: &mut JsonReport) {
    eprintln!("bench: transport");
    let mut rows = Vec::new();
    let msgs = if opts.quick { 1_000 } else { 10_000 };
    for &size in &[1usize << 10, 1 << 16, 1 << 20] {
        let elems = size / 4;
        let payload = Var::f32(&[elems], vec![0.5f32; elems]);
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let recv = std::thread::spawn(move || {
            for _ in 0..msgs {
                b.recv(0, 1).unwrap();
            }
        });
        let s = bench(&format!("p2p {}", size_label(size)), 0, 1, || {
            for _ in 0..msgs {
                a.send(1, 1, payload.clone()).unwrap();
            }
        });
        recv.join().unwrap();
        rows.push((s, Some(size * msgs)));
    }

    let rounds = if opts.quick { 200 } else { 2_000 };
    for &size in &[1usize << 16, 1 << 20] {
        let elems = size / 4;
        let s = bench(&format!("bcast x4 {}", size_label(size)), 0, 1, || {
            let net = Network::new(4);
            let mut handles = Vec::new();
            for r in 0..4 {
                let ep = net.endpoint(r);
                handles.push(std::thread::spawn(move || {
                    let root_payload =
                        (r == 0).then(|| Var::f32(&[elems], vec![0.25f32; elems]));
                    for _ in 0..rounds {
                        ep.bcast(0, root_payload.clone()).unwrap();
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        // Delivered bytes: 3 receivers × rounds × size.
        rows.push((s, Some(3 * rounds * size)));
    }
    for (s, b) in &rows {
        jr.push_stats("transport", s, *b);
    }
    print_section(opts.echo, "vmpi transport (p2p / bcast)", &rows);
}

/// Checkpoint frame substrate: single-pass write and verify-read by codec.
fn ckpt_frame_section(opts: &BenchOpts, jr: &mut JsonReport) {
    eprintln!("bench: ckpt_frame");
    let iters = if opts.quick { 10 } else { 30 };
    let dir = std::env::temp_dir().join(format!("sedar-bench-frame-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // A realistic checkpoint body: a rank's matrix state — f32 noise, the
    // worst case for the compressing codecs.
    let n = if opts.quick { 1 << 18 } else { 1 << 20 };
    let mut store = VarStore::new();
    let mut rng = SplitMix64::new(9);
    let mut m = vec![0f32; n];
    rng.fill_f32(&mut m);
    store.insert("A", Var::f32(&[n], m));
    let payload = store.serialize();
    let label = size_label(payload.len());

    let mut rows = Vec::new();
    for codec in [Codec::Raw, Codec::Deflate(1), Codec::Deflate(6)] {
        let p = dir.join("frame.bin");
        let clabel = format!("{codec:?}");
        rows.push((
            bench(&format!("write {clabel} {label}"), 1, iters, || {
                write_frame(&p, &payload, codec).unwrap();
            }),
            Some(payload.len()),
        ));
        rows.push((
            bench(&format!("read  {clabel} {label}"), 1, iters, || {
                black_box(read_frame(&p).unwrap());
            }),
            Some(payload.len()),
        ));
    }
    let _ = std::fs::remove_dir_all(&dir);
    for (s, b) in &rows {
        jr.push_stats("ckpt_frame", s, *b);
    }
    print_section(opts.echo, "checkpoint frame substrate (t_cs drivers)", &rows);
}

/// Network fault layer: what a plan costs per message to evaluate, and
/// what a perturbed transport costs end-to-end. The e2e pair uses the
/// `Reorder` plan — delay-only, so the faulted stream still delivers every
/// byte and the clean/faulted delta is pure perturbation overhead (drop
/// and corrupt plans change *what* arrives, not just when, and belong to
/// the campaign oracle rather than a throughput number).
fn faultnet_section(opts: &BenchOpts, jr: &mut JsonReport) {
    use crate::faultnet::{FaultLayer, FaultPlan, NetFaultMode};
    use crate::util::clock::Clock;
    use std::sync::Arc;
    eprintln!("bench: faultnet");
    let mut rows = Vec::new();
    let evals: u64 = if opts.quick { 100_000 } else { 1_000_000 };
    for mode in [NetFaultMode::Drop, NetFaultMode::Mixed] {
        let plan = FaultPlan::new(mode, 42);
        rows.push((
            bench(&format!("plan eval {} x{evals}", mode.label()), 1, 5, || {
                for seq in 0..evals {
                    black_box(plan.action(0, 1, seq));
                }
            }),
            None,
        ));
    }

    let msgs = if opts.quick { 500 } else { 2_000 };
    let size = 1usize << 16;
    let elems = size / 4;
    let variants: [(&str, Option<Arc<FaultLayer>>); 2] = [
        ("clean", None),
        (
            "reorder",
            Some(Arc::new(FaultLayer::new(
                FaultPlan::new(NetFaultMode::Reorder, 7),
                1,
                None,
            ))),
        ),
    ];
    for (label, layer) in variants {
        let payload = Var::f32(&[elems], vec![0.5f32; elems]);
        let net = Network::with_faults(2, Clock::wall(), layer);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let recv = std::thread::spawn(move || {
            for _ in 0..msgs {
                b.recv(0, 64).unwrap();
            }
        });
        let s = bench(
            &format!("p2p {label} {}", size_label(size)),
            0,
            1,
            || {
                for _ in 0..msgs {
                    a.send(1, 64, payload.clone()).unwrap();
                }
            },
        );
        recv.join().unwrap();
        rows.push((s, Some(size * msgs)));
    }
    for (s, b) in &rows {
        jr.push_stats("faultnet", s, *b);
    }
    print_section(opts.echo, "network fault layer (plan eval / perturbed p2p)", &rows);
}

/// Shard durability substrate: what one finished task costs to make
/// durable. Three cases over the same outcome batch:
///
/// - `wal append+compact` — the live path: per-outcome synced appends to
///   one SDWL log, snapshot compaction at the default interval, a final
///   compaction on clean shutdown;
/// - `dual write` — an emulation of the retired journal+artifact pair
///   (per-record synced appends to one file, then the whole shard payload
///   re-framed and synced to a second), kept as the comparison baseline;
/// - `wal replay` — the read side every resume and merge shares.
///
/// The bytes column is the encoded outcome payload per iteration (×2 for
/// the dual write — both files carry it), so MB/s compares like for like.
/// These are fsync-bound numbers: expect milliseconds per record on real
/// disks and noise on CI runners — trend, not threshold.
fn persistence_section(opts: &BenchOpts, jr: &mut JsonReport) {
    use crate::campaign::shard::TaskOutcome;
    use crate::campaign::CampaignApp;
    use crate::config::{CollectiveImpl, Strategy};
    use crate::detect::ValidationMode;
    use crate::faultnet::NetFaultMode;
    use crate::fleet::snapshot::read_wal;
    use crate::fleet::wal::{encode_outcome, ShardMeta, Wal};
    use crate::util::frame;

    eprintln!("bench: persistence");
    let n: usize = if opts.quick { 64 } else { 256 };
    let iters = if opts.quick { 3 } else { 5 };
    let dir = std::env::temp_dir().join(format!("sedar-bench-wal-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let outcomes: Vec<TaskOutcome> = (0..n)
        .map(|index| TaskOutcome {
            index,
            scenario_id: (index % 64) as u32 + 1,
            app: CampaignApp::Matmul,
            strategy: Strategy::SysCkpt,
            collectives: CollectiveImpl::PointToPoint,
            validation: ValidationMode::Full,
            netfault: NetFaultMode::None,
            faults: 1,
            completed: true,
            restarts: 0,
            injected: true,
            correct: Some(true),
            first_detection: None,
            last_resume: None,
            pass: true,
            mismatches: vec![],
            wall: std::time::Duration::ZERO,
            metrics: Default::default(),
        })
        .collect();
    let meta = ShardMeta {
        seed: 7,
        shard_index: 0,
        shard_count: 1,
        total_tasks: n as u64,
        spec_hash: 0xBE9C_0009,
    };
    let mut payload = Vec::new();
    for o in &outcomes {
        encode_outcome(o, &mut payload);
    }
    let bytes = payload.len();

    let mut rows = Vec::new();
    let wal_path = dir.join("bench.wal");
    rows.push((
        bench(&format!("wal append+compact x{n}"), 1, iters, || {
            let _ = std::fs::remove_file(&wal_path);
            let (mut w, _) = Wal::open(&wal_path, &meta).unwrap();
            for o in &outcomes {
                w.append(o).unwrap();
            }
            w.finalize().unwrap();
        }),
        Some(bytes),
    ));

    let journal_path = dir.join("bench.journal");
    let artifact_path = dir.join("bench.artifact");
    rows.push((
        bench(&format!("dual write (retired) x{n}"), 1, iters, || {
            let mut j = std::fs::OpenOptions::new()
                .create(true)
                .write(true)
                .truncate(true)
                .open(&journal_path)
                .unwrap();
            let mut body = Vec::new();
            for o in &outcomes {
                body.clear();
                encode_outcome(o, &mut body);
                frame::write_record(&mut j, &body).unwrap();
            }
            let mut framed = Vec::with_capacity(payload.len() + 8);
            frame::frame(&payload, &mut framed);
            let mut a = std::fs::File::create(&artifact_path).unwrap();
            std::io::Write::write_all(&mut a, &framed).unwrap();
            a.sync_data().unwrap();
        }),
        Some(bytes * 2),
    ));

    // Leave a compacted WAL behind for the replay case (the last append
    // iteration finalized it).
    rows.push((
        bench(&format!("wal replay x{n}"), 1, iters.max(10), || {
            black_box(read_wal(&wal_path).unwrap().1.len());
        }),
        Some(bytes),
    ));

    let _ = std::fs::remove_dir_all(&dir);
    for (s, b) in &rows {
        jr.push_stats("persistence", s, *b);
    }
    print_section(opts.echo, "shard persistence (WAL vs retired dual write)", &rows);
}

/// Gateway ingress: what one HTTP round-trip through the `sedar serve`
/// front door costs. The daemon is in-process with **zero** pooled worker
/// slots, so an accepted submission is parsed, planned, journaled (one
/// fsync — the ack is durable) and queued but never started: the number is
/// the front door itself, not the campaign behind it. Expect the submit
/// rows to be fsync-bound — trend, not threshold, on CI runners.
///
/// With `--campaign` (not `--quick`) and `SEDAR_BIN` pointing at a built
/// `sedar` binary, a heavy pair follows: four 32-task sweeps run
/// sequentially as standalone `sedar campaign` processes vs the same four
/// submitted concurrently to one pooled daemon with four shard slots —
/// the wall-clock delta is what multiplexing buys (and costs).
fn gateway_section(opts: &BenchOpts, jr: &mut JsonReport) {
    use crate::fleet::status::http_get;
    use crate::serve::http::http_post;
    use crate::serve::{Daemon, ServeOptions};
    use std::time::Duration;

    eprintln!("bench: gateway");
    let iters = if opts.quick { 20 } else { 100 };
    let timeout = Duration::from_secs(5);
    let dir = std::env::temp_dir().join(format!("sedar-bench-gateway-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let daemon = Daemon::spawn(ServeOptions {
        workers: 0,
        dir: dir.clone(),
        poll_interval: Duration::from_millis(1),
        rate: 1e9,
        burst: 1e9,
        queue_cap: usize::MAX,
        quiet: true,
        ..ServeOptions::default()
    })
    .unwrap();
    let addr = daemon.addr();

    let submit_small = "user=bench\nseed=7\nshards=1\nfilter=app=matmul,strategy=sys,scenario=1\n";
    let submit_large = "user=bench\nseed=7\nshards=4\nfilter=app=matmul,strategy=sys,scenario=1-64\n";
    let mut rows = Vec::new();
    rows.push((
        bench("submit 2-task sweep", 1, iters, || {
            black_box(http_post(addr, "/submit", submit_small, timeout).unwrap().len());
        }),
        None,
    ));
    rows.push((
        bench("submit 128-task sweep, 4 shards", 1, iters, || {
            black_box(http_post(addr, "/submit", submit_large, timeout).unwrap().len());
        }),
        None,
    ));
    // The listing walks every submission accepted above — a loaded table,
    // not an empty one.
    rows.push((
        bench("GET /sweeps (loaded)", 1, iters, || {
            black_box(http_get(addr, "/sweeps", timeout).unwrap().len());
        }),
        None,
    ));
    rows.push((
        bench("GET /metrics", 1, iters, || {
            black_box(http_get(addr, "/metrics", timeout).unwrap().len());
        }),
        None,
    ));
    drop(daemon);
    let _ = std::fs::remove_dir_all(&dir);
    for (s, b) in &rows {
        jr.push_stats("gateway", s, *b);
    }
    print_section(opts.echo, "serve gateway (front-door HTTP round-trips)", &rows);

    if opts.campaign && !opts.quick {
        match std::env::var("SEDAR_BIN") {
            Ok(bin) => gateway_e2e(opts, jr, bin.into()),
            Err(_) => eprintln!(
                "bench: gateway e2e skipped — set SEDAR_BIN to a built `sedar` binary"
            ),
        }
    }
}

/// The heavy half of the gateway section: four equal sweep slices run
/// sequentially as standalone campaigns, then multiplexed onto one pooled
/// daemon. Both sides get the same per-sweep worker budget (the default
/// split four ways), so the pooled win is scheduling, not extra threads.
fn gateway_e2e(opts: &BenchOpts, jr: &mut JsonReport, bin: std::path::PathBuf) {
    use crate::fleet::status::http_get;
    use crate::serve::http::http_post;
    use crate::serve::{Daemon, ServeOptions};
    use std::time::Duration;

    eprintln!("bench: gateway e2e (4 sweeps, sequential vs pooled)");
    let slices = ["1-16", "17-32", "33-48", "49-64"];
    let jobs = (CampaignSpec::default_jobs() / slices.len()).max(1);
    let timeout = Duration::from_secs(5);
    let dir = std::env::temp_dir().join(format!("sedar-bench-gw-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let t0 = Instant::now();
    for s in &slices {
        let status = std::process::Command::new(&bin)
            .args(["campaign", "--seed", "7", "--quiet", "--jobs"])
            .arg(jobs.to_string())
            .arg("--filter")
            .arg(format!("app=matmul,strategy=sys,scenario={s}"))
            .arg("--report-out")
            .arg(dir.join(format!("seq-{s}.md")))
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .status()
            .unwrap();
        assert!(status.success(), "standalone campaign slice {s} failed");
    }
    let sequential = t0.elapsed();

    let daemon = Daemon::spawn(ServeOptions {
        workers: slices.len(),
        dir: dir.join("serve"),
        poll_interval: Duration::from_millis(10),
        rate: 1e9,
        burst: 1e9,
        queue_cap: slices.len(),
        bin: Some(bin),
        quiet: true,
        ..ServeOptions::default()
    })
    .unwrap();
    let t0 = Instant::now();
    for s in &slices {
        http_post(
            daemon.addr(),
            "/submit",
            &format!(
                "user=bench\nseed=7\nshards=1\njobs={jobs}\n\
                 filter=app=matmul,strategy=sys,scenario={s}\n"
            ),
            timeout,
        )
        .unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(600);
    for i in 1..=slices.len() {
        let path = format!("/sweep/sweep-{i:04}/report");
        while http_get(daemon.addr(), &path, timeout).is_err() {
            assert!(Instant::now() < deadline, "pooled sweep {i} never merged");
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    let pooled = t0.elapsed();
    drop(daemon);
    let _ = std::fs::remove_dir_all(&dir);

    for (case, wall) in [
        ("4x32-task sequential standalone", sequential),
        ("4x32-task pooled daemon, 4 slots", pooled),
    ] {
        jr.push_raw(format!(
            "{{\"group\":\"gateway\",\"case\":\"{case}\",\"sweeps\":4,\
             \"jobs_per_sweep\":{jobs},\"wall_ms\":{}}}",
            wall.as_millis()
        ));
    }
    if opts.echo {
        println!(
            "\n=== gateway e2e (4 sweeps) ===\n\n  sequential {} | pooled {}",
            crate::util::human_duration(sequential),
            crate::util::human_duration(pooled)
        );
    }
}

/// End-to-end: the full injection campaign, one wall-clock number per
/// clock mode. The wall-clock run is the paper-faithful baseline; the
/// virtual-clock run is the same sweep (byte-identical report) with every
/// modeled timeout collapsed to a quiescence jump — the delta between the
/// two entries is exactly what virtual time buys.
fn campaign_section(opts: &BenchOpts, jr: &mut JsonReport) -> Result<()> {
    use crate::util::clock::ClockMode;
    for mode in [ClockMode::Wall, ClockMode::Virtual] {
        eprintln!("bench: campaign (e2e, {} clock)", mode.label());
        let mut spec = CampaignSpec::new(opts.seed);
        spec.jobs = opts.jobs.max(1);
        spec.echo = false;
        spec.base.clock = mode;
        if opts.quick {
            // A representative slice: every strategy and both collectives
            // modes, one app, 8 scenarios (48 worlds).
            spec.apply_filter("app=matmul,scenario=1-8")?;
        }
        spec.base.run_dir = std::env::temp_dir().join(format!(
            "sedar-bench-campaign-{}-{}",
            mode.label(),
            std::process::id()
        ));
        // The bench harness itself always measures real elapsed time —
        // `Instant` here is the measurement, not a decision path.
        let t0 = Instant::now();
        let report = run_campaign(&spec);
        let wall = t0.elapsed();
        let _ = std::fs::remove_dir_all(&spec.base.run_dir);
        let report = report?;
        let tasks = report.total();
        jr.push_raw(format!(
            "{{\"group\":\"campaign\",\"case\":\"e2e {tasks} tasks ({} clock)\",\
             \"tasks\":{tasks},\"jobs\":{},\"clock\":\"{}\",\"wall_ms\":{},\
             \"pass\":{}}}",
            mode.label(),
            spec.jobs,
            mode.label(),
            wall.as_millis(),
            report.verdict()
        ));
        if opts.echo {
            println!(
                "\n=== campaign e2e ({} clock) ===\n\n  {tasks} tasks, {} jobs → {} ({})",
                mode.label(),
                spec.jobs,
                crate::util::human_duration(wall),
                report.summary_line()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The quick suite (campaign excluded — the e2e path is exercised by
    /// the campaign integration tests) must produce a structurally sound
    /// document covering every section.
    #[test]
    fn quick_suite_renders_all_sections() {
        let opts = BenchOpts {
            quick: true,
            campaign: false,
            jobs: 1,
            seed: 7,
            echo: false,
        };
        let jr = run_suite(&opts).unwrap();
        let doc = jr.render();
        assert!(doc.contains("\"schema\": \"sedar-bench/1\""));
        for group in [
            "msg_validation",
            "transport",
            "ckpt_frame",
            "faultnet",
            "persistence",
            "gateway",
        ] {
            assert!(doc.contains(&format!("\"group\":\"{group}\"")), "missing {group}");
        }
        assert!(doc.contains("\"ns_per_mib\":"));
        let opens = doc.matches(['{', '[']).count();
        assert_eq!(opens, doc.matches(['}', ']']).count());
    }
}
