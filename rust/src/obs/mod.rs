//! Typed observability events: the Figure-3 experiment log as data.
//!
//! [`crate::coordinator::trace::Trace`] renders the run's story as text;
//! this module carries the same story as typed [`Event`]s — kind, rank,
//! replica, attempt and the modeled tick at which it happened — so runs
//! can be serialized, diffed byte-for-byte in CI, and exported to the
//! Chrome trace-event JSON that Perfetto loads (`sedar trace export`).
//!
//! The on-disk log uses the shared framing codec
//! ([`crate::util::frame`]: `len u32 | crc32 u32 | body` per record, a
//! versioned magic header first) in its strict discipline — a trace log is
//! written whole, so a record that does not frame is storage corruption
//! and surfaces as a recoverable error, exactly like a corrupt fleet WAL:
//!
//! ```text
//! file   := header-record record*
//! header := "SDTR" | version u32
//! record := tag u8 (0 = event, 1 = span) | payload
//! ```
//!
//! Ticks are modeled nanoseconds from the run's [`crate::util::clock`]:
//! under `--clock virtual` two runs of the same seed serialize
//! byte-identical logs, which the `obs-smoke` CI job diffs.

use std::path::Path;

use crate::error::{Result, SedarError};
use crate::metrics::{Phase, Span};
use crate::util::clock::Tick;
use crate::util::frame::{frame, push_string, read_record, ByteReader};

const MAGIC: &[u8; 4] = b"SDTR";
const VERSION: u32 = 1;

/// Rank value that marks a coordinator-level event.
pub const COORD_RANK: u32 = u32::MAX;

/// What happened — the typed counterpart of a trace line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The run started (strategy and configuration in the detail).
    RunStart,
    /// One execution attempt began (resume point in the detail).
    AttemptStart,
    /// A fault was injected into a replica.
    Injected,
    /// A checkpoint was stored (system or user level; see detail).
    CkptStored,
    /// A user-level checkpoint failed its validation hash.
    CkptCorrupt,
    /// A replica divergence was detected (TDC/FSC class in the detail).
    Detected,
    /// A rendezvous timeout expired (the TOE detection path).
    ToeExpired,
    /// The coordinator decided a rollback / resume point.
    Rollback,
    /// The final result comparison succeeded.
    Validated,
    /// The run completed.
    Completed,
    /// The coordinator exhausted its restart budget.
    GaveUp,
    /// The faultnet layer perturbed a message (action in the detail).
    NetFault,
}

impl EventKind {
    pub const ALL: [EventKind; 12] = [
        EventKind::RunStart,
        EventKind::AttemptStart,
        EventKind::Injected,
        EventKind::CkptStored,
        EventKind::CkptCorrupt,
        EventKind::Detected,
        EventKind::ToeExpired,
        EventKind::Rollback,
        EventKind::Validated,
        EventKind::Completed,
        EventKind::GaveUp,
        EventKind::NetFault,
    ];

    pub fn label(self) -> &'static str {
        match self {
            EventKind::RunStart => "run-start",
            EventKind::AttemptStart => "attempt-start",
            EventKind::Injected => "injected",
            EventKind::CkptStored => "ckpt-stored",
            EventKind::CkptCorrupt => "ckpt-corrupt",
            EventKind::Detected => "detected",
            EventKind::ToeExpired => "toe-expired",
            EventKind::Rollback => "rollback",
            EventKind::Validated => "validated",
            EventKind::Completed => "completed",
            EventKind::GaveUp => "gave-up",
            EventKind::NetFault => "net-fault",
        }
    }

    /// Stable ordinal, persisted in trace logs — frozen once released.
    pub fn ordinal(self) -> u8 {
        match self {
            EventKind::RunStart => 0,
            EventKind::AttemptStart => 1,
            EventKind::Injected => 2,
            EventKind::CkptStored => 3,
            EventKind::CkptCorrupt => 4,
            EventKind::Detected => 5,
            EventKind::ToeExpired => 6,
            EventKind::Rollback => 7,
            EventKind::Validated => 8,
            EventKind::Completed => 9,
            EventKind::GaveUp => 10,
            EventKind::NetFault => 11,
        }
    }

    /// Inverse of [`EventKind::ordinal`] (trace-log decoding).
    pub fn from_ordinal(ord: u8) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.ordinal() == ord)
    }
}

/// One typed run event, stamped in modeled ticks since run start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub tick: Tick,
    /// Rank that emitted the event; [`COORD_RANK`] = the coordinator.
    pub rank: u32,
    pub replica: u32,
    /// 1-based execution attempt the event belongs to (0 = pre-attempt).
    pub attempt: u32,
    pub kind: EventKind,
    /// Human-readable detail — the text of the matching trace line.
    pub detail: String,
}

/// Sort events into their canonical order: by tick, then rank, replica
/// and kind. The sort is stable, so same-key events (possible only within
/// one thread) keep their per-thread emission order — cross-thread
/// interleaving of the shared log can never leak into the serialized
/// bytes.
pub fn canonicalize_events(events: &mut [Event]) {
    events.sort_by_key(|e| (e.tick, e.rank, e.replica, e.kind.ordinal()));
}

fn encode_event(e: &Event, out: &mut Vec<u8>) {
    out.push(0); // record tag: event
    out.extend_from_slice(&e.tick.to_le_bytes());
    out.extend_from_slice(&e.rank.to_le_bytes());
    out.extend_from_slice(&e.replica.to_le_bytes());
    out.extend_from_slice(&e.attempt.to_le_bytes());
    out.push(e.kind.ordinal());
    push_string(out, &e.detail);
}

fn encode_span(s: &Span, out: &mut Vec<u8>) {
    out.push(1); // record tag: span
    out.push(s.phase.ordinal());
    out.extend_from_slice(&s.rank.to_le_bytes());
    out.extend_from_slice(&s.replica.to_le_bytes());
    out.extend_from_slice(&s.begin.to_le_bytes());
    out.extend_from_slice(&s.end.to_le_bytes());
}

fn decode_record(body: &[u8]) -> Result<RecordBody> {
    let mut r = ByteReader::new(body, "trace log");
    let tag = r.u8()?;
    let rec = match tag {
        0 => {
            let tick = r.u64()?;
            let rank = r.u32()?;
            let replica = r.u32()?;
            let attempt = r.u32()?;
            let ord = r.u8()?;
            let kind = EventKind::from_ordinal(ord).ok_or_else(|| {
                SedarError::Checkpoint(format!("trace log: bad event kind ordinal {ord}"))
            })?;
            let detail = r.string()?;
            RecordBody::Event(Event { tick, rank, replica, attempt, kind, detail })
        }
        1 => {
            let ord = r.u8()?;
            let phase = Phase::from_ordinal(ord).ok_or_else(|| {
                SedarError::Checkpoint(format!("trace log: bad phase ordinal {ord}"))
            })?;
            let rank = r.u32()?;
            let replica = r.u32()?;
            let begin = r.u64()?;
            let end = r.u64()?;
            RecordBody::Span(Span { phase, rank, replica, begin, end })
        }
        other => {
            return Err(SedarError::Checkpoint(format!(
                "trace log: unknown record tag {other}"
            )))
        }
    };
    if r.remaining() != 0 {
        return Err(SedarError::Checkpoint(format!(
            "trace log: {} trailing byte(s) in record",
            r.remaining()
        )));
    }
    Ok(rec)
}

enum RecordBody {
    Event(Event),
    Span(Span),
}

/// Serialize a run's events and spans to their canonical byte form.
/// Inputs are canonicalized first, so the bytes are independent of the
/// emission interleaving — two same-seed virtual-clock runs agree on them
/// exactly.
pub fn encode_log(events: &[Event], spans: &[Span]) -> Vec<u8> {
    let mut events: Vec<Event> = events.to_vec();
    canonicalize_events(&mut events);
    let mut spans: Vec<Span> = spans.to_vec();
    crate::metrics::canonicalize_spans(&mut spans);

    let mut out = Vec::with_capacity(16 + events.len() * 64 + spans.len() * 32);
    let mut header = Vec::with_capacity(8);
    header.extend_from_slice(MAGIC);
    header.extend_from_slice(&VERSION.to_le_bytes());
    frame(&header, &mut out);
    let mut body = Vec::with_capacity(96);
    for e in &events {
        body.clear();
        encode_event(e, &mut body);
        frame(&body, &mut out);
    }
    for s in &spans {
        body.clear();
        encode_span(s, &mut body);
        frame(&body, &mut out);
    }
    out
}

/// Parse trace-log bytes back into events and spans.
pub fn decode_log(data: &[u8]) -> Result<(Vec<Event>, Vec<Span>)> {
    let (header, mut pos) = read_record(data, 0, "trace log header")?;
    let mut r = ByteReader::new(header, "trace log header");
    if r.bytes(4)? != MAGIC {
        return Err(SedarError::Checkpoint(
            "not a trace log (bad header magic)".into(),
        ));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(SedarError::Checkpoint(format!(
            "unsupported trace log version {version} (this build reads \
             version {VERSION})"
        )));
    }

    let mut events = Vec::new();
    let mut spans = Vec::new();
    while pos < data.len() {
        let (body, end) = read_record(data, pos, "trace log record")?;
        match decode_record(body)? {
            RecordBody::Event(e) => events.push(e),
            RecordBody::Span(s) => spans.push(s),
        }
        pos = end;
    }
    Ok((events, spans))
}

/// Write a run's trace log to `path` (canonical bytes; see [`encode_log`]).
pub fn write_log(path: &Path, events: &[Event], spans: &[Span]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, encode_log(events, spans))?;
    Ok(())
}

/// Read a trace log back from `path`.
pub fn read_log(path: &Path) -> Result<(Vec<Event>, Vec<Span>)> {
    let data = std::fs::read(path)?;
    decode_log(&data)
}

/// Microsecond timestamp string from a tick count: Chrome trace `ts`/`dur`
/// fields are microseconds; a tick is one modeled nanosecond, rendered
/// with fixed sub-µs precision so the JSON is byte-deterministic.
fn micros(ticks: Tick) -> String {
    format!("{}.{:03}", ticks / 1_000, ticks % 1_000)
}

fn chrome_pid(rank: u32) -> u32 {
    if rank == COORD_RANK {
        0
    } else {
        rank + 1
    }
}

/// Render events + spans as Chrome trace-event JSON (the format Perfetto
/// and `chrome://tracing` load). Each rank maps to a process (coordinator
/// = pid 0), each replica to a thread; spans become complete (`"X"`)
/// slices, events become thread-scoped instants (`"i"`).
pub fn chrome_json(events: &[Event], spans: &[Span]) -> String {
    let mut events: Vec<Event> = events.to_vec();
    canonicalize_events(&mut events);
    let mut spans: Vec<Span> = spans.to_vec();
    crate::metrics::canonicalize_spans(&mut spans);

    let mut entries: Vec<String> = Vec::with_capacity(events.len() + spans.len() + 4);

    // Process-name metadata, one per pid in ascending order.
    let mut pids: Vec<u32> = events
        .iter()
        .map(|e| chrome_pid(e.rank))
        .chain(spans.iter().map(|s| chrome_pid(s.rank)))
        .collect();
    pids.sort_unstable();
    pids.dedup();
    for pid in pids {
        let name = if pid == 0 {
            "coord".to_string()
        } else {
            format!("rank {}", pid - 1)
        };
        entries.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"{name}\"}}}}"
        ));
    }

    for s in &spans {
        entries.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"phase\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":{},\"tid\":{}}}",
            s.phase.label(),
            micros(s.begin),
            micros(s.end.saturating_sub(s.begin)),
            chrome_pid(s.rank),
            s.replica
        ));
    }
    for e in &events {
        entries.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"event\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{},\
             \"pid\":{},\"tid\":{},\"args\":{{\"attempt\":{},\"detail\":\"{}\"}}}}",
            e.kind.label(),
            micros(e.tick),
            chrome_pid(e.rank),
            e.replica,
            e.attempt,
            crate::report::json_escape(&e.detail)
        ));
    }

    format!(
        "{{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\n{}\n]}}\n",
        entries.join(",\n")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::codec::crc32;

    fn event(tick: Tick, rank: u32, kind: EventKind, detail: &str) -> Event {
        Event {
            tick,
            rank,
            replica: 0,
            attempt: 1,
            kind,
            detail: detail.into(),
        }
    }

    fn sample() -> (Vec<Event>, Vec<Span>) {
        let events = vec![
            event(0, COORD_RANK, EventKind::RunStart, "run start: matmul"),
            event(10, 1, EventKind::Injected, "INJECTED [FSC] bit-flip"),
            event(20, 1, EventKind::Detected, "FSC divergence at VALIDATE"),
            event(30, COORD_RANK, EventKind::Completed, "COMPLETED — résumé ✓"),
        ];
        let spans = vec![
            Span { phase: Phase::Exec, rank: 0, replica: 0, begin: 0, end: 9 },
            Span { phase: Phase::Compare, rank: 1, replica: 1, begin: 12, end: 19 },
        ];
        (events, spans)
    }

    #[test]
    fn log_roundtrips_byte_exactly() {
        let (events, spans) = sample();
        let bytes = encode_log(&events, &spans);
        let (back_e, back_s) = decode_log(&bytes).unwrap();
        assert_eq!(back_e, events);
        assert_eq!(back_s, spans);
        // Canonical: re-encoding the decoded log is byte-identical.
        assert_eq!(encode_log(&back_e, &back_s), bytes);
    }

    #[test]
    fn encoding_is_independent_of_emission_interleaving() {
        let (mut events, mut spans) = sample();
        let forward = encode_log(&events, &spans);
        events.reverse();
        spans.reverse();
        assert_eq!(encode_log(&events, &spans), forward);
    }

    #[test]
    fn corruption_and_version_drift_are_refused() {
        let (events, spans) = sample();
        let bytes = encode_log(&events, &spans);
        // Truncation at any point must error, never panic.
        for cut in [0, 3, 11, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_log(&bytes[..cut]).is_err(), "accepted {cut}-byte prefix");
        }
        // A flipped payload byte trips the record CRC.
        let mut bent = bytes.clone();
        let last = bent.len() - 2;
        bent[last] ^= 0x10;
        assert!(decode_log(&bent).is_err());
        // A bumped header version is refused naming both versions.
        let mut v9 = bytes.clone();
        v9[12] = 9; // header body: magic(4) + version u32 at offset 8+4
        let crc = crc32(&v9[8..16]);
        v9[4..8].copy_from_slice(&crc.to_le_bytes());
        let err = decode_log(&v9).unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
        assert!(err.contains("version 1"), "{err}");
    }

    #[test]
    fn file_roundtrip() {
        let p = std::env::temp_dir().join(format!(
            "sedar-trace-{}-{:?}.log",
            std::process::id(),
            std::thread::current().id()
        ));
        let (events, spans) = sample();
        write_log(&p, &events, &spans).unwrap();
        let (back_e, back_s) = read_log(&p).unwrap();
        assert_eq!((back_e, back_s), (events, spans));
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn chrome_json_counts_and_shape() {
        let (events, spans) = sample();
        let json = chrome_json(&events, &spans);
        assert_eq!(json.matches("\"ph\":\"i\"").count(), events.len());
        assert_eq!(json.matches("\"ph\":\"X\"").count(), spans.len());
        // pid 0 = coordinator, pid N+1 = rank N.
        assert!(json.contains("\"args\":{\"name\":\"coord\"}"));
        assert!(json.contains("\"args\":{\"name\":\"rank 1\"}"));
        // Ticks render as microseconds with ns precision.
        assert!(json.contains("\"ts\":0.010"), "{json}");
        // Details are JSON-escaped, non-ASCII passes through.
        assert!(json.contains("résumé"));
    }

    #[test]
    fn kind_ordinals_roundtrip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::from_ordinal(k.ordinal()), Some(k));
            assert!(!k.label().is_empty());
        }
        assert_eq!(EventKind::from_ordinal(99), None);
    }
}
