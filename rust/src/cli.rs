//! Minimal command-line argument parsing (no external CLI crate in the
//! offline dependency set).
//!
//! Grammar: `sedar <command> [positional…] [--flag value…] [--switch…]`.
//! Boolean switches are a declared, closed set ([`SWITCHES`]): a `--name`
//! in that set never consumes the next token, so
//! `sedar merge --allow-partial s1.bin s2.bin` keeps both positionals. Any
//! other `--flag` binds the next non-`--` token as its value (absent that,
//! it degrades to a switch). Use `--flag=value` to force value binding.
//!
//! The `campaign` subcommand drives [`crate::campaign`]: `sedar campaign
//! --jobs 8 --seed 42 [--filter app=matmul,strategy=sys,scenario=1-8]`
//! fans the 64-scenario workfault × apps × strategies over a worker pool;
//! the same `--seed` yields a byte-identical report for any `--jobs`.
//! Fleet mode ([`crate::fleet`]) rides the same grammar: `--shard i/N`
//! runs one deterministic slice, `--wal` makes it durable and resumable
//! (one write-ahead log per shard), `--status-port` serves live progress,
//! and the `merge` subcommand (`sedar merge s1.wal s2.wal`) recombines
//! shard WALs into the byte-identical full report. `sedar bench --json` emits the
//! machine-readable perf trajectory ([`crate::bench`]). The full flag
//! list is in the `HELP` text of `src/main.rs`.

use std::collections::HashMap;

use crate::error::{Result, SedarError};

/// Every boolean switch any `sedar` subcommand understands. Parsing
/// consults this set so a switch can never swallow the token after it
/// (which is how `merge --allow-partial s1.bin s2.bin` once lost
/// `s1.bin`). A flag that takes a value must NOT be listed here.
pub const SWITCHES: &[&str] = &[
    "aet",
    "allow-partial",
    "json",
    "no-campaign",
    "quick",
    "quiet",
    "thresholds",
    "trace",
    "xla",
];

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    values: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.values.insert(k.to_string(), v.to_string());
                } else if SWITCHES.contains(&name) {
                    args.switches.push(name.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.values.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.switches.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.values.contains_key(name)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| SedarError::Config(format!("--{name}: {e}"))),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| SedarError::Config(format!("--{name}: {e}"))),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| SedarError::Config(format!("--{name}: {e}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("run matmul extra");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["matmul", "extra"]);
    }

    #[test]
    fn flags_and_switches() {
        let a = parse("run --n 256 --trace --strategy=userckpt");
        assert_eq!(a.get("n"), Some("256"));
        assert!(a.has("trace"));
        assert_eq!(a.get("strategy"), Some("userckpt"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("run --xla --n 64");
        assert!(a.has("xla"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 64);
    }

    #[test]
    fn switches_never_consume_positionals() {
        // The bug class this guards: `merge --allow-partial s1.bin s2.bin`
        // used to bind s1.bin as the switch's value and drop it from the
        // positional list.
        let a = parse("merge --allow-partial s1.bin s2.bin");
        assert!(a.has("allow-partial"));
        assert_eq!(a.get("allow-partial"), None);
        assert_eq!(a.positional, vec!["s1.bin", "s2.bin"]);

        // Every declared switch holds the invariant.
        for switch in SWITCHES {
            let a = parse(&format!("cmd --{switch} keepme"));
            assert!(a.has(switch), "--{switch} not registered");
            assert_eq!(a.get(switch), None, "--{switch} bound a value");
            assert_eq!(a.positional, vec!["keepme"], "--{switch} ate a positional");
        }

        // Switches mixed among value flags stay inert.
        let a = parse("bench --json --out trajectory.json --quick --jobs 4");
        assert!(a.has("json") && a.has("quick"));
        assert_eq!(a.get("out"), Some("trajectory.json"));
        assert_eq!(a.usize_or("jobs", 0).unwrap(), 4);
        assert!(a.positional.is_empty());

        // `--switch=value` still force-binds (the explicit form wins).
        let a = parse("campaign --quiet=yes next");
        assert!(a.has("quiet"));
        assert_eq!(a.get("quiet"), Some("yes"));
        assert_eq!(a.positional, vec!["next"]);
    }

    #[test]
    fn unknown_flags_keep_value_binding_heuristic() {
        // Flags outside the switch set still bind the next token — the
        // pre-existing grammar for value flags is unchanged.
        let a = parse("campaign --filter app=matmul --shard 1/2 tail");
        assert_eq!(a.get("filter"), Some("app=matmul"));
        assert_eq!(a.get("shard"), Some("1/2"));
        assert_eq!(a.positional, vec!["tail"]);
        // …and degrade to switches at end-of-line or before another flag.
        let a = parse("run --mystery --n 64");
        assert!(a.has("mystery"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 64);
    }

    #[test]
    fn numeric_parsing_errors() {
        let a = parse("run --n abc");
        assert!(a.usize_or("n", 0).is_err());
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
    }
}
