//! Minimal command-line argument parsing (no external CLI crate in the
//! offline dependency set).
//!
//! Grammar: `sedar <command> [positional…] [--flag value…] [--switch…]`.
//! A token starting with `--` is a switch if the next token is absent or is
//! itself a flag; otherwise it consumes the next token as its value. Use
//! `--flag=value` to force value binding.
//!
//! The `campaign` subcommand drives [`crate::campaign`]: `sedar campaign
//! --jobs 8 --seed 42 [--filter app=matmul,strategy=sys,scenario=1-8]`
//! fans the 64-scenario workfault × apps × strategies over a worker pool;
//! the same `--seed` yields a byte-identical report for any `--jobs`.
//! Fleet mode ([`crate::fleet`]) rides the same grammar: `--shard i/N`
//! runs one deterministic slice, `--out`/`--journal` make it durable and
//! resumable, `--status-port` serves live progress, and the `merge`
//! subcommand (`sedar merge s1.bin s2.bin`) recombines shard artifacts
//! into the byte-identical full report. `sedar bench --json` emits the
//! machine-readable perf trajectory ([`crate::bench`]). The full flag
//! list is in the `HELP` text of `src/main.rs`.

use std::collections::HashMap;

use crate::error::{Result, SedarError};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    values: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.values.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.values.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.switches.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.values.contains_key(name)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| SedarError::Config(format!("--{name}: {e}"))),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| SedarError::Config(format!("--{name}: {e}"))),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| SedarError::Config(format!("--{name}: {e}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("run matmul extra");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["matmul", "extra"]);
    }

    #[test]
    fn flags_and_switches() {
        let a = parse("run --n 256 --trace --strategy=userckpt");
        assert_eq!(a.get("n"), Some("256"));
        assert!(a.has("trace"));
        assert_eq!(a.get("strategy"), Some("userckpt"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("run --xla --n 64");
        assert!(a.has("xla"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 64);
    }

    #[test]
    fn numeric_parsing_errors() {
        let a = parse("run --n abc");
        assert!(a.usize_or("n", 0).is_err());
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
    }
}
