//! The run event trace — the Figure-3-style experiment log.
//!
//! Figure 3 of the paper shows the console output of an injection
//! experiment: checkpoints being stored, the injection, the detection, the
//! rollback attempts and the final successful validation. [`Trace`] records
//! exactly that sequence with timestamps; `sedar run --trace` and the
//! injection-campaign example print it.
//!
//! Timestamps come from the run's [`Clock`], so under a virtual clock every
//! trace line is stamped in deterministic modeled time — two runs of the
//! same seed produce identical stamps.
//!
//! Alongside the human-readable lines, the key protocol moments are
//! recorded as typed [`crate::obs::Event`]s via [`Trace::event`]: same
//! message text, same single lock, plus the machine-readable kind /
//! rank / replica / attempt / tick fields that `--trace-out` serializes.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::obs::{Event, EventKind, COORD_RANK};
use crate::util::clock::{Clock, Tick};

/// One trace line.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub elapsed: Duration,
    /// Rank that emitted the event; `usize::MAX` = the coordinator itself.
    pub rank: usize,
    pub replica: usize,
    pub msg: String,
}

impl TraceEvent {
    pub fn format(&self) -> String {
        let who = if self.rank == usize::MAX {
            "coord  ".to_string()
        } else {
            format!("r{}.{}   ", self.rank, self.replica)
        };
        format!(
            "[{:>9.3} ms] {} {}",
            self.elapsed.as_secs_f64() * 1e3,
            who,
            self.msg
        )
    }
}

/// The two event logs one run accumulates, guarded by a single lock so a
/// reader can never observe one without the matching state of the other.
#[derive(Default)]
struct TraceBuf {
    lines: Vec<TraceEvent>,
    typed: Vec<Event>,
}

/// Append-only, thread-safe event log for one SEDAR run (across attempts).
pub struct Trace {
    clock: Clock,
    start: Tick,
    /// Current 1-based execution attempt (0 until the first attempt).
    attempt: AtomicU32,
    buf: Mutex<TraceBuf>,
    echo: bool,
}

impl Trace {
    /// Wall-clock trace (tests and standalone callers).
    pub fn new(echo: bool) -> Trace {
        Trace::with_clock(echo, Clock::wall())
    }

    /// Trace stamped from the run's clock.
    pub fn with_clock(echo: bool, clock: Clock) -> Trace {
        let start = clock.now();
        Trace {
            clock,
            start,
            attempt: AtomicU32::new(0),
            buf: Mutex::new(TraceBuf::default()),
            echo,
        }
    }

    /// Tell the trace which execution attempt is running; typed events
    /// emitted after this carry the value.
    pub fn set_attempt(&self, attempt: u32) {
        self.attempt.store(attempt, Ordering::Relaxed);
    }

    fn push(&self, rank: usize, replica: usize, msg: String, kind: Option<EventKind>) {
        let elapsed = self.clock.since(self.start);
        let ev = TraceEvent {
            elapsed,
            rank,
            replica,
            msg,
        };
        if self.echo {
            eprintln!("{}", ev.format());
        }
        // One lock for both logs: the typed event and its line land
        // atomically, so ordering assertions on one always agree with the
        // other.
        let mut buf = self.buf.lock().unwrap();
        if let Some(kind) = kind {
            buf.typed.push(Event {
                tick: elapsed.as_nanos() as Tick,
                rank: if rank == usize::MAX {
                    COORD_RANK
                } else {
                    rank as u32
                },
                replica: replica as u32,
                attempt: self.attempt.load(Ordering::Relaxed),
                kind,
                detail: ev.msg.clone(),
            });
        }
        buf.lines.push(ev);
    }

    /// Record a plain trace line.
    pub fn emit(&self, rank: usize, replica: usize, msg: impl Into<String>) {
        self.push(rank, replica, msg.into(), None);
    }

    /// Record a trace line AND its typed [`Event`] (same text, one lock).
    pub fn event(&self, rank: usize, replica: usize, kind: EventKind, msg: impl Into<String>) {
        self.push(rank, replica, msg.into(), Some(kind));
    }

    /// Coordinator-level event.
    pub fn coord(&self, msg: impl Into<String>) {
        self.emit(usize::MAX, 0, msg);
    }

    /// Coordinator-level typed event.
    pub fn coord_event(&self, kind: EventKind, msg: impl Into<String>) {
        self.event(usize::MAX, 0, kind, msg);
    }

    /// Run `f` over the recorded lines under the log's lock — the one
    /// accessor every reader shares, so no two readers can race an `emit`
    /// between their own lock acquisitions.
    pub fn with_events<R>(&self, f: impl FnOnce(&[TraceEvent]) -> R) -> R {
        f(&self.buf.lock().unwrap().lines)
    }

    /// Absorb externally recorded typed events into the typed log — the
    /// faultnet layer records its perturbations against the world clock
    /// and the coordinator drains them here after each attempt. The
    /// events carry their own tick/rank/attempt stamps; canonical
    /// ordering happens at read time like everywhere else.
    pub fn ingest_events(&self, events: Vec<Event>) {
        if events.is_empty() {
            return;
        }
        self.buf.lock().unwrap().typed.extend(events);
    }

    /// The typed events recorded so far, in canonical order
    /// ([`crate::obs::canonicalize_events`]).
    pub fn typed_events(&self) -> Vec<Event> {
        let mut typed = self.buf.lock().unwrap().typed.clone();
        crate::obs::canonicalize_events(&mut typed);
        typed
    }

    /// Full log as text (the Figure-3 artifact).
    pub fn dump(&self) -> String {
        self.with_events(|evs| {
            evs.iter().map(|e| e.format()).collect::<Vec<_>>().join("\n")
        })
    }

    /// True if some event message contains `needle` (test helper).
    pub fn contains(&self, needle: &str) -> bool {
        self.with_events(|evs| evs.iter().any(|e| e.msg.contains(needle)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order() {
        let t = Trace::new(false);
        t.coord("start");
        t.emit(2, 1, "INJECTED bit-flip");
        t.coord("end");
        t.with_events(|evs| {
            assert_eq!(evs.len(), 3);
            assert!(evs[0].msg.contains("start"));
            assert_eq!(evs[1].rank, 2);
        });
        assert!(t.contains("INJECTED"));
        assert!(!t.contains("nothing"));
    }

    #[test]
    fn dump_formats_lines() {
        let t = Trace::new(false);
        t.coord("hello");
        let s = t.dump();
        assert!(s.contains("coord"));
        assert!(s.contains("hello"));
        assert!(s.contains("ms]"));
    }

    #[test]
    fn typed_events_mirror_their_lines() {
        let t = Trace::new(false);
        t.set_attempt(2);
        t.coord_event(EventKind::RunStart, "run start");
        t.event(1, 0, EventKind::Injected, "INJECTED [FSC] bit-flip");
        t.emit(1, 0, "an untyped line");
        let typed = t.typed_events();
        // Only the typed sites produce events; the text is shared.
        assert_eq!(typed.len(), 2);
        assert_eq!(typed[0].kind, EventKind::RunStart);
        assert_eq!(typed[0].rank, COORD_RANK);
        assert_eq!(typed[1].kind, EventKind::Injected);
        assert_eq!((typed[1].rank, typed[1].attempt), (1, 2));
        assert_eq!(typed[1].detail, "INJECTED [FSC] bit-flip");
        assert!(t.contains("INJECTED [FSC] bit-flip"));
        t.with_events(|evs| assert_eq!(evs.len(), 3));
    }

    #[test]
    fn virtual_clock_stamps_are_deterministic() {
        let stamps = |_: usize| {
            let c = Clock::virtual_clock();
            c.join_n(1);
            let _g = c.guard();
            let t = Trace::with_clock(false, c.clone());
            t.coord("begin");
            c.sleep(Duration::from_millis(250));
            t.coord_event(EventKind::Completed, "after-sleep");
            (t.dump(), t.typed_events())
        };
        assert_eq!(stamps(0), stamps(1));
        let (dump, typed) = stamps(0);
        assert!(dump.contains("[  250.000 ms]"));
        assert_eq!(typed[0].tick, 250_000_000);
    }
}
