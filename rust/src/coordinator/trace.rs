//! The run event trace — the Figure-3-style experiment log.
//!
//! Figure 3 of the paper shows the console output of an injection
//! experiment: checkpoints being stored, the injection, the detection, the
//! rollback attempts and the final successful validation. [`Trace`] records
//! exactly that sequence with timestamps; `sedar run --trace` and the
//! injection-campaign example print it.
//!
//! Timestamps come from the run's [`Clock`], so under a virtual clock every
//! trace line is stamped in deterministic modeled time — two runs of the
//! same seed produce identical stamps.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::clock::{Clock, Tick};

/// One trace line.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub elapsed: Duration,
    /// Rank that emitted the event; `usize::MAX` = the coordinator itself.
    pub rank: usize,
    pub replica: usize,
    pub msg: String,
}

impl TraceEvent {
    pub fn format(&self) -> String {
        let who = if self.rank == usize::MAX {
            "coord  ".to_string()
        } else {
            format!("r{}.{}   ", self.rank, self.replica)
        };
        format!(
            "[{:>9.3} ms] {} {}",
            self.elapsed.as_secs_f64() * 1e3,
            who,
            self.msg
        )
    }
}

/// Append-only, thread-safe event log for one SEDAR run (across attempts).
pub struct Trace {
    clock: Clock,
    start: Tick,
    events: Mutex<Vec<TraceEvent>>,
    echo: bool,
}

impl Trace {
    /// Wall-clock trace (tests and standalone callers).
    pub fn new(echo: bool) -> Trace {
        Trace::with_clock(echo, Clock::wall())
    }

    /// Trace stamped from the run's clock.
    pub fn with_clock(echo: bool, clock: Clock) -> Trace {
        let start = clock.now();
        Trace {
            clock,
            start,
            events: Mutex::new(Vec::new()),
            echo,
        }
    }

    pub fn emit(&self, rank: usize, replica: usize, msg: impl Into<String>) {
        let ev = TraceEvent {
            elapsed: self.clock.since(self.start),
            rank,
            replica,
            msg: msg.into(),
        };
        if self.echo {
            eprintln!("{}", ev.format());
        }
        self.events.lock().unwrap().push(ev);
    }

    /// Coordinator-level event.
    pub fn coord(&self, msg: impl Into<String>) {
        self.emit(usize::MAX, 0, msg);
    }

    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Full log as text (the Figure-3 artifact).
    pub fn dump(&self) -> String {
        self.events()
            .iter()
            .map(|e| e.format())
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// True if some event message contains `needle` (test helper).
    pub fn contains(&self, needle: &str) -> bool {
        self.events
            .lock()
            .unwrap()
            .iter()
            .any(|e| e.msg.contains(needle))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order() {
        let t = Trace::new(false);
        t.coord("start");
        t.emit(2, 1, "INJECTED bit-flip");
        t.coord("end");
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert!(evs[0].msg.contains("start"));
        assert_eq!(evs[1].rank, 2);
        assert!(t.contains("INJECTED"));
        assert!(!t.contains("nothing"));
    }

    #[test]
    fn dump_formats_lines() {
        let t = Trace::new(false);
        t.coord("hello");
        let s = t.dump();
        assert!(s.contains("coord"));
        assert!(s.contains("hello"));
        assert!(s.contains("ms]"));
    }

    #[test]
    fn virtual_clock_stamps_are_deterministic() {
        let stamps = |_: usize| {
            let c = Clock::virtual_clock();
            c.join_n(1);
            let _g = c.guard();
            let t = Trace::with_clock(false, c.clone());
            t.coord("begin");
            c.sleep(Duration::from_millis(250));
            t.coord("after-sleep");
            t.dump()
        };
        assert_eq!(stamps(0), stamps(1));
        assert!(stamps(0).contains("[  250.000 ms]"));
    }
}
