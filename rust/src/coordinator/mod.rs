//! The SEDAR run coordinator.
//!
//! [`SedarRun`] wires an application × a protection strategy × an optional
//! injected fault, executes the (re)launch loop, and produces a
//! [`RunOutcome`] with the detection/recovery history, timing and the
//! end-to-end correctness verdict against the app's sequential oracle.
//!
//! In process terms this plays the role of the paper's launcher scripts +
//! DMTCP coordinator + the external `failures.txt` machinery (§4.2): each
//! *attempt* spawns a fresh world (network + 2 replica threads per rank),
//! joins it, inspects the detector, and — per Algorithm 1 / Algorithm 2 —
//! decides where the next attempt resumes.

pub mod trace;

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::apps::spec::AppSpec;
use crate::checkpoint::snapshot::Codec;
use crate::checkpoint::{SystemChain, UserChain};
use crate::config::{RunConfig, Strategy};
use crate::detect::{DetectionEvent, Detector};
use crate::error::{Result, SedarError};
use crate::inject::{Injector, InjectionSpec, Latch};
use crate::metrics::{MetricsSnapshot, Phase, RunMetrics, Span};
use crate::obs::{Event, EventKind};
use crate::recovery::{decide_resume, ExternCounter, ResumeFrom};
use crate::replica::driver::replica_main;
use crate::replica::pair::PairSync;
use crate::replica::{ReplicaCtx, ReplicaParts};
use crate::runtime::{Engine, EngineHandle};
use crate::state::VarStore;
use crate::util::clock::{Clock, Tick};
use crate::vmpi::Network;

use trace::Trace;

/// Everything a finished run reports.
#[derive(Debug)]
pub struct RunOutcome {
    pub app: String,
    pub strategy: Strategy,
    /// Did an attempt run to completion?
    pub completed: bool,
    /// Total executions (1 = fault-free single pass).
    pub attempts: u32,
    /// Restarts performed — the paper's `N_roll`.
    pub restarts: u32,
    /// The detection event of every failed attempt, in order.
    pub detections: Vec<DetectionEvent>,
    /// What each restart resumed from (parallel to `detections`).
    pub resume_history: Vec<ResumeFrom>,
    /// Final result matches the sequential oracle (None if not completed).
    pub result_correct: Option<bool>,
    /// The master's final result variable (None if not completed). Carried
    /// so cross-configuration runs can be compared **bit-exactly** — the
    /// p2p-vs-native equivalence suite asserts identical final stores, not
    /// just identical oracle verdicts. A `Var` clone is a refcount bump
    /// into the shared store buffer, so carrying it costs no copy on the
    /// campaign hot path.
    pub final_result: Option<crate::state::Var>,
    /// Whether the configured injection actually fired.
    pub injected: bool,
    pub wall: Duration,
    pub attempt_walls: Vec<Duration>,
    pub metrics: MetricsSnapshot,
    pub trace_dump: String,
    /// The typed counterpart of `trace_dump`: the protocol moments as
    /// [`crate::obs::Event`]s in canonical order (`--trace-out`).
    pub events: Vec<Event>,
    /// Begin/end tick pairs of every instrumented phase, canonical order.
    pub spans: Vec<Span>,
}

impl RunOutcome {
    /// One-paragraph human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} under {}: {} after {} attempt(s) ({} restart(s)); \
             detections: [{}]; resumes: [{}]; result {}; wall {}",
            self.app,
            self.strategy.label(),
            if self.completed { "COMPLETED" } else { "GAVE UP" },
            self.attempts,
            self.restarts,
            self.detections
                .iter()
                .map(|d| format!("{}@{}", d.class, d.site))
                .collect::<Vec<_>>()
                .join(", "),
            self.resume_history
                .iter()
                .map(|r| r.to_string())
                .collect::<Vec<_>>()
                .join(", "),
            match self.result_correct {
                Some(true) => "CORRECT".to_string(),
                Some(false) => "WRONG".to_string(),
                None => "n/a".to_string(),
            },
            crate::util::human_duration(self.wall),
        )
    }
}

/// Injected run dependencies.
///
/// A [`SedarRun`] *borrows* its engine handle instead of owning an engine
/// process: the caller builds one `RunDeps` and lends it to as many
/// concurrent runs as it likes, so a whole campaign's worlds share a single
/// serialized compute engine in one process. [`SedarRun::run`] remains the
/// single-run convenience wrapper that builds (and keeps alive) a private
/// engine.
#[derive(Clone, Default)]
pub struct RunDeps {
    /// Handle to a live engine service thread, if XLA compute is available.
    pub engine: Option<EngineHandle>,
    /// Notes accumulated while constructing (engine degradation and the
    /// like); forwarded into each run's trace.
    pub notes: Vec<String>,
}

impl RunDeps {
    /// No engine: every run uses the pure-rust compute fallback.
    pub fn none() -> RunDeps {
        RunDeps::default()
    }

    /// Start an engine serving `artifact_dir` and warm `artifacts`.
    ///
    /// Any failure (engine start, missing artifact) degrades to the
    /// pure-rust path with a note rather than failing the run — the same
    /// contract the coordinator always had. The returned [`Engine`] owner
    /// must be kept alive for as long as the deps are used.
    pub fn start(
        use_xla: bool,
        artifact_dir: &Path,
        artifacts: &[String],
    ) -> (RunDeps, Option<Engine>) {
        if !use_xla {
            return (RunDeps::none(), None);
        }
        match Engine::start(artifact_dir) {
            Ok(engine) => {
                let handle = engine.handle();
                for art in artifacts {
                    if let Err(err) = handle.warm(art) {
                        let deps = RunDeps {
                            engine: None,
                            notes: vec![format!(
                                "artifact '{art}' unavailable ({err}); using rust fallback"
                            )],
                        };
                        return (deps, None);
                    }
                }
                (
                    RunDeps {
                        engine: Some(handle),
                        notes: Vec::new(),
                    },
                    Some(engine),
                )
            }
            Err(err) => (
                RunDeps {
                    engine: None,
                    notes: vec![format!("XLA engine unavailable ({err}); rust fallback")],
                },
                None,
            ),
        }
    }
}

/// A configured SEDAR execution.
pub struct SedarRun {
    pub app: Arc<dyn AppSpec>,
    pub cfg: Arc<RunConfig>,
    pub injections: Vec<InjectionSpec>,
}

struct Shared {
    app: Arc<dyn AppSpec>,
    cfg: Arc<RunConfig>,
    injector: Arc<Injector>,
    sys_chain: Option<Arc<SystemChain>>,
    user_chain: Option<Arc<UserChain>>,
    engine: Option<EngineHandle>,
    metrics: Arc<RunMetrics>,
    trace: Arc<Trace>,
    /// The world clock, created from `cfg.clock` and shared by the network,
    /// every pair channel and every replica thread of this run.
    clock: Clock,
}

enum AttemptResult {
    Completed(VarStore),
    Fault(DetectionEvent),
}

impl SedarRun {
    pub fn new(
        app: Arc<dyn AppSpec>,
        cfg: RunConfig,
        injection: Option<InjectionSpec>,
    ) -> SedarRun {
        SedarRun {
            app,
            cfg: Arc::new(cfg),
            injections: injection.into_iter().collect(),
        }
    }

    /// A run with several independent armed faults (§4.2's multi-fault
    /// extension; each fault gets its own external latch file).
    pub fn new_multi(
        app: Arc<dyn AppSpec>,
        cfg: RunConfig,
        injections: Vec<InjectionSpec>,
    ) -> SedarRun {
        SedarRun {
            app,
            cfg: Arc::new(cfg),
            injections,
        }
    }

    /// Execute the run to completion (or give up after `max_attempts`),
    /// building (and keeping alive) a private engine per the config.
    pub fn run(&self) -> Result<RunOutcome> {
        let (deps, _engine) = RunDeps::start(
            self.cfg.use_xla,
            &self.cfg.artifact_dir,
            &self.app.artifacts(),
        );
        self.run_with(&deps)
    }

    /// Execute the run with *borrowed* dependencies: the caller owns the
    /// engine (if any) and may lend the same deps to many concurrent runs.
    pub fn run_with(&self, deps: &RunDeps) -> Result<RunOutcome> {
        // One clock per run: wall for interactive/bench runs, virtual for
        // campaign worlds. Every blocking primitive below routes through it.
        let clock = Clock::new(self.cfg.clock);
        let t_run = clock.now();
        // Fresh working directory.
        let _ = std::fs::remove_dir_all(&self.cfg.run_dir);
        std::fs::create_dir_all(&self.cfg.run_dir)?;

        let trace = Arc::new(Trace::with_clock(self.cfg.echo_trace, clock.clone()));
        let metrics = Arc::new(RunMetrics::new(clock.clone()));

        // Fault injection latches (injected_<i>.txt), external to all
        // checkpoints — the paper's injected.txt (§4.2).
        let injector = Arc::new(if self.injections.is_empty() {
            Injector::none()
        } else {
            let mut slots = Vec::with_capacity(self.injections.len());
            for (i, spec) in self.injections.iter().enumerate() {
                let latch =
                    Latch::file_backed(&self.cfg.run_dir.join(format!("injected_{i}.txt")))?;
                slots.push((spec.clone(), latch));
            }
            Injector::multi(slots)
        });

        // Checkpoint substrates per strategy.
        let nranks = self.app.nranks();
        let codec: Codec = self.cfg.codec;
        let sys_chain = match self.cfg.strategy {
            Strategy::SysCkpt => Some(Arc::new(SystemChain::create(
                &self.cfg.run_dir.join("ckpt"),
                nranks,
                codec,
            )?)),
            _ => None,
        };
        let user_chain = match self.cfg.strategy {
            Strategy::UserCkpt => Some(Arc::new(UserChain::create(
                &self.cfg.run_dir.join("uckpt"),
                nranks,
                codec,
            )?)),
            _ => None,
        };

        // Borrowed XLA engine (optional): the deps owner keeps it alive.
        for note in &deps.notes {
            trace.coord(note.clone());
        }
        let engine: Option<EngineHandle> = if self.cfg.use_xla {
            deps.engine.clone()
        } else {
            None
        };

        let shared = Shared {
            app: Arc::clone(&self.app),
            cfg: Arc::clone(&self.cfg),
            injector: Arc::clone(&injector),
            sys_chain,
            user_chain,
            engine,
            metrics: Arc::clone(&metrics),
            trace: Arc::clone(&trace),
            clock,
        };

        if self.cfg.strategy == Strategy::Baseline {
            return self.run_baseline(&shared, t_run);
        }

        // Algorithm 1's external counter.
        let counter = ExternCounter::at(&self.cfg.run_dir)?;
        counter.reset()?;

        let mut attempts: u32 = 0;
        let mut detections = Vec::new();
        let mut resume_history = Vec::new();
        let mut attempt_walls = Vec::new();
        let mut resume = ResumeFrom::Scratch;

        trace.coord_event(
            EventKind::RunStart,
            format!(
                "run start: app={} strategy={} nranks={} inject={}",
                self.app.name(),
                self.cfg.strategy.label(),
                nranks,
                if self.injections.is_empty() {
                    "none".to_string()
                } else {
                    self.injections
                        .iter()
                        .map(|s| s.name.clone())
                        .collect::<Vec<_>>()
                        .join("+")
                },
            ),
        );

        loop {
            attempts += 1;
            trace.set_attempt(attempts);
            let t_attempt = shared.clock.now();
            trace.coord_event(
                EventKind::AttemptStart,
                format!("attempt {attempts}: start from {resume}"),
            );
            let result = self.attempt(&shared, resume, attempts)?;
            attempt_walls.push(shared.clock.since(t_attempt));

            match result {
                AttemptResult::Completed(master_store) => {
                    let correct = self.check_oracle(&master_store)?;
                    let final_result = master_store.get(self.app.result_var())?.clone();
                    trace.coord_event(
                        EventKind::Completed,
                        format!(
                            "attempt {attempts}: COMPLETED (result {})",
                            if correct { "correct" } else { "WRONG" }
                        ),
                    );
                    return Ok(RunOutcome {
                        app: self.app.name().to_string(),
                        strategy: self.cfg.strategy,
                        completed: true,
                        attempts,
                        restarts: attempts - 1,
                        detections,
                        resume_history,
                        result_correct: Some(correct),
                        final_result: Some(final_result),
                        injected: injector.injected(),
                        wall: shared.clock.since(t_run),
                        attempt_walls,
                        metrics: metrics.snapshot(),
                        trace_dump: trace.dump(),
                        events: trace.typed_events(),
                        spans: metrics.take_spans(),
                    });
                }
                AttemptResult::Fault(ev) => {
                    trace.coord_event(
                        EventKind::Detected,
                        format!(
                            "attempt {attempts}: FAULT {} detected at {} (rank {})",
                            ev.class, ev.site, ev.rank
                        ),
                    );
                    detections.push(ev);
                    if attempts >= self.cfg.max_attempts {
                        trace.coord_event(
                            EventKind::GaveUp,
                            "max attempts exceeded: giving up".to_string(),
                        );
                        return Ok(RunOutcome {
                            app: self.app.name().to_string(),
                            strategy: self.cfg.strategy,
                            completed: false,
                            attempts,
                            restarts: attempts - 1,
                            detections,
                            resume_history,
                            result_correct: None,
                            final_result: None,
                            injected: injector.injected(),
                            wall: shared.clock.since(t_run),
                            attempt_walls,
                            metrics: metrics.snapshot(),
                            trace_dump: trace.dump(),
                            events: trace.typed_events(),
                            spans: metrics.take_spans(),
                        });
                    }
                    // Algorithm 1 / Algorithm 2 resume decision.
                    let rb = metrics.span(Phase::Rollback, u32::MAX, 0);
                    metrics.add(&metrics.rollbacks, 1);
                    let n_fail = counter.increment()?;
                    let sys_count = match &shared.sys_chain {
                        Some(c) => Some(c.count()?),
                        None => None,
                    };
                    let user_latest = match &shared.user_chain {
                        Some(c) => c.latest()?,
                        None => None,
                    };
                    resume = decide_resume(self.cfg.strategy, sys_count, n_fail, user_latest);
                    if let (ResumeFrom::SysCkpt(k), Some(chain)) = (resume, &shared.sys_chain)
                    {
                        // §4.2: the wrong-restart checkpoint will be stored
                        // again during re-execution; logically truncate.
                        chain.truncate(k + 1)?;
                    }
                    drop(rb);
                    trace.coord_event(
                        EventKind::Rollback,
                        format!("recovery: extern_counter={n_fail} → resume from {resume}"),
                    );
                    resume_history.push(resume);
                }
            }
        }
    }

    /// One execution attempt: fresh world, run every replica to completion
    /// or first detection.
    fn attempt(
        &self,
        shared: &Shared,
        resume: ResumeFrom,
        attempt_no: u32,
    ) -> Result<AttemptResult> {
        let nranks = self.app.nranks();
        // Network faults are transient soft errors: the plan folds the
        // attempt number, so a re-execution sees fresh perturbation
        // positions (deterministically) instead of replaying the exact
        // fault that killed the previous attempt.
        let faults = crate::faultnet::FaultLayer::for_attempt(
            self.cfg.netfault,
            self.cfg.seed,
            attempt_no,
            self.cfg.toe_timeout,
        )
        .map(Arc::new);
        let net = Network::with_faults(nranks, shared.clock.clone(), faults);
        let detector = Arc::new(Detector::new());
        detector.attach_network(Arc::clone(&net));

        // Build every replica context before registering participants or
        // spawning: a state-build error must not leave clock slots claimed.
        let mut ctxs = Vec::with_capacity(nranks * 2);
        for rank in 0..nranks {
            let pair = PairSync::with_clock(detector.abort_flag(), shared.clock.clone());
            let (stores, cursor) = self.build_state(shared, rank, resume)?;
            for (replica, store) in stores.into_iter().enumerate() {
                ctxs.push(ReplicaCtx::new(ReplicaParts {
                    rank,
                    nranks,
                    replica,
                    start_cursor: cursor,
                    store,
                    cfg: Arc::clone(&shared.cfg),
                    pair: Arc::clone(&pair),
                    ep: net.endpoint(rank),
                    detector: Arc::clone(&detector),
                    injector: Arc::clone(&shared.injector),
                    sys_chain: shared.sys_chain.clone(),
                    user_chain: shared.user_chain.clone(),
                    engine: shared.engine.clone(),
                    metrics: Arc::clone(&shared.metrics),
                    trace: Arc::clone(&shared.trace),
                    clock: shared.clock.clone(),
                    significant: shared.app.significant_vars(rank),
                    solo: false,
                }));
            }
        }

        // Register every replica thread with the world clock BEFORE any of
        // them can run, so a not-yet-scheduled thread is never mistaken for
        // a blocked one (which would let virtual time advance early). Each
        // guard travels into its thread and releases the slot on drop —
        // thread exit, panic unwind, or a failed spawn alike.
        shared.clock.join_n(ctxs.len());
        // Claim every guard up front: if a spawn fails halfway, dropping
        // this vector (and the failed closure) releases every slot, so
        // already-running replicas can still quiesce and time out instead
        // of hanging on a world that never reaches quiescence.
        let mut guards: Vec<_> = ctxs.iter().map(|_| shared.clock.guard()).collect();
        let mut handles = Vec::with_capacity(ctxs.len());
        for ctx in ctxs {
            let app = Arc::clone(&shared.app);
            let det = Arc::clone(&detector);
            let participant = guards.pop().expect("one guard per ctx");
            handles.push(
                std::thread::Builder::new()
                    .name(format!("r{}.{}", ctx.rank, ctx.replica))
                    .spawn(move || {
                        let _participant = participant;
                        let mut ctx = ctx;
                        let r = replica_main(&*app, &mut ctx);
                        if let Err(e) = &r {
                            if !e.is_fault_signal() {
                                det.hard_abort();
                            }
                        }
                        (r, ctx.rank, ctx.replica, ctx.store)
                    })
                    .map_err(|e| SedarError::Runtime(format!("spawn: {e}")))?,
            );
        }

        let mut master_store: Option<VarStore> = None;
        let mut hard_error: Option<SedarError> = None;
        for h in handles {
            let (r, rank, replica, store) = h
                .join()
                .map_err(|_| SedarError::Runtime("replica thread panicked".into()))?;
            match r {
                Ok(()) => {
                    if rank == 0 && replica == 0 {
                        master_store = Some(store);
                    }
                }
                Err(e) if e.is_fault_signal() => {}
                Err(e) => {
                    if hard_error.is_none() {
                        hard_error = Some(e);
                    }
                }
            }
        }
        // Drain the fault layer's typed perturbation events into the run
        // trace whatever the attempt's outcome.
        if let Some(fl) = net.fault_layer() {
            shared.trace.ingest_events(fl.take_events());
        }
        if let Some(e) = hard_error {
            return Err(e);
        }
        if let Some(ev) = detector.event() {
            return Ok(AttemptResult::Fault(ev));
        }
        let store = master_store.ok_or_else(|| {
            SedarError::Runtime("no master store after successful attempt".into())
        })?;
        Ok(AttemptResult::Completed(store))
    }

    /// Build the two replica stores + start cursor for `rank` per the
    /// resume decision.
    fn build_state(
        &self,
        shared: &Shared,
        rank: usize,
        resume: ResumeFrom,
    ) -> Result<([VarStore; 2], u64)> {
        match resume {
            ResumeFrom::Scratch => {
                // Both replicas start from the identical deterministic
                // store; the clone is a per-buffer refcount bump (COW keeps
                // replica isolation: the first write — injected or computed
                // — privatizes the touched buffer). Halves the init work
                // and, with the pooled-world arena, lets a campaign worker
                // recycle one set of allocations across world builds.
                let s0 = shared.app.init_store(rank, shared.cfg.seed);
                let s1 = s0.clone();
                Ok(([s0, s1], 0))
            }
            ResumeFrom::SysCkpt(k) => {
                let chain = shared.sys_chain.as_ref().ok_or_else(|| {
                    SedarError::Checkpoint("sys resume without chain".into())
                })?;
                let snap = chain.read(k, rank)?;
                // System-level restore is FAITHFUL: replica divergence
                // captured in a dirty checkpoint comes back (§3.2).
                Ok((snap.stores, snap.cursor))
            }
            ResumeFrom::UserCkpt(k) => {
                let chain = shared.user_chain.as_ref().ok_or_else(|| {
                    SedarError::Checkpoint("user resume without chain".into())
                })?;
                let snap = chain.read(k, rank)?;
                // User-level restore loads the single VALIDATED copy into
                // both replicas (overlaid on a fresh base store), wiping any
                // divergence (§3.3). Overlay once, then COW-clone for the
                // sibling — same sharing discipline as the scratch path.
                let mut base0 = shared.app.init_store(rank, shared.cfg.seed);
                for name in snap.store.names() {
                    let v = snap.store.get(name)?;
                    base0.insert(name, v.clone());
                }
                let base1 = base0.clone();
                Ok(([base0, base1], snap.cursor))
            }
        }
    }

    /// Compare the protected run's final result against the sequential
    /// oracle, tolerating XLA-vs-naive accumulation-order noise.
    fn check_oracle(&self, master_store: &VarStore) -> Result<bool> {
        let got = master_store.f32(self.app.result_var())?;
        let want = self.app.expected_result(self.cfg.seed);
        if got.len() != want.len() {
            return Ok(false);
        }
        Ok(got.iter().zip(&want).all(|(g, w)| {
            let tol = 1e-3f32.max(w.abs() * 1e-4);
            (g - w).abs() <= tol
        }))
    }

    // -------------------------------------------------------------- baseline

    /// The paper's baseline (§3): two independent unreplicated instances run
    /// simultaneously; their final results are compared; on mismatch a third
    /// run breaks the tie by majority vote.
    fn run_baseline(&self, shared: &Shared, t_run: Tick) -> Result<RunOutcome> {
        let trace = Arc::clone(&shared.trace);
        trace.coord(format!(
            "baseline: two independent instances of {}",
            self.app.name()
        ));
        let t0 = shared.clock.now();
        let (r0, r1) = std::thread::scope(|s| {
            let h0 = s.spawn(|| self.solo_instance(shared, 0));
            let h1 = s.spawn(|| self.solo_instance(shared, 1));
            (h0.join().unwrap(), h1.join().unwrap())
        });
        let wall_two = shared.clock.since(t0);
        let c0 = r0?;
        let c1 = r1?;
        let equal = c0.f32(self.app.result_var())?.iter().zip(
            c1.f32(self.app.result_var())?.iter(),
        ).all(|(a, b)| a.to_bits() == b.to_bits());

        let mut attempts = 2;
        let mut attempt_walls = vec![wall_two, wall_two];
        let final_store;
        if equal {
            trace.coord("baseline: instances agree".to_string());
            final_store = c0;
        } else {
            // Third run + vote (Equation 2's re-execution).
            trace.coord("baseline: MISMATCH — third run + majority vote".to_string());
            let t2 = shared.clock.now();
            let c2 = self.solo_instance(shared, 2)?;
            attempt_walls.push(shared.clock.since(t2));
            attempts = 3;
            let v2 = c2.f32(self.app.result_var())?;
            let matches0 = c0.f32(self.app.result_var())?.iter().zip(v2.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            final_store = if matches0 { c0 } else { c1 };
        }
        let correct = self.check_oracle(&final_store)?;
        let final_result = final_store.get(self.app.result_var())?.clone();
        Ok(RunOutcome {
            app: self.app.name().to_string(),
            strategy: Strategy::Baseline,
            completed: true,
            attempts,
            restarts: attempts - 2,
            detections: Vec::new(),
            resume_history: Vec::new(),
            result_correct: Some(correct),
            final_result: Some(final_result),
            injected: shared.injector.injected(),
            wall: shared.clock.since(t_run),
            attempt_walls,
            metrics: shared.metrics.snapshot(),
            trace_dump: trace.dump(),
            events: trace.typed_events(),
            spans: shared.metrics.take_spans(),
        })
    }

    /// One unreplicated application instance (baseline component).
    /// `instance` doubles as the injection "replica" id.
    fn solo_instance(&self, shared: &Shared, instance: usize) -> Result<VarStore> {
        let nranks = self.app.nranks();
        // Baseline instances face the same faulty interconnect; the
        // instance number plays the attempt role in the plan seed.
        let faults = crate::faultnet::FaultLayer::for_attempt(
            self.cfg.netfault,
            self.cfg.seed,
            instance as u32 + 1,
            self.cfg.toe_timeout,
        )
        .map(Arc::new);
        let net = Network::with_faults(nranks, shared.clock.clone(), faults);
        let detector = Arc::new(Detector::new());
        detector.attach_network(Arc::clone(&net));
        // Same participant discipline as `attempt`: register all ranks of
        // this instance up front, one pre-claimed guard per thread (a
        // failed spawn drops the rest, keeping the slot count honest).
        shared.clock.join_n(nranks);
        let mut guards: Vec<_> = (0..nranks).map(|_| shared.clock.guard()).collect();
        let mut handles = Vec::with_capacity(nranks);
        for rank in 0..nranks {
            let pair = PairSync::with_clock(detector.abort_flag(), shared.clock.clone());
            let store = shared.app.init_store(rank, shared.cfg.seed);
            let ctx = ReplicaCtx::new(ReplicaParts {
                rank,
                nranks,
                replica: instance,
                start_cursor: 0,
                store,
                cfg: Arc::clone(&shared.cfg),
                pair,
                ep: net.endpoint(rank),
                detector: Arc::clone(&detector),
                injector: Arc::clone(&shared.injector),
                sys_chain: None,
                user_chain: None,
                engine: shared.engine.clone(),
                metrics: Arc::clone(&shared.metrics),
                trace: Arc::clone(&shared.trace),
                clock: shared.clock.clone(),
                significant: Vec::new(),
                solo: true,
            });
            let app = Arc::clone(&shared.app);
            let det = Arc::clone(&detector);
            let participant = guards.pop().expect("one guard per rank");
            handles.push(
                std::thread::Builder::new()
                    .name(format!("solo{instance}.r{rank}"))
                    .spawn(move || {
                        let _participant = participant;
                        let mut ctx = ctx;
                        let r = replica_main(&*app, &mut ctx);
                        if r.is_err() {
                            det.hard_abort();
                        }
                        (r, ctx.rank, ctx.store)
                    })
                    .map_err(|e| SedarError::Runtime(format!("spawn: {e}")))?,
            );
        }
        let mut master = None;
        let mut err = None;
        for h in handles {
            let (r, rank, store) = h
                .join()
                .map_err(|_| SedarError::Runtime("solo thread panicked".into()))?;
            match r {
                Ok(()) => {
                    if rank == 0 {
                        master = Some(store);
                    }
                }
                Err(e) if err.is_none() => err = Some(e),
                Err(_) => {}
            }
        }
        if let Some(fl) = net.fault_layer() {
            shared.trace.ingest_events(fl.take_events());
        }
        if let Some(e) = err {
            return Err(e);
        }
        master.ok_or_else(|| SedarError::Runtime("solo instance lost master store".into()))
    }
}
