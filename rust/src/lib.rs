//! # SEDAR-RS
//!
//! A reproduction of *"Soft Errors Detection and Automatic Recovery based on
//! Replication combined with different Levels of Checkpointing"* (Montezanti
//! et al., Future Generation Computer Systems, 2020).
//!
//! SEDAR protects message-passing parallel applications against transient
//! faults (silent data corruption and time-out errors) by duplicating every
//! application process in a replica thread, validating the contents of every
//! outgoing message between the two replicas before it is sent, and — when a
//! divergence is detected — recovering automatically from one of two kinds of
//! checkpoints:
//!
//! 1. **Detection-only** — notify the user and safe-stop (§3.1 of the paper).
//! 2. **Multiple system-level checkpoints** — a DMTCP-style chain of
//!    coordinated whole-state snapshots walked backwards until a clean one is
//!    found (§3.2, Algorithm 1).
//! 3. **A single validated application-level checkpoint** — per-replica dumps
//!    of the application's significant variables, cross-validated by hash so
//!    at most one rollback is ever needed (§3.3, Algorithm 2).
//!
//! The crate is the Layer-3 (coordination) component of a three-layer stack:
//! the compute hot spots of the benchmark applications are Pallas kernels
//! (Layer 1) wrapped in JAX functions (Layer 2) that are AOT-lowered to HLO
//! text at build time and executed from Rust through the PJRT C API (the
//! [`runtime`] module). Python never runs on the request path.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`cluster`] | multicore-cluster topology model + replica placement |
//! | [`vmpi`] | in-process message-passing substrate (the "MPI") |
//! | [`state`] | typed variable store = the application state |
//! | [`replica`] | dual-replica lockstep execution of each rank |
//! | [`detect`] | comparison engine: TDC / FSC / TOE / LE classification |
//! | [`inject`] | controlled bit-flip fault injection (§4.2) |
//! | [`checkpoint`] | system-level chain + user-level validated checkpoints |
//! | [`recovery`] | Algorithms 1 and 2: rollback orchestration |
//! | [`coordinator`] | the SEDAR run controller (strategy × app × injection) |
//! | [`campaign`] | parallel sweep of the workfault × apps × strategies |
//! | [`fleet`] | sharded multi-process sweeps: shard plans, per-shard write-ahead log (resume = replay), status endpoint, supervisor + sweep objects, self-healing launch driver |
//! | [`serve`] | campaign-as-a-service gateway: pooled concurrent sweeps over HTTP |
//! | [`apps`] | matmul (Master/Worker), Jacobi (SPMD), Smith-Waterman (pipeline) |
//! | [`workfault`] | the 64-scenario workfault catalog + prediction oracle (§4.1) |
//! | [`model`] | analytical temporal model: Equations 1–14 + AET (§3.4, §4.3-4.4) |
//! | [`runtime`] | PJRT engine: loads `artifacts/*.hlo.txt`, executes from rust |
//! | [`faultnet`] | deterministic network-fault injection (drop/dup/reorder/corrupt) |
//! | [`metrics`] | tick-based phase counters/spans + measured Table-3 parameters |
//! | [`obs`] | typed run events: CRC'd trace logs + Chrome/Perfetto export |
//! | [`conform`] | N-run determinism-conformance harness + divergence localizer |
//! | [`report`] | markdown / CSV table emitters for the experiment harness |
//! | [`bench`] | `sedar bench`: the machine-readable perf trajectory (`BENCH_*.json`) |
//! | [`prop`] | in-repo property-based testing mini-framework |

pub mod apps;
pub mod bench;
pub mod campaign;
pub mod checkpoint;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod conform;
pub mod coordinator;
pub mod detect;
pub mod error;
pub mod faultnet;
pub mod fleet;
pub mod inject;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod prop;
pub mod recovery;
pub mod replica;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod state;
pub mod util;
pub mod vmpi;
pub mod workfault;

pub use error::{Result, SedarError};
