//! The PJRT execution engine — Layer-3's bridge to the AOT-compiled
//! Layer-2/Layer-1 compute.
//!
//! `make artifacts` (python, build time only) lowers each JAX model function
//! — whose hot spot is a Pallas kernel — to **HLO text** under
//! `artifacts/<name>.hlo.txt`. At run time this module loads the text,
//! compiles it once on the PJRT CPU client and executes it from the rank
//! threads' hot path. HLO *text* (not serialized protos) is the interchange
//! format because jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! ## Threading model
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so the engine
//! owns it on a dedicated **service thread**; rank/replica threads talk to
//! it through a cloneable [`EngineHandle`] over an mpsc channel. Execution
//! requests are serialized, which also guarantees the bit-exact determinism
//! SEDAR's replica comparison relies on (same executable + same inputs ⇒
//! same output bytes, trivially, since there is exactly one compute stream).
//! The perf pass measures the dispatch overhead in
//! `benches/micro_hotpath.rs`.
//!
//! ## The `pjrt` feature
//!
//! The PJRT client binds the external `xla` crate (xla_extension C++),
//! which is not part of the offline dependency set. The binding is gated
//! behind the off-by-default `pjrt` cargo feature: without it the engine
//! fails to start with a clear message and every caller degrades to the
//! bit-deterministic pure-rust compute fallbacks (the coordinator already
//! treats engine start/warm failure as "use the fallback"). Enabling
//! `pjrt` requires adding the `xla` dependency locally.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

use crate::error::{Result, SedarError};
#[cfg(feature = "pjrt")]
use crate::state::{Buf, DType};
use crate::state::Var;

/// A compute request: run artifact `name` on `inputs`.
struct ExecRequest {
    artifact: String,
    inputs: Vec<Var>,
    resp: mpsc::Sender<Result<Vec<Var>>>,
}

enum Msg {
    Exec(ExecRequest),
    /// Preload + compile an artifact (warm-up path, so compile time does not
    /// pollute hot-path measurements).
    Warm(String, mpsc::Sender<Result<()>>),
    Shutdown,
}

/// Cloneable, thread-safe handle to the engine service thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
}

impl EngineHandle {
    /// Execute artifact `name` with `inputs`; returns the output buffers.
    pub fn execute(&self, name: &str, inputs: Vec<Var>) -> Result<Vec<Var>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Exec(ExecRequest {
                artifact: name.to_string(),
                inputs,
                resp: tx,
            }))
            .map_err(|_| SedarError::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| SedarError::Runtime("engine thread dropped reply".into()))?
    }

    /// Compile `name` now (idempotent).
    pub fn warm(&self, name: &str) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Warm(name.to_string(), tx))
            .map_err(|_| SedarError::Runtime("engine thread gone".into()))?;
        rx.recv()
            .map_err(|_| SedarError::Runtime("engine thread dropped reply".into()))?
    }
}

/// The engine: spawns the service thread at construction, joins at drop.
pub struct Engine {
    handle: EngineHandle,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Engine {
    /// Start an engine serving artifacts from `artifact_dir`.
    pub fn start(artifact_dir: &Path) -> Result<Engine> {
        let dir = artifact_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("sedar-xla".into())
            .spawn(move || service_main(dir, rx, ready_tx))
            .map_err(|e| SedarError::Runtime(format!("spawn engine: {e}")))?;
        // Fail fast if the PJRT client cannot be created.
        ready_rx
            .recv()
            .map_err(|_| SedarError::Runtime("engine init lost".into()))??;
        Ok(Engine {
            handle: EngineHandle { tx },
            join: Mutex::new(Some(join)),
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Default artifact directory: `$SEDAR_ARTIFACTS` or `./artifacts`.
    pub fn default_artifact_dir() -> PathBuf {
        std::env::var("SEDAR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// True if the artifact directory exists and holds at least one .hlo.txt
    /// (used to decide between the XLA path and the pure-rust fallback).
    pub fn artifacts_available(dir: &Path) -> bool {
        std::fs::read_dir(dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .any(|e| e.file_name().to_string_lossy().ends_with(".hlo.txt"))
            })
            .unwrap_or(false)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

// ---------------------------------------------------------------- service

/// Without the `pjrt` feature there is no PJRT client to serve: fail the
/// ready handshake so `Engine::start` errors out and callers fall back to
/// the pure-rust compute path.
#[cfg(not(feature = "pjrt"))]
fn service_main(_dir: PathBuf, rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let _ = ready.send(Err(SedarError::Runtime(
        "sedar was built without the `pjrt` feature; XLA engine unavailable".into(),
    )));
    // Answer any stray requests with the same error so senders never hang.
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Warm(name, resp) => {
                let _ = resp.send(Err(SedarError::Runtime(format!(
                    "pjrt disabled: cannot warm '{name}'"
                ))));
            }
            Msg::Exec(req) => {
                let ExecRequest {
                    artifact,
                    inputs,
                    resp,
                } = req;
                drop(inputs);
                let _ = resp.send(Err(SedarError::Runtime(format!(
                    "pjrt disabled: cannot execute '{artifact}'"
                ))));
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn service_main(dir: PathBuf, rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(SedarError::Runtime(format!(
                "PjRtClient::cpu failed: {e}"
            ))));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Warm(name, resp) => {
                let r = ensure(&client, &dir, &mut cache, &name).map(|_| ());
                let _ = resp.send(r);
            }
            Msg::Exec(req) => {
                let r = exec_one(&client, &dir, &mut cache, &req.artifact, &req.inputs);
                let _ = req.resp.send(r);
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn ensure<'a>(
    client: &xla::PjRtClient,
    dir: &Path,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
) -> Result<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(name) {
        let path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            SedarError::Runtime(format!("load {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| SedarError::Runtime(format!("compile {name}: {e}")))?;
        cache.insert(name.to_string(), exe);
    }
    Ok(cache.get(name).unwrap())
}

#[cfg(feature = "pjrt")]
fn to_literal(v: &Var) -> Result<xla::Literal> {
    let lit = match v.buf.dtype() {
        DType::F32 => xla::Literal::vec1(v.buf.as_f32()?),
        DType::F64 => xla::Literal::vec1(v.buf.as_f64()?),
        DType::I64 => xla::Literal::vec1(v.buf.as_i64()?),
        DType::U8 => {
            return Err(SedarError::Runtime(
                "u8 buffers are not executable inputs".into(),
            ))
        }
    };
    if v.shape.is_empty() {
        return Ok(lit);
    }
    let dims: Vec<i64> = v.shape.iter().map(|d| *d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| SedarError::Runtime(format!("reshape input: {e}")))
}

#[cfg(feature = "pjrt")]
fn from_literal(lit: &xla::Literal) -> Result<Var> {
    let shape = lit
        .array_shape()
        .map_err(|e| SedarError::Runtime(format!("output shape: {e}")))?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let ty = lit
        .ty()
        .map_err(|e| SedarError::Runtime(format!("output type: {e}")))?;
    let buf = match ty {
        xla::ElementType::F32 => Buf::f32(
            &lit.to_vec::<f32>()
                .map_err(|e| SedarError::Runtime(format!("read f32: {e}")))?,
        ),
        xla::ElementType::F64 => Buf::f64(
            &lit.to_vec::<f64>()
                .map_err(|e| SedarError::Runtime(format!("read f64: {e}")))?,
        ),
        xla::ElementType::S64 => Buf::i64(
            &lit.to_vec::<i64>()
                .map_err(|e| SedarError::Runtime(format!("read i64: {e}")))?,
        ),
        other => {
            return Err(SedarError::Runtime(format!(
                "unsupported output type {other:?}"
            )))
        }
    };
    Ok(Var { shape: dims, buf })
}

#[cfg(feature = "pjrt")]
fn exec_one(
    client: &xla::PjRtClient,
    dir: &Path,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    inputs: &[Var],
) -> Result<Vec<Var>> {
    let exe = ensure(client, dir, cache, name)?;
    let lits: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
    let out = exe
        .execute::<xla::Literal>(&lits)
        .map_err(|e| SedarError::Runtime(format!("execute {name}: {e}")))?;
    let result = out[0][0]
        .to_literal_sync()
        .map_err(|e| SedarError::Runtime(format!("fetch result: {e}")))?;
    // aot.py lowers with return_tuple=True: the result is always a tuple.
    let parts = result
        .to_tuple()
        .map_err(|e| SedarError::Runtime(format!("untuple: {e}")))?;
    parts.iter().map(from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full engine tests (needing artifacts) live in rust/tests/runtime_xla.rs;
    // here we cover the host-side marshalling only.

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let v = Var::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = to_literal(&v).unwrap();
        let back = from_literal(&lit).unwrap();
        assert_eq!(back, v);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn u8_inputs_rejected() {
        let v = Var {
            shape: vec![1],
            buf: Buf::u8(&[1]),
        };
        assert!(to_literal(&v).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn engine_start_fails_cleanly_without_pjrt() {
        let err = Engine::start(Path::new("artifacts")).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn artifacts_probe() {
        let dir = std::env::temp_dir().join(format!("sedar-art-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(!Engine::artifacts_available(&dir));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(!Engine::artifacts_available(&dir));
        std::fs::write(dir.join("x.hlo.txt"), "hlo").unwrap();
        assert!(Engine::artifacts_available(&dir));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
