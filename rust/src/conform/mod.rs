//! `sedar conform` — the N-run determinism harness and divergence
//! localizer.
//!
//! The repo's central reproducibility claim is that a campaign slice is a
//! pure function of its seed: same seed + same filter ⇒ byte-identical
//! report and trace logs, whatever the worker count, shard split or host
//! load — and PR 8's network-fault axis leans on that claim hardest, since
//! a reorder/dup schedule that varied between runs would make every
//! faulted verdict unreproducible. `conform` turns the claim into a
//! checked property: it executes the same slice N times into per-run
//! scratch directories, byte-compares every deterministic artifact the
//! runs produced (the deterministic report plus each task's typed trace
//! log), and on the first mismatch localizes it exactly — artifact name,
//! byte offset, a 16-byte hex window from both runs, and, when the
//! artifact is a trace log, a decoded root-cause hint naming the first
//! divergent event's tick, kind, rank and replica.
//!
//! On success the scratch tree is removed; on divergence it is left in
//! place so the operator can diff the full artifacts.

use std::path::{Path, PathBuf};

use crate::campaign::{CampaignReport, CampaignSpec};
use crate::error::{Result, SedarError};
use crate::fleet::{self, FleetOptions};

/// What to replay and how often.
pub struct ConformOpts {
    /// Number of identical executions (≥ 2; run 0 is the baseline).
    pub runs: usize,
    /// Campaign master seed, as for `sedar campaign --seed`.
    pub seed: u64,
    /// Optional cell filter, as for `sedar campaign --filter`.
    pub filter: Option<String>,
    /// Worker threads per run (jobs-invariance is part of the contract,
    /// so any value must yield the same bytes).
    pub jobs: usize,
    /// Scratch root; per-run trees live at `<work_dir>/run-<i>/`.
    pub work_dir: PathBuf,
}

/// The first byte-level disagreement between run 0 and a later run.
#[derive(Debug)]
pub struct Divergence {
    /// Which artifact differed (`report.md` or `task-NNNN.trace`).
    pub artifact: String,
    /// The run (1-based index into the replay sequence) that disagreed
    /// with run 0.
    pub run: usize,
    /// First differing byte offset (== the shorter length when one
    /// artifact is a strict prefix of the other).
    pub offset: usize,
    /// 16-byte hex window around `offset` in run 0's artifact.
    pub baseline_hex: String,
    /// The same window in the diverged run's artifact.
    pub diverged_hex: String,
    /// Root-cause hint: for trace logs, the first decoded event the two
    /// runs disagree on (tick/kind/rank/replica); otherwise a structural
    /// note.
    pub hint: String,
}

impl Divergence {
    /// Operator-facing localization block.
    pub fn render(&self) -> String {
        format!(
            "conformance FAILED: run 0 and run {} diverge in {} at byte {}\n\
             \x20 run 0   : {}\n\
             \x20 run {:<4}: {}\n\
             \x20 hint    : {}",
            self.run,
            self.artifact,
            self.offset,
            self.baseline_hex,
            self.run,
            self.diverged_hex,
            self.hint
        )
    }
}

/// Result of a conformance campaign.
pub struct ConformOutcome {
    pub runs: usize,
    /// Tasks executed per run.
    pub tasks: usize,
    /// Artifacts compared per run (report + one trace per task).
    pub artifacts: usize,
    /// `None` ⇒ all runs byte-identical.
    pub divergence: Option<Divergence>,
}

impl ConformOutcome {
    pub fn passed(&self) -> bool {
        self.divergence.is_none()
    }

    pub fn summary(&self) -> String {
        match &self.divergence {
            None => format!(
                "conformance OK: {} run(s) × {} task(s), {} artifact(s) byte-identical",
                self.runs, self.tasks, self.artifacts
            ),
            Some(d) => d.render(),
        }
    }
}

/// One comparable artifact of one run: its name (comparison key across
/// runs), on-disk path (kept for trace decoding) and raw bytes.
struct Artifact {
    name: String,
    path: PathBuf,
    bytes: Vec<u8>,
}

/// Execute the slice `opts.runs` times and compare.
pub fn run_conform(opts: &ConformOpts) -> Result<ConformOutcome> {
    if opts.runs < 2 {
        return Err(SedarError::Config(format!(
            "conform: --runs {} makes no comparison (need at least 2)",
            opts.runs
        )));
    }
    let mut baseline: Vec<Artifact> = Vec::new();
    let mut tasks = 0usize;
    for run in 0..opts.runs {
        let (n, artifacts) = one_run(opts, run)?;
        if run == 0 {
            tasks = n;
            baseline = artifacts;
            continue;
        }
        if let Some(d) = compare_runs(&baseline, &artifacts, run) {
            // Leave the scratch tree for inspection.
            return Ok(ConformOutcome {
                runs: opts.runs,
                tasks,
                artifacts: baseline.len(),
                divergence: Some(d),
            });
        }
    }
    let artifacts = baseline.len();
    let _ = std::fs::remove_dir_all(&opts.work_dir);
    Ok(ConformOutcome {
        runs: opts.runs,
        tasks,
        artifacts,
        divergence: None,
    })
}

/// Run the slice once into `<work_dir>/run-<i>/` and collect its
/// artifacts, name-sorted (directory iteration order is not stable).
fn one_run(opts: &ConformOpts, run: usize) -> Result<(usize, Vec<Artifact>)> {
    let dir = opts.work_dir.join(format!("run-{run}"));
    let trace_dir = dir.join("trace");
    let mut spec = CampaignSpec::new(opts.seed);
    spec.jobs = opts.jobs.max(1);
    if let Some(f) = &opts.filter {
        spec.apply_filter(f)?;
    }
    spec.echo = false;
    spec.base.run_dir = dir.join("world");
    spec.trace_out = Some(trace_dir.clone());
    let shard = fleet::run_shard(&spec, &FleetOptions::default())?;
    let tasks = shard.outcomes.len();
    let report = CampaignReport::new(spec.seed, shard.outcomes);
    let report_path = dir.join("report.md");
    let report_bytes = report.deterministic_report().into_bytes();
    std::fs::write(&report_path, &report_bytes)?;
    // The per-world scratch (checkpoints, stores) is not a comparison
    // artifact — every deterministic byte it influences is already in the
    // report and traces.
    let _ = std::fs::remove_dir_all(dir.join("world"));
    let mut artifacts = vec![Artifact {
        name: "report.md".into(),
        path: report_path,
        bytes: report_bytes,
    }];
    for entry in std::fs::read_dir(&trace_dir)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let path = entry.path();
        let bytes = std::fs::read(&path)?;
        artifacts.push(Artifact { name, path, bytes });
    }
    artifacts.sort_by(|a, b| a.name.cmp(&b.name));
    Ok((tasks, artifacts))
}

/// First differing byte offset, or `None` if `a == b`. A strict prefix
/// diverges at the shorter length.
fn first_diff(a: &[u8], b: &[u8]) -> Option<usize> {
    let n = a.len().min(b.len());
    match (0..n).find(|&i| a[i] != b[i]) {
        Some(i) => Some(i),
        None if a.len() != b.len() => Some(n),
        None => None,
    }
}

/// A 16-byte hex window around `offset` (8 before, 8 after, clipped).
fn hex_window(data: &[u8], offset: usize) -> String {
    let start = offset.saturating_sub(8);
    let end = (offset + 8).min(data.len());
    if start >= end {
        return format!("(empty — artifact ends at byte {})", data.len());
    }
    let body: Vec<String> = data[start..end]
        .iter()
        .enumerate()
        .map(|(i, b)| {
            if start + i == offset {
                format!("[{b:02x}]")
            } else {
                format!("{b:02x}")
            }
        })
        .collect();
    format!("bytes {start}..{end}: {}", body.join(" "))
}

/// Compare one replay against the baseline; `None` ⇒ byte-identical.
fn compare_runs(base: &[Artifact], cur: &[Artifact], run: usize) -> Option<Divergence> {
    // A differing artifact *set* is itself a divergence (e.g. a task that
    // wrote no trace in one run).
    let base_names: Vec<&str> = base.iter().map(|a| a.name.as_str()).collect();
    let cur_names: Vec<&str> = cur.iter().map(|a| a.name.as_str()).collect();
    if base_names != cur_names {
        let missing = base_names
            .iter()
            .find(|n| !cur_names.contains(n))
            .or_else(|| cur_names.iter().find(|n| !base_names.contains(n)))
            .copied()
            .unwrap_or("?");
        return Some(Divergence {
            artifact: missing.to_string(),
            run,
            offset: 0,
            baseline_hex: format!("artifact set: {}", base_names.join(", ")),
            diverged_hex: format!("artifact set: {}", cur_names.join(", ")),
            hint: "an artifact exists in only one run — a task wrote (or skipped) \
                   a trace non-deterministically"
                .into(),
        });
    }
    for (a, b) in base.iter().zip(cur) {
        if let Some(offset) = first_diff(&a.bytes, &b.bytes) {
            return Some(Divergence {
                artifact: a.name.clone(),
                run,
                offset,
                baseline_hex: hex_window(&a.bytes, offset),
                diverged_hex: hex_window(&b.bytes, offset),
                hint: root_cause_hint(a, b, run),
            });
        }
    }
    None
}

/// For trace logs, decode both files and name the first event the runs
/// disagree on — the rank/replica/tick that first went off-script is the
/// natural place to start reading.
fn root_cause_hint(a: &Artifact, b: &Artifact, run: usize) -> String {
    if !a.name.ends_with(".trace") {
        return "the deterministic report differs — diff the two report.md \
                files in the kept run directories"
            .into();
    }
    let (base, other) = match (
        crate::obs::read_log(&a.path),
        crate::obs::read_log(&b.path),
    ) {
        (Ok((e0, _)), Ok((e1, _))) => (e0, e1),
        _ => {
            return "trace log undecodable at the divergence — the file is torn \
                    or the writer emitted a malformed record"
                .into()
        }
    };
    let n = base.len().min(other.len());
    for i in 0..n {
        let (x, y) = (&base[i], &other[i]);
        if (x.tick, x.rank, x.replica, x.kind, &x.detail)
            != (y.tick, y.rank, y.replica, y.kind, &y.detail)
        {
            return format!(
                "first divergent event is #{i}: run 0 has tick={} kind={} \
                 rank={} replica={} \"{}\"; run {run} has tick={} kind={} \
                 rank={} replica={} \"{}\"",
                x.tick,
                x.kind.label(),
                x.rank,
                x.replica,
                x.detail,
                y.tick,
                y.kind.label(),
                y.rank,
                y.replica,
                y.detail
            );
        }
    }
    if base.len() != other.len() {
        return format!(
            "runs agree on the first {n} event(s) but run 0 logged {} and \
             run {run} logged {} — one world did extra (or missing) work",
            base.len(),
            other.len()
        );
    }
    "events identical — the byte difference is in the span table or log \
     framing, not the event stream"
        .into()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_diff_localizes_exactly() {
        assert_eq!(first_diff(b"abcd", b"abcd"), None);
        assert_eq!(first_diff(b"abcd", b"abXd"), Some(2));
        assert_eq!(first_diff(b"abcd", b"ab"), Some(2), "strict prefix");
        assert_eq!(first_diff(b"", b""), None);
        assert_eq!(first_diff(b"", b"x"), Some(0));
    }

    #[test]
    fn hex_window_brackets_the_divergent_byte() {
        let data: Vec<u8> = (0..32).collect();
        let w = hex_window(&data, 16);
        assert_eq!(w, "bytes 8..24: 08 09 0a 0b 0c 0d 0e 0f [10] 11 12 13 14 15 16 17");
        // Clipped at both ends.
        assert!(hex_window(&data, 0).starts_with("bytes 0..8: [00]"));
        let tail = hex_window(&data, 31);
        assert!(tail.ends_with("[1f]"), "got: {tail}");
        // Offset at the prefix end of the shorter artifact.
        assert!(hex_window(&data[..4], 4).contains("bytes 0..4"));
        assert!(hex_window(&[], 0).contains("ends at byte 0"));
    }

    fn art(name: &str, bytes: &[u8]) -> Artifact {
        Artifact {
            name: name.into(),
            path: PathBuf::from("/nonexistent"),
            bytes: bytes.to_vec(),
        }
    }

    #[test]
    fn compare_runs_finds_byte_and_set_divergences() {
        let base = vec![art("report.md", b"hello"), art("task-0001.trace", b"abc")];
        let same = vec![art("report.md", b"hello"), art("task-0001.trace", b"abc")];
        assert!(compare_runs(&base, &same, 1).is_none());

        let bent = vec![art("report.md", b"heLlo"), art("task-0001.trace", b"abc")];
        let d = compare_runs(&base, &bent, 2).unwrap();
        assert_eq!(d.artifact, "report.md");
        assert_eq!(d.run, 2);
        assert_eq!(d.offset, 2);
        assert!(d.baseline_hex.contains("[6c]"), "got: {}", d.baseline_hex);
        assert!(d.diverged_hex.contains("[4c]"), "got: {}", d.diverged_hex);
        assert!(d.render().contains("at byte 2"), "got: {}", d.render());

        let short = vec![art("report.md", b"hello")];
        let d = compare_runs(&base, &short, 1).unwrap();
        assert_eq!(d.artifact, "task-0001.trace");
        assert!(d.hint.contains("only one run"), "got: {}", d.hint);
    }

    #[test]
    fn undecodable_trace_still_gets_a_hint() {
        // Paths don't exist, so read_log fails and the hint degrades
        // gracefully instead of erroring the whole comparison.
        let a = art("task-0001.trace", b"xy");
        let b = art("task-0001.trace", b"xz");
        let d = compare_runs(&[a], &[b], 1).unwrap();
        assert!(d.hint.contains("undecodable"), "got: {}", d.hint);
    }

    #[test]
    fn runs_below_two_are_refused() {
        let err = run_conform(&ConformOpts {
            runs: 1,
            seed: 1,
            filter: None,
            jobs: 1,
            work_dir: std::env::temp_dir().join("sedar-conform-refused"),
        })
        .unwrap_err();
        assert!(err.to_string().contains("at least 2"), "got: {err}");
    }

    #[test]
    fn one_cell_slice_conforms_across_two_runs() {
        let work_dir = std::env::temp_dir().join(format!(
            "sedar-conform-e2e-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&work_dir);
        let out = run_conform(&ConformOpts {
            runs: 2,
            seed: 42,
            filter: Some(
                "scenario=1,app=matmul,strategy=detect,collectives=p2p".into(),
            ),
            jobs: 1,
            work_dir: work_dir.clone(),
        })
        .unwrap();
        assert!(out.passed(), "diverged: {}", out.summary());
        assert_eq!(out.tasks, 1);
        assert_eq!(out.artifacts, 2, "report + one trace");
        assert!(
            !work_dir.exists(),
            "scratch tree must be removed on success"
        );
    }
}
