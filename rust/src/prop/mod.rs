//! A small property-based-testing framework (in-repo `proptest` substitute —
//! the offline crate set does not include proptest).
//!
//! Usage:
//!
//! ```no_run
//! use sedar::prop::{forall, Gen};
//! forall("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.i64_range(-1000, 1000);
//!     let b = g.i64_range(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! On failure the case number and seed are printed so the exact failing input
//! can be replayed with [`replay`].

use crate::util::prng::SplitMix64;

/// Per-case generator handed to property closures.
pub struct Gen {
    rng: SplitMix64,
    /// Size hint that grows with the case index, so early cases are small
    /// (fast, easy to debug) and later cases stress larger inputs.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self {
            rng: SplitMix64::new(seed),
            size,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.below((hi - lo) as u64) as i64
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// A small dimension in `[1, 1+size]` — handy for shapes.
    pub fn dim(&mut self) -> usize {
        self.usize_range(1, 2 + self.size)
    }

    /// Vector of signed-uniform f32s.
    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        self.rng.fill_f32(&mut v);
        v
    }

    /// Vector of random bytes.
    pub fn vec_u8(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| (self.rng.next_u64() & 0xff) as u8).collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range(0, xs.len())]
    }
}

/// Base seed: fixed so CI is deterministic; override with `SEDAR_PROP_SEED`.
fn base_seed() -> u64 {
    std::env::var("SEDAR_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EDA_2020)
}

/// Number of cases multiplier, override with `SEDAR_PROP_CASES_MULT`.
fn cases_mult() -> usize {
    std::env::var("SEDAR_PROP_CASES_MULT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Run `cases` random cases of `property`. Panics (with replay info) on the
/// first failing case.
pub fn forall<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut property: F) {
    let seed0 = base_seed();
    let cases = cases * cases_mult();
    for case in 0..cases {
        let case_seed = seed0
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let size = 1 + case * 32 / cases.max(1);
        let mut g = Gen::new(case_seed, size);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g);
        }));
        if let Err(payload) = result {
            eprintln!(
                "property '{name}' FAILED at case {case}/{cases} \
                 (replay: sedar::prop::replay({case_seed:#x}, {size}, ..))"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Re-run a single failing case from its printed seed and size.
pub fn replay<F: FnMut(&mut Gen)>(case_seed: u64, size: usize, mut property: F) {
    let mut g = Gen::new(case_seed, size);
    property(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("reflexive equality", 50, |g| {
            let x = g.u64();
            assert_eq!(x, x);
        });
    }

    #[test]
    fn forall_catches_violation() {
        let r = std::panic::catch_unwind(|| {
            forall("always fails eventually", 50, |g| {
                // Fails whenever the generated value is even — certain to
                // occur within 50 cases.
                assert!(g.u64() % 2 == 1);
            });
        });
        assert!(r.is_err());
    }

    #[test]
    fn gen_sizes_grow() {
        // size is monotone in case index by construction; sanity-check dims.
        let mut g = Gen::new(3, 16);
        for _ in 0..100 {
            let d = g.dim();
            assert!((1..=17).contains(&d));
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        replay(0xabcd, 4, |g| seen.push(g.u64()));
        let mut seen2 = Vec::new();
        replay(0xabcd, 4, |g| seen2.push(g.u64()));
        assert_eq!(seen, seen2);
    }
}
