//! `sedar` — the command-line launcher.
//!
//! ```text
//! sedar run      --app matmul|jacobi|sw --strategy baseline|detect|sysckpt|userckpt
//!                [--n 256] [--nranks 4] [--iters 32] [--scenario 50] [--xla]
//!                [--trace] [--trace-out FILE] [--seed 7]
//!                [--collectives p2p|native] [--run-dir DIR]
//!                [--netfault none|drop|dup|reorder|corrupt|mixed]
//! sedar campaign [--jobs 8] [--seed 42] [--filter app=matmul,strategy=sys,scenario=1-8]
//!                [--report md|csv] [--xla] [--run-dir DIR] [--quiet]
//!                [--shard i/N] [--wal shard.wal]
//!                [--status-port 8080] [--report-out report.md] [--trace-out DIR]
//! sedar trace    export FILE [--format chrome] [--out trace.json]
//! sedar fleet launch --shards N [--jobs J] [--seed S] [--filter …] [--dir D]
//!                [--max-restarts R] [--stall-secs T] [--poll-ms P]
//!                [--status-port P] [--report md|csv] [--report-out report.md]
//!                [--quiet]
//! sedar serve    [--port P] [--workers W] [--dir D] [--rate R] [--burst B]
//!                [--queue-cap Q] [--max-restarts R] [--stall-secs T]
//!                [--poll-ms P] [--addr-file F] [--quiet]
//! sedar merge    shard1.wal shard2.wal … [--report md|csv] [--report-out report.md]
//!                [--allow-partial]
//! sedar conform  --runs N [--seed S] [--filter …] [--jobs J] [--dir D]
//! sedar catalog                                           # print Table 2 (all 64 rows)
//! sedar model    [--table 4|5] [--thresholds] [--aet]     # the analytical model
//! sedar bench    [--json] [--out FILE] [--quick] [--no-campaign] [--jobs N]
//! sedar help
//! ```

use std::sync::Arc;

use sedar::apps::{AppSpec, JacobiApp, MatmulApp, SwApp};
use sedar::campaign::{CampaignReport, CampaignSpec};
use sedar::cli::Args;
use sedar::fleet::{self, plan::ShardPlan, FleetOptions};
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::error::{Result, SedarError};
use sedar::model::params::PaperApp;
use sedar::model::tables;
use sedar::report::Table;
use sedar::workfault;

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("sedar: error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("run") => cmd_run(args),
        Some("campaign") => cmd_campaign(args),
        Some("fleet") => cmd_fleet(args),
        Some("serve") => cmd_serve(args),
        Some("merge") => cmd_merge(args),
        Some("conform") => cmd_conform(args),
        Some("trace") => cmd_trace(args),
        Some("catalog") => cmd_catalog(),
        Some("model") => cmd_model(args),
        Some("bench") => cmd_bench(args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => Err(SedarError::Config(format!(
            "unknown command '{other}' (try 'sedar help')"
        ))),
    }
}

const HELP: &str = "\
sedar — soft-error detection and automatic recovery (SEDAR, FGCS 2020)

commands:
  run       run an application under a protection strategy (optionally
            injecting one of the 64 workfault scenarios)
  campaign  run the parallel injection campaign: the 64-scenario workfault
            × {matmul, jacobi, sw} × {detect-only, sys-ckpt, user-ckpt}
            × {p2p, native} collectives = 1152 worlds, fanned over a worker
            pool, graded against the §4.1/§4.2 oracle (native collectives
            get their own prediction columns: root-FSC rows flip to TDC at
            the collective); optionally as one shard of a multi-process
            fleet
  fleet     drive a whole multi-process fleet with one command:
            `fleet launch` spawns N shard processes, monitors their status
            endpoints and exit codes, relaunches any shard that dies or
            stalls (WAL replay skips finished tasks), streams every shard's
            WAL into a live partial aggregate as tasks land, and renders the
            final report from that same stream
  serve     run the campaign-as-a-service gateway: a long-lived daemon
            accepting sweep submissions over HTTP (POST /submit with
            user/seed/shards/jobs/filter lines), multiplexing every
            submission's shards onto one pooled worker budget with
            per-client rate limits and queue caps, journaling each
            accepted sweep so a killed daemon restarted over the same
            --dir resumes every in-flight sweep — each merged report is
            byte-identical to the standalone `sedar campaign` run
  merge     combine shard WALs written by `campaign --shard i/N --wal F`
            into the full sweep's report (byte-identical to a single-process
            run with the same --seed); live or partial WALs union with
            --allow-partial
  conform   replay the same campaign slice N times and byte-compare every
            deterministic artifact (report + per-task trace logs); on the
            first mismatch, localize it — artifact, byte offset, 16-byte
            hex context from both runs, and the first divergent decoded
            event (tick/kind/rank/replica)
  trace     work with typed event logs written by `--trace-out`:
            `trace export FILE --format chrome` emits Chrome trace-event
            JSON (load it at ui.perfetto.dev or chrome://tracing; 1 tick =
            1 ns of modeled time)
  catalog   print the full scenario catalog (the paper's Table 2)
  model     evaluate the analytical temporal model (Tables 4/5, thresholds,
            AET-vs-MTBE sweeps)
  bench     measure the hot paths (message validation, vmpi transport,
            checkpoint frames, end-to-end campaign) and emit the
            machine-readable perf trajectory
  help      this text

campaign flags:
  --jobs N      worker threads (default: available cores, capped at 8)
  --seed S      campaign master seed; every task seed derives from it as
                hash(seed, scenario, app, strategy, collectives,
                validation, faults) — same seed ⇒ byte-identical report,
                whatever --jobs or --shard split is used (default 42)
  --filter F    comma-separated cell filter, e.g.
                app=matmul,strategy=sys,scenario=1-8 (repeat keys to widen);
                collectives=p2p|native narrows the §4.2 axis (default:
                both); beyond-paper axes: validation=full|sha256,
                faults=1..4, netfault=none|drop|dup|reorder|corrupt|mixed
                (deterministic network perturbation of the vmpi transport;
                graded against the fail-safe oracle: corrupt ⇒ TDC, drop ⇒
                TOE, dup/reorder ⇒ absorbed byte-identically or detected)
  --scenario K  shorthand for --filter scenario=K
  --clock M     wall | virtual (default: virtual). Virtual runs the sweep
                on per-world logical clocks: TOE lapses and injected delays
                resolve in modeled ticks the instant a world quiesces, so
                timeout-heavy cells cost no wall time and verdicts are
                independent of host load. The report is byte-identical in
                both modes. (`sedar run` takes --clock too; there the
                default is wall.)
  --report FMT  md (default) or csv
  --xla         compute through the AOT artifacts (needs the pjrt feature)
  --run-dir D   campaign working directory (default runs/campaign-<pid>)
  --quiet       suppress per-task progress lines
  --trace-out D write every task's typed event log to D/task-NNNN.trace
                (export one with `sedar trace export`)

trace flags:
  --format F    export format: chrome (Chrome trace-event JSON; default)
  --out FILE    write the export to FILE instead of stdout

fleet flags (sharded / resumable / observable sweeps):
  --shard i/N      run only member i of an N-way deterministic split
                   (1-based; round-robin over canonical task indices)
  --wal FILE       the shard's write-ahead log — its ONE durable file:
                   every finished task is appended (and synced) as it
                   lands, compaction snapshots ride in the same stream, a
                   re-run over the same WAL resumes by replay (skipping
                   every finished task), and `sedar merge` combines the N
                   WALs into the full report
  --status-port P  serve live progress on http://127.0.0.1:P/ (text) and
                   /json while the sweep runs (0 = OS-assigned)
  --status-addr-file F  atomically write the endpoint's actual address to F
                   once it binds (implies --status-port 0 if no port was
                   given) — how `fleet launch` discovers its children
  --report-out F   also write the deterministic report to F (handy for
                   byte-diffing sharded vs single-process runs)

fleet launch flags (one-command self-healing fleets):
  --shards N       spawn N `campaign --shard i/N` child processes, each
                   with a WAL and status endpoint under the run directory
                   (default 2)
  --jobs J         worker threads per shard (default: the machine's
                   default budget split evenly across shards)
  --seed S / --filter F / --scenario K   as for campaign (forwarded)
  --dir D          run directory for WALs, logs, pid and addr files
                   (default runs/fleet-<pid>)
  --max-restarts R relaunch budget per shard; a shard that dies or stalls
                   is relaunched (replaying its WAL) at most R times
                   before the launch fails (default 3)
  --stall-secs T   no status heartbeat advance for T seconds counts as a
                   stall -> kill + relaunch; must exceed the slowest
                   single task (default 300)
  --poll-ms P      supervisor poll cadence (default 200)
  --status-port P  serve the fleet-wide live partial aggregate (the union
                   of every shard's WAL so far) on http://127.0.0.1:P/
                   (text), /json and /metrics (0 = OS-assigned)
  --status-addr-file F  atomically write that endpoint's address to F
                   once it binds (implies --status-port 0)
  --report FMT / --report-out F          as for campaign (merged report)
  --quiet          suppress the live aggregate progress line

serve flags (campaign as a service):
  --port P         listen on 127.0.0.1:P (default 0 = OS-assigned; pair
                   with --addr-file to discover the bound address)
  --workers W      pooled budget of concurrent shard processes across ALL
                   sweeps (default 4); free slots go to active sweeps
                   round-robin, one shard at a time (fair-share, not FIFO)
  --dir D          service directory: the submission manifest plus one
                   sweep directory (WALs, logs, report.md) per submission
                   (default runs/serve-<pid>); restarting over the same
                   directory kills orphaned shards, re-adopts every
                   journaled sweep and resumes it by WAL replay
  --rate R         token-bucket refill per client, submissions/second
                   (default 5)
  --burst B        token-bucket burst capacity per client (default 10)
  --queue-cap Q    max queued+running sweeps per user (default 8)
  --max-restarts R / --stall-secs T      per-shard supervision, as for
                   fleet launch
  --poll-ms P      scheduler cadence (default 50)
  --addr-file F    atomically write the bound address to F (the same
                   handshake fleet shards use)
  --quiet          suppress per-request error chatter
  routes: POST /submit (body: key=value lines — user, seed, shards, jobs,
          filter, scenario), GET /sweeps, GET /sweep/ID/json,
          GET /sweep/ID/report (the merged report, 404 until merged),
          GET /metrics (Prometheus), GET /

merge flags:
  --report FMT     md (default) or csv
  --report-out F   also write the deterministic report to F
  --allow-partial  render even if the shards do not cover the whole sweep

conform flags (N-run determinism harness):
  --runs N         identical executions to compare (default 2; min 2)
  --seed S / --filter F / --jobs J       as for campaign
  --dir D          scratch root for the per-run trees (default
                   runs/conform-<pid>; removed on success, kept on
                   divergence so the artifacts can be diffed)

bench flags:
  --json           emit the sedar-bench/1 JSON document on stdout (tables
                   are suppressed; progress goes to stderr)
  --out FILE       write the JSON document to FILE instead of stdout
                   (how the committed BENCH_pr<N>.json files are produced)
  --quick          CI-scale sizes/iterations (also: SEDAR_BENCH_QUICK=1)
  --no-campaign    skip the end-to-end campaign section (the slow one)
  --jobs N         campaign worker threads (default: as for campaign)
  --seed S         campaign master seed (default 42)

run `sedar <cmd>` flag semantics are documented in rust/src/main.rs.
";

fn build_app(args: &Args) -> Result<Arc<dyn AppSpec>> {
    let nranks = args.usize_or("nranks", 4)?;
    match args.get_or("app", "matmul") {
        "matmul" => {
            let n = args.usize_or("n", 256)?;
            Ok(Arc::new(MatmulApp::new(n, nranks)))
        }
        "jacobi" => {
            let n = args.usize_or("n", 256)?;
            let iters = args.usize_or("iters", 32)?;
            let every = args.usize_or("ckpt-every", 8)?;
            Ok(Arc::new(JacobiApp::new(n, nranks, iters, every)))
        }
        "sw" => {
            let m = args.usize_or("n", 512)?;
            let block = args.usize_or("block", m / 8)?;
            let every = args.usize_or("ckpt-every", 2)?;
            Ok(Arc::new(SwApp::new(m, nranks, block, every)))
        }
        other => Err(SedarError::Config(format!("unknown app '{other}'"))),
    }
}

fn build_cfg(args: &Args) -> Result<RunConfig> {
    // `--config FILE` loads a key=value config first; CLI flags override.
    let base = match args.get("config") {
        Some(path) => RunConfig::from_kv(&std::fs::read_to_string(path)?)?,
        None => RunConfig::default(),
    };
    let mut cfg = RunConfig {
        strategy: match args.get("strategy") {
            Some(s) => Strategy::parse(s)?,
            None => base.strategy,
        },
        ..base
    };
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.use_xla = args.has("xla");
    cfg.echo_trace = args.has("trace");
    if let Some(c) = args.get("collectives") {
        cfg.set("collectives", c)?;
    }
    if let Some(d) = args.get("run-dir") {
        cfg.run_dir = d.into();
    } else {
        cfg.run_dir =
            format!("runs/{}-{}", args.get_or("app", "matmul"), std::process::id()).into();
    }
    if let Some(ms) = args.get("toe-timeout-ms") {
        cfg.set("toe_timeout_ms", ms)?;
    }
    if let Some(c) = args.get("clock") {
        cfg.set("clock", c)?;
    }
    if let Some(m) = args.get("netfault") {
        cfg.set("netfault", m)?;
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let app = build_app(args)?;
    let cfg = build_cfg(args)?;
    let injection = match args.get("scenario") {
        None => None,
        Some(k) => {
            let id: u32 = k
                .parse()
                .map_err(|e| SedarError::Config(format!("--scenario: {e}")))?;
            // Scenarios are defined over the matmul test app (§4.1).
            let m = MatmulApp::new(args.usize_or("n", 256)?, args.usize_or("nranks", 4)?);
            let cat = workfault::catalog(&m);
            let sc = cat
                .iter()
                .find(|s| s.id == id)
                .ok_or_else(|| SedarError::Config(format!("no scenario {id}")))?;
            println!("injecting: {}", sc.row());
            Some(workfault::injection_for(&m, sc, &cfg))
        }
    };
    let run = SedarRun::new(app, cfg, injection);
    let outcome = run.run()?;
    println!("{}", outcome.summary());
    println!("\n-- metrics --\n{}", outcome.metrics.markdown());
    if args.has("trace") {
        println!("\n-- trace --\n{}", outcome.trace_dump);
    }
    if let Some(path) = args.get("trace-out") {
        sedar::obs::write_log(std::path::Path::new(path), &outcome.events, &outcome.spans)?;
        println!(
            "trace log: {path} ({} event(s), {} span(s))",
            outcome.events.len(),
            outcome.spans.len()
        );
    }
    if outcome.result_correct == Some(false) {
        return Err(SedarError::Config("final result WRONG".into()));
    }
    Ok(())
}

fn cmd_campaign(args: &Args) -> Result<()> {
    // Validate the output format and fleet wiring up front: a typo must
    // not cost a full sweep's worth of work.
    let report_fmt = args.get_or("report", "md");
    if !matches!(report_fmt, "md" | "csv") {
        return Err(SedarError::Config(format!(
            "unknown report '{report_fmt}' (md|csv)"
        )));
    }
    // The retired two-file flags fail fast with a pointer at the WAL: an
    // operator's muscle memory (or an old script) must get a migration
    // hint, not a silently ignored flag.
    if args.get("out").is_some() {
        return Err(SedarError::Config(
            "campaign: --out is gone — the SDWL write-ahead log replaced the \
             journal+artifact pair; pass --wal FILE (one durable file per \
             shard, merged with `sedar merge`)"
                .into(),
        ));
    }
    if args.get("journal").is_some() {
        return Err(SedarError::Config(
            "campaign: --journal is gone — the SDWL write-ahead log replaced \
             the journal+artifact pair; pass --wal FILE (resume works the \
             same: re-run with the same --wal and finished tasks are \
             replayed, not re-executed)"
                .into(),
        ));
    }
    let opts = FleetOptions {
        plan: args.get("shard").map(ShardPlan::parse).transpose()?,
        wal_path: args.get("wal").map(Into::into),
        status_port: match args.get("status-port") {
            // `--status-addr-file` without an explicit port implies an
            // OS-assigned one (the supervisor's handshake needs nothing
            // more).
            None => args.get("status-addr-file").map(|_| 0),
            Some(p) => Some(
                p.parse()
                    .map_err(|e| SedarError::Config(format!("--status-port: {e}")))?,
            ),
        },
        status_addr_file: args.get("status-addr-file").map(Into::into),
    };

    let mut spec = CampaignSpec::new(args.u64_or("seed", 42)?);
    spec.jobs = args.usize_or("jobs", CampaignSpec::default_jobs())?;
    if let Some(f) = args.get("filter") {
        spec.apply_filter(f)?;
    }
    if let Some(k) = args.get("scenario") {
        spec.apply_filter(&format!("scenario={k}"))?;
    }
    spec.base.use_xla = args.has("xla");
    // Campaigns default to the virtual clock (set in `CampaignSpec::new`);
    // `--clock wall` restores the physical clock for comparison runs. The
    // deterministic report is byte-identical either way.
    if let Some(c) = args.get("clock") {
        spec.base.set("clock", c)?;
    }
    spec.base.run_dir = match args.get("run-dir") {
        Some(d) => d.into(),
        None => format!("runs/campaign-{}", std::process::id()).into(),
    };
    spec.echo = !args.has("quiet");
    spec.trace_out = args.get("trace-out").map(Into::into);

    let sharded = opts.plan.map(|p| p.count > 1).unwrap_or(false);
    let run = fleet::run_shard(&spec, &opts)?;
    if sharded || run.resumed > 0 {
        eprintln!("{}", run.summary_line());
    }
    let report = CampaignReport::new(spec.seed, run.outcomes);
    emit_report(args, report_fmt, &report)?;
    println!("\n{}", report.summary_line());
    if let Some(path) = &run.wal_path {
        println!("shard WAL: {}", path.display());
    }
    let _ = std::fs::remove_dir_all(&spec.base.run_dir);
    if !report.verdict() {
        return Err(SedarError::Config(format!(
            "{} campaign task(s) diverged from the oracle",
            report.failed()
        )));
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("launch") => cmd_fleet_launch(args),
        Some(other) => Err(SedarError::Config(format!(
            "unknown fleet subcommand '{other}' (try 'sedar fleet launch --shards 2')"
        ))),
        None => Err(SedarError::Config(
            "usage: sedar fleet launch --shards N [--jobs J --seed S --filter … --dir D]".into(),
        )),
    }
}

fn cmd_fleet_launch(args: &Args) -> Result<()> {
    let report_fmt = args.get_or("report", "md");
    if !matches!(report_fmt, "md" | "csv") {
        return Err(SedarError::Config(format!(
            "unknown report '{report_fmt}' (md|csv)"
        )));
    }
    let opts = sedar::fleet::launch::LaunchOptions {
        shards: args.usize_or("shards", 2)?,
        jobs: args.usize_or("jobs", 0)?,
        seed: args.u64_or("seed", 42)?,
        filter: args.get("filter").map(String::from),
        scenario: args.get("scenario").map(String::from),
        dir: match args.get("dir") {
            Some(d) => d.into(),
            None => format!("runs/fleet-{}", std::process::id()).into(),
        },
        max_restarts: args.usize_or("max-restarts", 3)?,
        stall_timeout: std::time::Duration::from_secs(args.u64_or("stall-secs", 300)?),
        poll_interval: std::time::Duration::from_millis(args.u64_or("poll-ms", 200)?.max(10)),
        bin: None,
        quiet: args.has("quiet"),
        status_port: match args.get("status-port") {
            // As for campaign: an addr file without an explicit port
            // implies an OS-assigned one.
            None => args.get("status-addr-file").map(|_| 0),
            Some(p) => Some(
                p.parse()
                    .map_err(|e| SedarError::Config(format!("--status-port: {e}")))?,
            ),
        },
        status_addr_file: args.get("status-addr-file").map(Into::into),
    };
    let launch = sedar::fleet::launch::run_launch(&opts)?;
    emit_report(args, report_fmt, &launch.report)?;
    println!("\n{}", launch.report.summary_line());
    println!("{}", launch.summary());
    if !launch.report.verdict() {
        return Err(SedarError::Config(format!(
            "{} campaign task(s) diverged from the oracle",
            launch.report.failed()
        )));
    }
    Ok(())
}

/// `sedar serve`: the campaign-as-a-service gateway. Runs until killed.
fn cmd_serve(args: &Args) -> Result<()> {
    let port = args.u64_or("port", 0)?;
    if port > u16::MAX as u64 {
        return Err(SedarError::Config(format!(
            "serve: --port {port} out of range"
        )));
    }
    let workers = args.usize_or("workers", 4)?;
    if workers == 0 {
        return Err(SedarError::Config(
            "serve: --workers must be >= 1 (the pooled shard budget)".into(),
        ));
    }
    let opts = sedar::serve::ServeOptions {
        port: port as u16,
        workers,
        dir: match args.get("dir") {
            Some(d) => d.into(),
            None => format!("runs/serve-{}", std::process::id()).into(),
        },
        poll_interval: std::time::Duration::from_millis(args.u64_or("poll-ms", 50)?.max(10)),
        stall_timeout: std::time::Duration::from_secs(args.u64_or("stall-secs", 300)?),
        max_restarts: args.usize_or("max-restarts", 3)?,
        rate: args.f64_or("rate", 5.0)?,
        burst: args.f64_or("burst", 10.0)?,
        queue_cap: args.usize_or("queue-cap", 8)?,
        addr_file: args.get("addr-file").map(Into::into),
        bin: None,
        quiet: args.has("quiet"),
    };
    sedar::serve::run_serve(&opts)
}

/// Print the report in the chosen format and honor `--report-out` (the
/// deterministic markdown report, byte-diffable across shardings).
fn emit_report(args: &Args, report_fmt: &str, report: &CampaignReport) -> Result<()> {
    if let Some(path) = args.get("report-out") {
        std::fs::write(path, report.deterministic_report())?;
    }
    match report_fmt {
        "csv" => print!("{}", report.csv()),
        _ => println!("{}", report.deterministic_report()),
    }
    Ok(())
}

fn cmd_merge(args: &Args) -> Result<()> {
    let report_fmt = args.get_or("report", "md");
    if !matches!(report_fmt, "md" | "csv") {
        return Err(SedarError::Config(format!(
            "unknown report '{report_fmt}' (md|csv)"
        )));
    }
    let paths: Vec<&str> = args.positional.iter().map(|s| s.as_str()).collect();
    if paths.is_empty() {
        return Err(SedarError::Config(
            "merge: name at least one shard WAL (sedar merge s1.wal s2.wal …)".into(),
        ));
    }
    // One read path for everything: the same lenient WAL replay a resuming
    // shard uses, so merging the WAL of a still-running shard is safe (its
    // torn tail is simply not part of the union yet).
    let mut shards = Vec::with_capacity(paths.len());
    for path in &paths {
        shards.push(sedar::fleet::snapshot::read_wal(std::path::Path::new(path))?);
    }
    let (seed, total_tasks, outcomes) = sedar::fleet::snapshot::merge_wals(shards)?;
    if (outcomes.len() as u64) < total_tasks && !args.has("allow-partial") {
        return Err(SedarError::Config(format!(
            "merge: shards cover {} of {} task(s) — some shard WALs are \
             missing or still being written (pass --allow-partial to render \
             the union anyway)",
            outcomes.len(),
            total_tasks
        )));
    }
    let report = CampaignReport::new(seed, outcomes);
    emit_report(args, report_fmt, &report)?;
    println!("\n{}", report.summary_line());
    if !report.verdict() {
        return Err(SedarError::Config(format!(
            "{} campaign task(s) diverged from the oracle",
            report.failed()
        )));
    }
    Ok(())
}

/// `sedar conform --runs N [--seed S --filter F --jobs J --dir D]`: the
/// N-run determinism harness — replay one slice repeatedly, byte-compare
/// the artifacts, localize the first divergence.
fn cmd_conform(args: &Args) -> Result<()> {
    let opts = sedar::conform::ConformOpts {
        runs: args.usize_or("runs", 2)?,
        seed: args.u64_or("seed", 42)?,
        filter: args.get("filter").map(String::from),
        jobs: args.usize_or("jobs", CampaignSpec::default_jobs())?,
        work_dir: match args.get("dir") {
            Some(d) => d.into(),
            None => format!("runs/conform-{}", std::process::id()).into(),
        },
    };
    let out = sedar::conform::run_conform(&opts)?;
    println!("{}", out.summary());
    if !out.passed() {
        println!("run trees kept under {}", opts.work_dir.display());
        return Err(SedarError::Config(
            "conformance failed: runs are not byte-identical".into(),
        ));
    }
    Ok(())
}

/// `sedar trace export FILE [--format chrome] [--out F]`: decode a typed
/// event log written by `--trace-out` and emit it in a viewer format.
fn cmd_trace(args: &Args) -> Result<()> {
    if args.positional.first().map(|s| s.as_str()) != Some("export") {
        return Err(SedarError::Config(
            "usage: sedar trace export FILE [--format chrome] [--out trace.json]".into(),
        ));
    }
    let path = args.positional.get(1).ok_or_else(|| {
        SedarError::Config("trace export: name a trace log written by --trace-out".into())
    })?;
    let fmt = args.get_or("format", "chrome");
    if fmt != "chrome" {
        return Err(SedarError::Config(format!(
            "unknown trace format '{fmt}' (chrome)"
        )));
    }
    let (events, spans) = sedar::obs::read_log(std::path::Path::new(path))?;
    let json = sedar::obs::chrome_json(&events, &spans);
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, &json)?;
            eprintln!(
                "trace: {} event(s), {} span(s) → {out}",
                events.len(),
                spans.len()
            );
        }
        None => print!("{json}"),
    }
    Ok(())
}

fn cmd_catalog() -> Result<()> {
    let app = MatmulApp::new(64, 4);
    println!("{}", workfault::table2_header());
    for sc in workfault::catalog(&app) {
        println!("{}", sc.row());
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let json = args.has("json") || args.get("out").is_some();
    let opts = sedar::bench::BenchOpts {
        quick: args.has("quick") || sedar::report::benchkit::quick(),
        campaign: !args.has("no-campaign"),
        jobs: args.usize_or("jobs", CampaignSpec::default_jobs())?,
        seed: args.u64_or("seed", 42)?,
        // Human tables share stdout with the JSON document; suppress them
        // when JSON goes there so the output stays parseable.
        echo: !json || args.get("out").is_some(),
    };
    let report = sedar::bench::run_suite(&opts)?;
    if json {
        let doc = report.render();
        match args.get("out") {
            Some(path) => {
                std::fs::write(path, &doc)?;
                eprintln!("bench: wrote {path}");
            }
            None => print!("{doc}"),
        }
    }
    Ok(())
}

fn cmd_model(args: &Args) -> Result<()> {
    let cols: Vec<(&str, sedar::model::Params)> = PaperApp::ALL
        .iter()
        .map(|a| (a.label(), a.paper_params()))
        .collect();
    match args.get_or("table", "4") {
        "4" => print!("{}", tables::table4_markdown(&cols)),
        "5" => {
            let p = PaperApp::Jacobi.paper_params();
            let t = tables::table5(&p, &[0.3, 0.5, 0.8], 4);
            print!("{}", tables::table5_markdown(&t));
        }
        other => return Err(SedarError::Config(format!("unknown table '{other}'"))),
    }
    if args.has("thresholds") {
        let p = PaperApp::Jacobi.paper_params();
        println!("\n§4.4 crossovers (Jacobi parameters):");
        for k in 0..=2u32 {
            println!(
                "  X*(k={k}) = {:.2}%  (rolling back k={k} beats stop-and-relaunch beyond this)",
                tables::threshold_x(&p, k) * 100.0
            );
        }
    }
    if args.has("aet") {
        let mut t = Table::new(&["MTBE [h]", "baseline", "detect", "sys-ckpt", "user-ckpt"]);
        let p = PaperApp::Jacobi.paper_params();
        use sedar::model::equations::*;
        for mtbe_h in [2.0, 5.0, 10.0, 20.0, 50.0, 100.0] {
            let mtbe = mtbe_h * 3600.0;
            let row = [
                sedar::model::aet(eq1_baseline_fa(&p), eq2_baseline_fp(&p), p.t_prog, mtbe),
                sedar::model::aet(eq3_detect_fa(&p), eq4_detect_fp(&p, 0.5), p.t_prog, mtbe),
                sedar::model::aet(eq5_sys_fa(&p), eq6_sys_fp(&p, 0), p.t_prog, mtbe),
                sedar::model::aet(eq7_user_fa(&p), eq8_user_fp(&p), p.t_prog, mtbe),
            ];
            t.row(&[
                format!("{mtbe_h}"),
                format!("{:.2}", row[0] / 3600.0),
                format!("{:.2}", row[1] / 3600.0),
                format!("{:.2}", row[2] / 3600.0),
                format!("{:.2}", row[3] / 3600.0),
            ]);
        }
        println!("\nAET vs MTBE (hours, Jacobi parameters):\n{}", t.markdown());
    }
    Ok(())
}
