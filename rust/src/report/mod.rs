//! Small report-building helpers shared by the CLI, examples and benches:
//! aligned markdown tables, CSV emission, and the bench-timing kit.

pub mod benchkit;

/// Incremental builder for an aligned markdown table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as aligned markdown.
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let mut s = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, width) in cells.iter().zip(w) {
                line.push_str(&format!(" {c:<width$} |"));
            }
            line
        };
        s.push_str(&fmt_row(&self.header, &w));
        s.push('\n');
        s.push('|');
        for width in &w {
            s.push_str(&format!("{}|", "-".repeat(width + 2)));
        }
        s.push('\n');
        for row in &self.rows {
            s.push_str(&fmt_row(row, &w));
            s.push('\n');
        }
        s
    }

    /// Render as CSV (no quoting — callers keep cells comma-free).
    pub fn csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control bytes) for
/// the hand-rolled JSON emitters (no serde in the offline dependency set);
/// used by the fleet status endpoint.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format seconds as `H.HH` hours (paper table style).
pub fn fmt_hours(seconds: f64) -> String {
    format!("{:.2}", seconds / 3600.0)
}

/// Format a ratio as a percentage with sensible precision.
pub fn fmt_pct(x: f64) -> String {
    if x.abs() < 0.001 {
        format!("{:.3}%", x * 100.0)
    } else {
        format!("{:.2}%", x * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new(&["name", "v"]);
        t.row_strs(&["a", "1"]).row_strs(&["long-name", "22"]);
        let md = t.markdown();
        assert!(md.contains("| name      | v  |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["1", "2"]);
        assert_eq!(t.csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_hours(36756.0), "10.21");
        assert_eq!(fmt_pct(0.006), "0.60%");
        assert_eq!(fmt_pct(0.0001), "0.010%");
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("line\nbreak\ttab"), "line\\nbreak\\ttab");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
        // Non-ASCII passes through untouched (JSON is UTF-8).
        assert_eq!(json_escape("héllo"), "héllo");
    }
}
