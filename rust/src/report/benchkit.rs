//! Minimal benchmarking kit (in-repo criterion substitute — the offline
//! crate set has no criterion). Used by the `harness = false` targets in
//! `rust/benches/`.
//!
//! Method: `warmup` untimed iterations, then `iters` timed ones; reports
//! min / mean / p50 / p95. Deliberately simple — the experiment benches
//! measure *seconds-scale end-to-end runs* where statistical machinery
//! adds nothing, and the micro benches report throughput where min is the
//! meaningful roofline figure.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl Stats {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            self.iters.to_string(),
            crate::util::human_duration(self.min),
            crate::util::human_duration(self.mean),
            crate::util::human_duration(self.p50),
            crate::util::human_duration(self.p95),
        ]
    }

    pub fn header() -> &'static [&'static str] {
        &["case", "iters", "min", "mean", "p50", "p95"]
    }

    /// Throughput for `bytes` processed per iteration, based on `min`.
    pub fn gib_per_s(&self, bytes: usize) -> f64 {
        bytes as f64 / self.min.as_secs_f64() / (1024.0 * 1024.0 * 1024.0)
    }
}

/// Time `f` with warmup; returns stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let min = samples[0];
    let p50 = samples[iters / 2];
    let p95 = samples[(iters * 95 / 100).min(iters - 1)];
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    Stats {
        name: name.to_string(),
        iters,
        min,
        mean,
        p50,
        p95,
    }
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Quick-mode switch: `SEDAR_BENCH_QUICK=1` shrinks iteration counts so
/// `cargo bench` stays minutes-scale in CI.
pub fn quick() -> bool {
    std::env::var("SEDAR_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench("t", 1, 20, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.min <= s.p50);
        assert!(s.p50 <= s.p95);
        assert_eq!(s.iters, 20);
    }

    #[test]
    fn throughput_positive() {
        let s = bench("t", 0, 3, || {
            std::thread::sleep(Duration::from_micros(100));
        });
        assert!(s.gib_per_s(1024) > 0.0);
    }
}
