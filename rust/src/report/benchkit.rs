//! Minimal benchmarking kit (in-repo criterion substitute — the offline
//! crate set has no criterion). Used by the `harness = false` targets in
//! `rust/benches/` and by `sedar bench`.
//!
//! Method: `warmup` untimed iterations, then `iters` timed ones; reports
//! min / mean / p50 / p95. Deliberately simple — the experiment benches
//! measure *seconds-scale end-to-end runs* where statistical machinery
//! adds nothing, and the micro benches report throughput where min is the
//! meaningful roofline figure.
//!
//! [`JsonReport`] renders results as the machine-readable `sedar-bench/1`
//! document (the `BENCH_*.json` trajectory committed per perf PR, so later
//! PRs can diff hot-path numbers instead of guessing); schema documented in
//! the README's "Performance" section.

use std::time::{Duration, Instant};

use crate::report::json_escape;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl Stats {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            self.iters.to_string(),
            crate::util::human_duration(self.min),
            crate::util::human_duration(self.mean),
            crate::util::human_duration(self.p50),
            crate::util::human_duration(self.p95),
        ]
    }

    pub fn header() -> &'static [&'static str] {
        &["case", "iters", "min", "mean", "p50", "p95"]
    }

    /// Throughput for `bytes` processed per iteration, based on `min`.
    pub fn gib_per_s(&self, bytes: usize) -> f64 {
        bytes as f64 / self.min.as_secs_f64() / (1024.0 * 1024.0 * 1024.0)
    }

    /// One `sedar-bench/1` entry object. `group` buckets related cases;
    /// `bytes` (payload bytes per iteration) adds the derived `ns_per_mib`
    /// and `gib_per_s` throughput fields.
    pub fn json_obj(&self, group: &str, bytes: Option<usize>) -> String {
        let mut s = format!(
            "{{\"group\":\"{}\",\"case\":\"{}\",\"iters\":{},\
             \"min_ns\":{},\"mean_ns\":{},\"p50_ns\":{},\"p95_ns\":{}",
            json_escape(group),
            json_escape(&self.name),
            self.iters,
            self.min.as_nanos(),
            self.mean.as_nanos(),
            self.p50.as_nanos(),
            self.p95.as_nanos(),
        );
        if let Some(b) = bytes {
            s.push_str(&format!(",\"bytes\":{b}"));
            // Derived throughput only when both operands are non-zero: a
            // sub-clock-resolution min (0 ns) would otherwise format as
            // `inf`, which is not JSON.
            if b > 0 && self.min.as_nanos() > 0 {
                s.push_str(&format!(
                    ",\"ns_per_mib\":{:.1},\"gib_per_s\":{:.3}",
                    self.min.as_nanos() as f64 * (1024.0 * 1024.0) / b as f64,
                    self.gib_per_s(b)
                ));
            }
        }
        s.push('}');
        s
    }
}

/// Accumulates bench entries into one `sedar-bench/1` JSON document.
#[derive(Debug, Default)]
pub struct JsonReport {
    meta: Vec<(String, String)>,
    entries: Vec<String>,
}

impl JsonReport {
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Attach a top-level metadata field. `value_json` must already be
    /// valid JSON — quote strings with [`crate::report::json_escape`].
    pub fn meta(&mut self, key: &str, value_json: impl Into<String>) {
        self.meta.push((key.to_string(), value_json.into()));
    }

    /// Add one benchmark case.
    pub fn push_stats(&mut self, group: &str, s: &Stats, bytes: Option<usize>) {
        self.entries.push(s.json_obj(group, bytes));
    }

    /// Add a pre-rendered entry object (e.g. the campaign wall-time record,
    /// whose fields do not fit the Stats shape).
    pub fn push_raw(&mut self, json_obj: impl Into<String>) {
        self.entries.push(json_obj.into());
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Render the complete document.
    pub fn render(&self) -> String {
        let mut s = String::from("{\n  \"schema\": \"sedar-bench/1\"");
        for (k, v) in &self.meta {
            s.push_str(&format!(",\n  \"{}\": {}", json_escape(k), v));
        }
        s.push_str(",\n  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str("    ");
            s.push_str(e);
            if i + 1 < self.entries.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Print one section of `(Stats, payload bytes)` rows as an aligned
/// markdown table on stdout — the shared presenter behind `sedar bench`
/// and the `harness = false` bench targets.
pub fn print_table(title: &str, rows: &[(Stats, Option<usize>)]) {
    println!("\n=== {title} ===\n");
    let mut t = crate::report::Table::new(&[
        "case",
        "iters",
        "min",
        "mean",
        "p50",
        "p95",
        "throughput",
    ]);
    for (s, bytes) in rows {
        let mut row = s.row();
        row.push(match bytes {
            // Same sub-clock-resolution guard as Stats::json_obj: a 0 ns
            // min would print "inf GiB/s".
            Some(b) if *b > 0 && s.min.as_nanos() > 0 => {
                format!("{:.2} GiB/s", s.gib_per_s(*b))
            }
            _ => "-".to_string(),
        });
        t.row(&row);
    }
    print!("{}", t.markdown());
}

/// Time `f` with warmup; returns stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let min = samples[0];
    let p50 = samples[iters / 2];
    let p95 = samples[(iters * 95 / 100).min(iters - 1)];
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    Stats {
        name: name.to_string(),
        iters,
        min,
        mean,
        p50,
        p95,
    }
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Quick-mode switch: `SEDAR_BENCH_QUICK=1` shrinks iteration counts so
/// `cargo bench` stays minutes-scale in CI.
pub fn quick() -> bool {
    std::env::var("SEDAR_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench("t", 1, 20, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.min <= s.p50);
        assert!(s.p50 <= s.p95);
        assert_eq!(s.iters, 20);
    }

    #[test]
    fn json_report_shape() {
        let s = bench("token full 1 MiB", 0, 5, || {
            black_box((0..64).sum::<u64>());
        });
        let mut jr = JsonReport::new();
        jr.meta("pr", "3");
        jr.meta("quick", "true");
        jr.push_stats("msg_validation", &s, Some(1 << 20));
        jr.push_raw("{\"group\":\"campaign\",\"case\":\"e2e\",\"tasks\":576,\"wall_ms\":1}");
        let doc = jr.render();
        assert!(doc.starts_with("{\n  \"schema\": \"sedar-bench/1\""));
        assert!(doc.ends_with("  ]\n}\n"));
        assert!(doc.contains("\"pr\": 3"));
        assert!(doc.contains("\"group\":\"msg_validation\""));
        assert!(doc.contains("\"bytes\":1048576"));
        assert!(doc.contains("\"ns_per_mib\":"));
        assert!(doc.contains("\"tasks\":576"));
        // Balanced braces/brackets — the cheap well-formedness proxy the
        // offline dependency set allows (no JSON parser crate).
        let opens = doc.matches(['{', '[']).count();
        let closes = doc.matches(['}', ']']).count();
        assert_eq!(opens, closes);
        // Exactly one separating comma between the two entries.
        assert_eq!(doc.matches("},\n    {").count(), 1);
    }

    #[test]
    fn json_obj_without_bytes_has_no_throughput() {
        let s = bench("t", 0, 3, || {
            black_box(1 + 1);
        });
        let o = s.json_obj("g", None);
        assert!(!o.contains("gib_per_s"));
        assert!(o.contains("\"min_ns\":"));
    }

    #[test]
    fn throughput_positive() {
        let s = bench("t", 0, 3, || {
            std::thread::sleep(Duration::from_micros(100));
        });
        assert!(s.gib_per_s(1024) > 0.0);
    }
}
