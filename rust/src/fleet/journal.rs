//! The fleet's resume journal: the sweep validator checkpointing itself.
//!
//! SEDAR level 2 protects the *application* by journaling recoverable
//! state as it goes; the fleet applies the same idea to the *validation
//! campaign*. As each task of a shard completes, its [`TaskOutcome`] is
//! appended to an on-disk journal — length-prefixed and CRC-guarded per
//! record — so a killed shard re-run recovers every finished task, skips
//! re-executing it, and still renders the byte-identical report (outcomes
//! are pure functions of the task seed, so a journaled outcome *is* the
//! outcome a re-run would have produced).
//!
//! ```text
//! file   := header-record record*
//! record := len u32 | crc32(body) u32 | body
//! ```
//!
//! Record 0's body is a header binding the journal to one sweep — seed,
//! shard plan and filtered task total — so a stale journal from a different
//! seed or filter can never leak foreign outcomes into a report. A torn
//! tail record (the process died mid-append) is detected by its length/CRC
//! and dropped; everything before it is recovered.

use std::fs::OpenOptions;
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

use crate::campaign::shard::TaskOutcome;
use crate::error::{Result, SedarError};
use crate::util::codec::crc32;

use super::artifact::{decode_outcome, encode_outcome, ByteReader, ShardMeta};

const MAGIC: &[u8; 4] = b"SDJL";
/// Bumped to 2 with the collectives axis: the outcome record format gained
/// a per-record ordinal byte ([`encode_outcome`]), so a version-1 journal
/// is unreadable by construction and must be refused, never mis-decoded.
/// Bumped to 3 with the per-task observability counters (the trailing
/// [`crate::metrics::MetricsSnapshot`] of each outcome record).
/// Bumped to 4 with the netfault axis (a per-record ordinal byte after
/// the validation mode's), so a version-3 journal is refused by name
/// rather than mis-decoded.
const VERSION: u32 = 4;
/// Sanity cap on a single record body; real outcome records are ≪ this.
const MAX_RECORD: usize = 1 << 24;

/// An open, append-positioned journal.
pub struct Journal {
    file: std::fs::File,
}

/// `Some((body, end_offset))` if a whole, CRC-valid record starts at `pos`.
fn next_record(data: &[u8], pos: usize) -> Option<(&[u8], usize)> {
    if data.len() - pos < 8 {
        return None;
    }
    let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
    if len > MAX_RECORD || data.len() - pos - 8 < len {
        return None;
    }
    let body = &data[pos + 8..pos + 8 + len];
    if crc32(body) != crc {
        return None;
    }
    Some((body, pos + 8 + len))
}

fn header_body(meta: &ShardMeta) -> Vec<u8> {
    let mut b = Vec::with_capacity(48);
    b.extend_from_slice(MAGIC);
    b.extend_from_slice(&VERSION.to_le_bytes());
    b.extend_from_slice(&meta.seed.to_le_bytes());
    b.extend_from_slice(&meta.shard_index.to_le_bytes());
    b.extend_from_slice(&meta.shard_count.to_le_bytes());
    b.extend_from_slice(&meta.total_tasks.to_le_bytes());
    b.extend_from_slice(&meta.spec_hash.to_le_bytes());
    b
}

fn parse_header(body: &[u8]) -> Result<ShardMeta> {
    let mut r = ByteReader::new(body, "fleet journal header");
    if r.bytes(4)? != MAGIC {
        return Err(SedarError::Checkpoint(
            "not a fleet journal (bad header magic)".into(),
        ));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(SedarError::Checkpoint(format!(
            "unsupported fleet journal version {version} (this build reads \
             version {VERSION}) — delete the journal to re-run the shard"
        )));
    }
    Ok(ShardMeta {
        seed: r.u64()?,
        shard_index: r.u32()?,
        shard_count: r.u32()?,
        total_tasks: r.u64()?,
        spec_hash: r.u64()?,
    })
}

impl Journal {
    /// Open (creating if absent) the journal at `path` for `meta`'s sweep.
    ///
    /// Returns the append-positioned journal plus every outcome recovered
    /// from a previous run of the same shard. The valid prefix is kept; a
    /// torn tail record is truncated away. A journal whose header names a
    /// different sweep (other seed, plan or filter width) is an error — as
    /// is a non-empty file that is not a journal at all; this function
    /// never truncates a file it cannot positively identify as its own.
    pub fn open(path: &Path, meta: &ShardMeta) -> Result<(Journal, Vec<TaskOutcome>)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let existing = match std::fs::read(path) {
            Ok(data) => data,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };

        let mut recovered: Vec<TaskOutcome> = Vec::new();
        let mut valid_len = 0usize;
        if !existing.is_empty() {
            let Some((header, end)) = next_record(&existing, 0) else {
                return Err(SedarError::Checkpoint(format!(
                    "{}: unreadable journal header (torn or foreign file); \
                     delete it to start the shard from scratch",
                    path.display()
                )));
            };
            let found = parse_header(header)?;
            if found != *meta {
                let drift = if found.spec_hash != meta.spec_hash
                    && (found.seed, found.shard_index, found.shard_count, found.total_tasks)
                        == (meta.seed, meta.shard_index, meta.shard_count, meta.total_tasks)
                {
                    " — same seed and plan but a different --filter set"
                } else {
                    ""
                };
                return Err(SedarError::Checkpoint(format!(
                    "{}: journal belongs to a different sweep \
                     (journal seed {} shard {}/{} of {} tasks; \
                     this run is seed {} shard {}/{} of {} tasks){drift}",
                    path.display(),
                    found.seed,
                    found.shard_index + 1,
                    found.shard_count,
                    found.total_tasks,
                    meta.seed,
                    meta.shard_index + 1,
                    meta.shard_count,
                    meta.total_tasks
                )));
            }
            valid_len = end;
            let mut pos = end;
            while let Some((body, end)) = next_record(&existing, pos) {
                let mut r = ByteReader::new(body, "fleet journal");
                match decode_outcome(&mut r) {
                    Ok(o) if r.remaining() == 0 => recovered.push(o),
                    // A record that frames correctly but no longer decodes
                    // ends the valid prefix, like a torn tail.
                    _ => break,
                }
                valid_len = end;
                pos = end;
            }
            // Keep the first occurrence if a record was ever duplicated
            // (outcomes are deterministic, so duplicates are benign here;
            // the *merge* layer is where overlap is a hard error).
            let mut seen = std::collections::HashSet::new();
            recovered.retain(|o| seen.insert(o.index));
        }

        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(false)
            .open(path)?;
        file.set_len(valid_len as u64)?;
        let mut journal = Journal { file };
        journal.file.seek(SeekFrom::End(0))?;
        if valid_len == 0 {
            journal.write_record(&header_body(meta))?;
            // A fresh journal's directory entry must survive a crash too:
            // without this, a kill right after creation can lose the whole
            // file even though every record inside it was synced.
            super::sync_parent_dir(path)?;
        }
        Ok((journal, recovered))
    }

    /// Durably append one finished task (synced before returning, so a kill
    /// immediately after completion cannot lose the record).
    pub fn append(&mut self, outcome: &TaskOutcome) -> Result<()> {
        let mut body = Vec::with_capacity(128);
        encode_outcome(outcome, &mut body);
        self.write_record(&body)
    }

    fn write_record(&mut self, body: &[u8]) -> Result<()> {
        let mut rec = Vec::with_capacity(8 + body.len());
        rec.extend_from_slice(&(body.len() as u32).to_le_bytes());
        rec.extend_from_slice(&crc32(body).to_le_bytes());
        rec.extend_from_slice(body);
        self.file.write_all(&rec)?;
        self.file.sync_data()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::CampaignApp;
    use crate::config::Strategy;
    use crate::detect::ValidationMode;

    fn meta() -> ShardMeta {
        ShardMeta {
            seed: 42,
            shard_index: 0,
            shard_count: 2,
            total_tasks: 8,
            spec_hash: 0xF1E7,
        }
    }

    fn outcome(index: usize) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: index as u32,
            app: CampaignApp::Matmul,
            strategy: Strategy::SysCkpt,
            collectives: crate::config::CollectiveImpl::PointToPoint,
            validation: ValidationMode::Full,
            netfault: crate::faultnet::NetFaultMode::None,
            faults: 1,
            completed: true,
            restarts: 0,
            injected: true,
            correct: Some(true),
            first_detection: None,
            last_resume: None,
            pass: true,
            mismatches: vec![],
            wall: std::time::Duration::ZERO,
            metrics: crate::metrics::MetricsSnapshot {
                compare_bytes: 64,
                sync_events: 2,
                execs: 1,
                ..Default::default()
            },
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "sedar-journal-{tag}-{}-{:?}.bin",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn append_then_recover() {
        let p = tmp("roundtrip");
        let _ = std::fs::remove_file(&p);
        {
            let (mut j, recovered) = Journal::open(&p, &meta()).unwrap();
            assert!(recovered.is_empty());
            j.append(&outcome(0)).unwrap();
            j.append(&outcome(2)).unwrap();
        }
        let (_, recovered) = Journal::open(&p, &meta()).unwrap();
        let idx: Vec<usize> = recovered.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 2]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn torn_tail_is_dropped() {
        let p = tmp("torn");
        let _ = std::fs::remove_file(&p);
        {
            let (mut j, _) = Journal::open(&p, &meta()).unwrap();
            j.append(&outcome(0)).unwrap();
            j.append(&outcome(2)).unwrap();
        }
        // Simulate a crash mid-append: chop bytes off the last record.
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() - 5]).unwrap();
        let (mut j, recovered) = Journal::open(&p, &meta()).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].index, 0);
        // The journal must be appendable after truncation, and the new
        // record must land cleanly where the torn one was.
        j.append(&outcome(4)).unwrap();
        drop(j);
        let (_, recovered) = Journal::open(&p, &meta()).unwrap();
        let idx: Vec<usize> = recovered.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 4]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn created_journal_in_fresh_directory_reopens() {
        // Creation in a freshly made nested directory exercises the
        // create → header write → parent-directory fsync path; the reopen
        // proves the journal those steps left behind is well-formed.
        let dir = std::env::temp_dir().join(format!(
            "sedar-journal-dirsync-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("deep").join("sweep.journal");
        {
            let (mut j, recovered) = Journal::open(&p, &meta()).unwrap();
            assert!(recovered.is_empty());
            j.append(&outcome(0)).unwrap();
        }
        let (_, recovered) = Journal::open(&p, &meta()).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].index, 0);
        // The helper itself must tolerate a parentless (cwd-relative)
        // path — it syncs "." rather than erroring.
        crate::fleet::sync_parent_dir(std::path::Path::new("bare-name.journal")).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn foreign_sweep_rejected() {
        let p = tmp("foreign");
        let _ = std::fs::remove_file(&p);
        {
            let (mut j, _) = Journal::open(&p, &meta()).unwrap();
            j.append(&outcome(0)).unwrap();
        }
        let mut other = meta();
        other.seed = 43;
        assert!(Journal::open(&p, &other).is_err());
        let mut other = meta();
        other.shard_index = 1;
        assert!(Journal::open(&p, &other).is_err());
        // Same seed and plan but a different filter set (spec fingerprint).
        let mut other = meta();
        other.spec_hash = 0xDEAD;
        let err = Journal::open(&p, &other).unwrap_err();
        assert!(err.to_string().contains("--filter"), "got: {err}");
        // A non-journal file is refused, not truncated.
        std::fs::write(&p, b"definitely not a journal").unwrap();
        assert!(Journal::open(&p, &meta()).is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"definitely not a journal");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn v3_journal_is_refused_naming_both_versions() {
        // Hand-build a journal whose header claims version 3 (the
        // pre-netfault record format): the reader must refuse it
        // with an error naming both versions, and must NOT truncate it.
        let p = tmp("v3");
        let _ = std::fs::remove_file(&p);
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&3u32.to_le_bytes());
        body.extend_from_slice(&meta().seed.to_le_bytes());
        body.extend_from_slice(&meta().shard_index.to_le_bytes());
        body.extend_from_slice(&meta().shard_count.to_le_bytes());
        body.extend_from_slice(&meta().total_tasks.to_le_bytes());
        body.extend_from_slice(&meta().spec_hash.to_le_bytes());
        let mut rec = Vec::new();
        rec.extend_from_slice(&(body.len() as u32).to_le_bytes());
        rec.extend_from_slice(&crc32(&body).to_le_bytes());
        rec.extend_from_slice(&body);
        std::fs::write(&p, &rec).unwrap();
        let err = Journal::open(&p, &meta()).unwrap_err().to_string();
        assert!(err.contains("version 3"), "missing file version: {err}");
        assert!(err.contains("version 4"), "missing reader version: {err}");
        assert_eq!(std::fs::read(&p).unwrap(), rec, "v3 journal was modified");
        std::fs::remove_file(&p).unwrap();
    }
}
