//! The self-healing fleet driver: `sedar fleet launch --shards N`.
//!
//! The paper's pitch is detection plus *automatic* recovery (§1): a failed
//! replica is noticed and restarted from durable state without an operator
//! in the loop. This module is now a thin single-sweep client of the
//! extracted service machinery — [`Sweep`](super::sweep::Sweep) owns the
//! plan, directory, live aggregate and lifecycle;
//! [`Supervisor`](super::supervisor::Supervisor) owns spawn / poll /
//! restart / stall — the same components the `sedar serve` gateway
//! multiplexes many sweeps over:
//!
//! * [`run_launch`] builds one `Sweep`, starts every shard at once, and
//!   blocks polling it until every slice is durable;
//! * a child that **dies** (any exit before its WAL holds its whole
//!   slice) or **stalls** (its monotone `heartbeat` counter stops
//!   advancing for longer than the stall timeout) is killed if needed and
//!   relaunched — WAL replay makes every relaunch skip the tasks that
//!   already finished, so the retry cost is bounded by the work actually
//!   lost; restarts are bounded per shard;
//! * while shards run, the sweep re-reads each WAL as it grows and feeds
//!   a **live partial aggregate** — served over the optional launch-level
//!   status endpoint (`--status-port`), and *reused as the final merge*
//!   when the fleet completes, so the live aggregate at completion and
//!   the final report are the same object by construction —
//!   byte-identical to the single-process run with the same `--seed`
//!   (`rust/tests/fleet_launch.rs` proves this survives a mid-sweep
//!   SIGKILL).
//!
//! The stall detector compares heartbeats across polls: the counter ticks
//! once per finished task, so "no advance" means the worker pool is wedged
//! (or the process is gone — then the poll itself fails and the exit path
//! fires first). The timeout must therefore exceed the slowest single
//! task; the default is generous and CLI-tunable (`--stall-secs`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::campaign::CampaignReport;
use crate::error::Result;

use super::status::StatusServer;
use super::supervisor::{progress_line, LocalSpawner, SupervisorConfig};
use super::sweep::{Sweep, SweepConfig};

/// How the driver runs the fleet.
#[derive(Debug, Clone)]
pub struct LaunchOptions {
    /// Number of shard processes (the `N` of `--shard i/N`).
    pub shards: usize,
    /// Worker threads per shard (`0` = split the machine's default budget
    /// evenly across the shards, at least 1 each).
    pub jobs: usize,
    /// Campaign master seed (forwarded to every child).
    pub seed: u64,
    /// Campaign `--filter` expression (forwarded verbatim).
    pub filter: Option<String>,
    /// Campaign `--scenario` shorthand (forwarded verbatim).
    pub scenario: Option<String>,
    /// Run directory: WALs, logs, pid/addr files and the children's
    /// working dirs all live here.
    pub dir: PathBuf,
    /// Relaunch budget per shard; exceeding it fails the launch.
    pub max_restarts: usize,
    /// No heartbeat advance for this long ⇒ the shard is stalled and gets
    /// killed + relaunched. Must exceed the slowest single task.
    pub stall_timeout: Duration,
    /// Supervisor poll cadence.
    pub poll_interval: Duration,
    /// The `sedar` binary to spawn (`None` = this executable).
    pub bin: Option<PathBuf>,
    /// Suppress the live aggregate progress line (restart notices and the
    /// final summary still print).
    pub quiet: bool,
    /// Serve the fleet-wide live partial aggregate on `127.0.0.1:port`
    /// while shards run (port 0 = OS-assigned). This is the *union* view:
    /// what `sedar merge --allow-partial` over the live WALs would render.
    pub status_port: Option<u16>,
    /// After the launch-level status server binds, atomically write its
    /// actual address here (same handshake the children use).
    pub status_addr_file: Option<PathBuf>,
}

impl Default for LaunchOptions {
    fn default() -> LaunchOptions {
        LaunchOptions {
            shards: 2,
            jobs: 0,
            seed: 42,
            filter: None,
            scenario: None,
            dir: PathBuf::from("runs/fleet"),
            max_restarts: 3,
            stall_timeout: Duration::from_secs(300),
            poll_interval: Duration::from_millis(200),
            bin: None,
            quiet: false,
            status_port: None,
            status_addr_file: None,
        }
    }
}

/// What one shard looked like when the fleet finished.
#[derive(Debug, Clone)]
pub struct ShardStat {
    /// The plan label (`"2/4"`).
    pub label: String,
    /// Tasks this shard owned.
    pub owned: usize,
    /// Times the supervisor had to relaunch it.
    pub restarts: usize,
    /// Resumed/executed split of its *last observed* status snapshot —
    /// best-effort (a shard that finishes between polls keeps the
    /// previous snapshot's split).
    pub resumed: usize,
    pub executed: usize,
}

/// The driver's result: per-shard restart accounting plus the merged,
/// deterministic campaign report.
pub struct LaunchReport {
    pub shards: Vec<ShardStat>,
    pub report: CampaignReport,
}

impl LaunchReport {
    /// Restarts across the whole fleet.
    pub fn total_restarts(&self) -> usize {
        self.shards.iter().map(|s| s.restarts).sum()
    }

    /// Operator summary: one line per shard plus the fleet totals.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "fleet launch: {} shard(s), {} task(s), {} restart(s)",
            self.shards.len(),
            self.report.total(),
            self.total_restarts()
        );
        for st in &self.shards {
            s.push_str(&format!(
                "\n  shard {}: {} task(s), {} restart(s), last snapshot {} resumed / {} executed",
                st.label, st.owned, st.restarts, st.resumed, st.executed
            ));
        }
        s
    }
}

/// Run the whole fleet: spawn, supervise, relaunch, merge. Blocks until
/// every shard's slice is durable, then returns the merged report (or the
/// first unrecoverable error — children are killed on the way out).
pub fn run_launch(opts: &LaunchOptions) -> Result<LaunchReport> {
    let mut sweep = Sweep::new(
        SweepConfig {
            seed: opts.seed,
            shards: opts.shards,
            jobs: opts.jobs,
            filter: opts.filter.clone(),
            scenario: opts.scenario.clone(),
        },
        opts.dir.clone(),
        opts.bin.clone(),
        SupervisorConfig {
            max_restarts: opts.max_restarts,
            stall_timeout: opts.stall_timeout,
        },
        Arc::new(LocalSpawner),
    )?;
    let total = sweep.total();

    let _agg_server: Option<StatusServer> = match opts.status_port {
        None => None,
        Some(port) => {
            let server = StatusServer::spawn(port, sweep.aggregate())?;
            eprintln!(
                "fleet status endpoint: http://{}/ (and /json)",
                server.addr()
            );
            if let Some(path) = &opts.status_addr_file {
                // Write-then-rename: a watcher polling for this file must
                // never observe a half-written address.
                let tmp = path.with_extension("addr-tmp");
                std::fs::write(&tmp, format!("{}\n", server.addr()))?;
                std::fs::rename(&tmp, path)?;
            }
            Some(server)
        }
    };

    sweep.start_all()?;
    eprintln!(
        "fleet: launched {} shard(s) over {total} task(s) ({} job(s) per shard, dir {})",
        opts.shards,
        sweep.jobs(),
        opts.dir.display()
    );

    let mut last_line = String::new();
    let mut last_emit = Instant::now();
    loop {
        sweep.poll()?;
        if sweep.done() {
            break;
        }
        if !opts.quiet {
            let line = progress_line(sweep.supervisor().shards(), total);
            if line != last_line && last_emit.elapsed() >= Duration::from_millis(900) {
                eprintln!("{line}");
                last_line = line;
                last_emit = Instant::now();
            }
        }
        std::thread::sleep(opts.poll_interval);
    }

    let report = sweep.finalize()?;
    let stats = sweep
        .supervisor()
        .shards()
        .iter()
        .map(|p| ShardStat {
            label: p.plan.label(),
            owned: p.owned,
            restarts: p.restarts,
            resumed: p.snap.as_ref().map(|s| s.resumed).unwrap_or(0),
            executed: p.snap.as_ref().map(|s| s.executed).unwrap_or(0),
        })
        .collect();
    Ok(LaunchReport {
        shards: stats,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_rejects_empty_fleets_and_empty_sweeps() {
        let opts = LaunchOptions {
            shards: 0,
            ..LaunchOptions::default()
        };
        assert!(run_launch(&opts).is_err());
        let opts = LaunchOptions {
            filter: Some("scenario=999".into()),
            dir: std::env::temp_dir().join(format!(
                "sedar-launch-empty-{}-{:?}",
                std::process::id(),
                std::thread::current().id()
            )),
            ..LaunchOptions::default()
        };
        let err = run_launch(&opts).unwrap_err();
        assert!(err.to_string().contains("no tasks"), "got: {err}");
        let _ = std::fs::remove_dir_all(&opts.dir);
    }

    #[test]
    fn launch_report_summary_counts_shards_and_restarts() {
        // The summary format is part of the CI launch-smoke contract:
        // "fleet launch: 2 shard(s), 24 task(s), 0 restart(s)".
        let report = LaunchReport {
            shards: vec![
                ShardStat {
                    label: "1/2".into(),
                    owned: 12,
                    restarts: 1,
                    resumed: 2,
                    executed: 10,
                },
                ShardStat {
                    label: "2/2".into(),
                    owned: 12,
                    restarts: 0,
                    resumed: 0,
                    executed: 12,
                },
            ],
            report: crate::campaign::CampaignReport::new(7, vec![]),
        };
        assert_eq!(report.total_restarts(), 1);
        let s = report.summary();
        assert!(s.contains("2 shard(s)"), "got: {s}");
        assert!(s.contains("1 restart(s)"), "got: {s}");
        assert!(
            s.contains("shard 1/2: 12 task(s), 1 restart(s)"),
            "got: {s}"
        );
    }
}
