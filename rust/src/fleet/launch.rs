//! The self-healing fleet driver: `sedar fleet launch --shards N`.
//!
//! The paper's pitch is detection plus *automatic* recovery (§1): a failed
//! replica is noticed and restarted from durable state without an operator
//! in the loop. The fleet layer already had the durable half — each
//! shard's write-ahead log, plus live status endpoints — but a crashed
//! shard still needed a human to notice and re-run it. This module closes
//! that loop, applying SEDAR's own recovery discipline (level 2:
//! redundancy + checkpointing beats re-execution from scratch) to the
//! validation campaign itself:
//!
//! * [`run_launch`] spawns `N` `sedar campaign --shard i/N` child
//!   processes, each with its own WAL and OS-assigned status port under
//!   one run directory (`--status-addr-file` is the port-discovery
//!   handshake);
//! * the supervisor polls each child's `/json` status snapshot and exit
//!   code; a child that **dies** (any exit before its WAL holds its whole
//!   slice) or **stalls** (its monotone `heartbeat` counter stops
//!   advancing for longer than the stall timeout) is killed if needed and
//!   relaunched — WAL replay makes every relaunch skip the tasks that
//!   already finished, so the retry cost is bounded by the work actually
//!   lost;
//! * restarts are bounded per shard; a shard that exhausts its budget
//!   fails the whole launch with a pointer to its log;
//! * while shards run, the supervisor re-reads each WAL as it grows and
//!   feeds a **live partial aggregate** (one
//!   [`IncrementalMerger`] across the fleet) — served over the optional
//!   launch-level status endpoint (`--status-port`), and *reused as the
//!   final merge* when the fleet completes, so the live aggregate at
//!   completion and the final report are the same object by construction
//!   — byte-identical to the single-process run with the same `--seed`
//!   (`rust/tests/fleet_launch.rs` proves this survives a mid-sweep
//!   SIGKILL).
//!
//! The stall detector compares heartbeats across polls: the counter ticks
//! once per finished task, so "no advance" means the worker pool is wedged
//! (or the process is gone — then the poll itself fails and the exit path
//! fires first). The timeout must therefore exceed the slowest single
//! task; the default is generous and CLI-tunable (`--stall-secs`).

use std::fs::OpenOptions;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::campaign::aggregate::IncrementalMerger;
use crate::campaign::shard::TaskOutcome;
use crate::campaign::{build_tasks, sweep_fingerprint, CampaignReport, CampaignSpec};
use crate::error::{Result, SedarError};

use super::plan::ShardPlan;
use super::snapshot::read_wal;
use super::status::{http_get, StatusServer, StatusSource};
use super::wal::ShardMeta;

/// Per-poll timeout for one status GET (children live on loopback — a
/// healthy endpoint answers in microseconds, a dead one refuses at once).
const HTTP_TIMEOUT: Duration = Duration::from_millis(400);

/// How the supervisor runs the fleet.
#[derive(Debug, Clone)]
pub struct LaunchOptions {
    /// Number of shard processes (the `N` of `--shard i/N`).
    pub shards: usize,
    /// Worker threads per shard (`0` = split the machine's default budget
    /// evenly across the shards, at least 1 each).
    pub jobs: usize,
    /// Campaign master seed (forwarded to every child).
    pub seed: u64,
    /// Campaign `--filter` expression (forwarded verbatim).
    pub filter: Option<String>,
    /// Campaign `--scenario` shorthand (forwarded verbatim).
    pub scenario: Option<String>,
    /// Run directory: WALs, logs, pid/addr files and the children's
    /// working dirs all live here.
    pub dir: PathBuf,
    /// Relaunch budget per shard; exceeding it fails the launch.
    pub max_restarts: usize,
    /// No heartbeat advance for this long ⇒ the shard is stalled and gets
    /// killed + relaunched. Must exceed the slowest single task.
    pub stall_timeout: Duration,
    /// Supervisor poll cadence.
    pub poll_interval: Duration,
    /// The `sedar` binary to spawn (`None` = this executable).
    pub bin: Option<PathBuf>,
    /// Suppress the live aggregate progress line (restart notices and the
    /// final summary still print).
    pub quiet: bool,
    /// Serve the fleet-wide live partial aggregate on `127.0.0.1:port`
    /// while shards run (port 0 = OS-assigned). This is the *union* view:
    /// what `sedar merge --allow-partial` over the live WALs would render.
    pub status_port: Option<u16>,
    /// After the launch-level status server binds, atomically write its
    /// actual address here (same handshake the children use).
    pub status_addr_file: Option<PathBuf>,
}

impl Default for LaunchOptions {
    fn default() -> LaunchOptions {
        LaunchOptions {
            shards: 2,
            jobs: 0,
            seed: 42,
            filter: None,
            scenario: None,
            dir: PathBuf::from("runs/fleet"),
            max_restarts: 3,
            stall_timeout: Duration::from_secs(300),
            poll_interval: Duration::from_millis(200),
            bin: None,
            quiet: false,
            status_port: None,
            status_addr_file: None,
        }
    }
}

/// What one shard looked like when the fleet finished.
#[derive(Debug, Clone)]
pub struct ShardStat {
    /// The plan label (`"2/4"`).
    pub label: String,
    /// Tasks this shard owned.
    pub owned: usize,
    /// Times the supervisor had to relaunch it.
    pub restarts: usize,
    /// Resumed/executed split of its *last observed* status snapshot —
    /// best-effort (a shard that finishes between polls keeps the
    /// previous snapshot's split).
    pub resumed: usize,
    pub executed: usize,
}

/// The supervisor's result: per-shard restart accounting plus the merged,
/// deterministic campaign report.
pub struct LaunchReport {
    pub shards: Vec<ShardStat>,
    pub report: CampaignReport,
}

impl LaunchReport {
    /// Restarts across the whole fleet.
    pub fn total_restarts(&self) -> usize {
        self.shards.iter().map(|s| s.restarts).sum()
    }

    /// Operator summary: one line per shard plus the fleet totals.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "fleet launch: {} shard(s), {} task(s), {} restart(s)",
            self.shards.len(),
            self.report.total(),
            self.total_restarts()
        );
        for st in &self.shards {
            s.push_str(&format!(
                "\n  shard {}: {} task(s), {} restart(s), last snapshot {} resumed / {} executed",
                st.label, st.owned, st.restarts, st.resumed, st.executed
            ));
        }
        s
    }
}

/// The fleet-wide live partial aggregate: one [`IncrementalMerger`] re-fed
/// from each shard's WAL as it grows.
///
/// Ingest is idempotent per shard (a re-read *replaces* that shard's
/// outcome set), so the supervisor can refresh as often as it likes; the
/// WAL reader is lenient about a racing writer's torn tail, so the refresh
/// never needs a lock against the children. When the fleet completes, the
/// **same** merger renders the final report — the "live aggregate at
/// completion equals the final report" invariant holds by construction,
/// not by comparison.
struct FleetAggregate {
    total: usize,
    merger: Mutex<IncrementalMerger>,
}

impl FleetAggregate {
    fn new(first: ShardMeta, total: usize) -> FleetAggregate {
        FleetAggregate {
            total,
            merger: Mutex::new(IncrementalMerger::new(first)),
        }
    }

    /// Best-effort live refresh from one shard's WAL. A file that is
    /// missing, mid-creation or identity-drifted is skipped — the strict
    /// final ingest surfaces real problems with real errors.
    fn refresh(&self, path: &Path) {
        if let Ok((meta, outcomes)) = read_wal(path) {
            let _ = self.merger.lock().unwrap().ingest(&meta, outcomes);
        }
    }

    /// Strict ingest (the final-merge path): every error is fatal.
    fn ingest(&self, meta: &ShardMeta, outcomes: Vec<TaskOutcome>) -> Result<()> {
        self.merger.lock().unwrap().ingest(meta, outcomes)
    }

    /// Render the final report, requiring full coverage.
    fn final_report(&self) -> Result<CampaignReport> {
        let merger = self.merger.lock().unwrap();
        if merger.done() != self.total {
            return Err(SedarError::Config(format!(
                "fleet launch: merged union covers {} of {} task(s) — \
                 a shard WAL is incomplete",
                merger.done(),
                self.total
            )));
        }
        merger.report()
    }
}

impl StatusSource for FleetAggregate {
    fn text_snapshot(&self) -> String {
        let m = self.merger.lock().unwrap();
        let mut s = format!(
            "SEDAR fleet launch seed {}\ndone {}/{} (pass {}, fail {}) — {}\n",
            m.seed(),
            m.done(),
            self.total,
            m.passed(),
            m.failed(),
            if m.done() == self.total {
                "complete"
            } else {
                "partial union of live WALs"
            }
        );
        for (shard, done) in m.shard_progress() {
            s.push_str(&format!("  shard {}: {done} outcome(s)\n", shard + 1));
        }
        s
    }

    fn json_snapshot(&self) -> String {
        let m = self.merger.lock().unwrap();
        let shards: Vec<String> = m
            .shard_progress()
            .iter()
            .map(|(shard, done)| format!("{{\"shard\":{},\"done\":{done}}}", shard + 1))
            .collect();
        format!(
            "{{\"fleet\":\"launch\",\"seed\":{},\"total\":{},\"done\":{},\
             \"passed\":{},\"failed\":{},\"complete\":{},\"shards\":[{}]}}",
            m.seed(),
            self.total,
            m.done(),
            m.passed(),
            m.failed(),
            m.done() == self.total,
            shards.join(",")
        )
    }

    fn prometheus_snapshot(&self) -> String {
        let m = self.merger.lock().unwrap();
        let mut s = String::new();
        let mut metric = |name: &str, kind: &str, help: &str, value: String| {
            s.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        };
        metric(
            "sedar_fleet_tasks_total",
            "gauge",
            "Tasks in the whole sweep across all shards.",
            self.total.to_string(),
        );
        metric(
            "sedar_fleet_tasks_done_total",
            "counter",
            "Distinct finished tasks across the live WAL union.",
            m.done().to_string(),
        );
        metric(
            "sedar_fleet_tasks_passed_total",
            "counter",
            "Finished tasks that passed their cell's oracle.",
            m.passed().to_string(),
        );
        metric(
            "sedar_fleet_tasks_failed_total",
            "counter",
            "Finished tasks that mismatched their cell's oracle.",
            m.failed().to_string(),
        );
        metric(
            "sedar_fleet_complete",
            "gauge",
            "1 once the union covers every task of the sweep.",
            if m.done() == self.total { "1" } else { "0" }.to_string(),
        );
        s
    }
}

/// Shard-level scalars of one `/json` status snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Snapshot {
    done: usize,
    passed: usize,
    failed: usize,
    resumed: usize,
    executed: usize,
    heartbeat: u64,
}

/// First occurrence of `"key":<digits>` in `body`. The board emits every
/// shard-level scalar before the `cells` array, so the first occurrence is
/// always the shard-level value even though cells repeat `done`/`total`.
fn json_u64_field(body: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat)? + pat.len();
    let digits: String = body[at..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().ok()
}

impl Snapshot {
    fn parse(body: &str) -> Option<Snapshot> {
        Some(Snapshot {
            done: json_u64_field(body, "done")? as usize,
            passed: json_u64_field(body, "passed")? as usize,
            failed: json_u64_field(body, "failed")? as usize,
            resumed: json_u64_field(body, "resumed")? as usize,
            executed: json_u64_field(body, "executed")? as usize,
            heartbeat: json_u64_field(body, "heartbeat")?,
        })
    }
}

/// Where one shard's files live under the launch directory.
struct ShardPaths {
    /// The shard's single durable file: its write-ahead log.
    wal: PathBuf,
    addr: PathBuf,
    pid: PathBuf,
    log: PathBuf,
    run_dir: PathBuf,
}

impl ShardPaths {
    fn new(dir: &Path, member: usize) -> ShardPaths {
        ShardPaths {
            wal: dir.join(format!("shard-{member}.wal")),
            addr: dir.join(format!("shard-{member}.addr")),
            pid: dir.join(format!("shard-{member}.pid")),
            log: dir.join(format!("shard-{member}.log")),
            run_dir: dir.join(format!("run-{member}")),
        }
    }
}

/// What every (re)spawn needs: the launch options plus the resolved
/// binary path and per-shard worker budget.
struct SpawnCtx<'a> {
    opts: &'a LaunchOptions,
    bin: &'a Path,
    jobs: usize,
}

/// One supervised shard process (its current incarnation, if any).
struct ShardProc {
    plan: ShardPlan,
    owned: usize,
    expect: ShardMeta,
    paths: ShardPaths,
    child: Option<Child>,
    restarts: usize,
    addr: Option<SocketAddr>,
    snap: Option<Snapshot>,
    last_heartbeat: Option<u64>,
    last_advance: Instant,
    finished: bool,
    /// Last observed WAL byte length — the cheap change detector that
    /// gates re-reading the file into the live aggregate.
    wal_len: u64,
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        // An early supervisor exit (error path) must not leak children.
        if let Some(mut c) = self.child.take() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

impl ShardProc {
    /// Spawn (or respawn) this shard's `sedar campaign` child. The WAL
    /// path is stable across incarnations — that is what makes a relaunch
    /// a *resume*.
    fn spawn(&mut self, ctx: &SpawnCtx<'_>) -> Result<()> {
        let _ = std::fs::remove_file(&self.paths.addr);
        let log = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.paths.log)?;
        let mut cmd = Command::new(ctx.bin);
        cmd.arg("campaign")
            .arg("--seed")
            .arg(ctx.opts.seed.to_string())
            .arg("--jobs")
            .arg(ctx.jobs.to_string())
            .arg("--shard")
            .arg(self.plan.label())
            .arg("--wal")
            .arg(&self.paths.wal)
            .arg("--status-port")
            .arg("0")
            .arg("--status-addr-file")
            .arg(&self.paths.addr)
            .arg("--run-dir")
            .arg(&self.paths.run_dir)
            .arg("--quiet");
        if let Some(f) = &ctx.opts.filter {
            cmd.arg("--filter").arg(f);
        }
        if let Some(k) = &ctx.opts.scenario {
            cmd.arg("--scenario").arg(k);
        }
        cmd.stdin(Stdio::null())
            .stdout(Stdio::from(log.try_clone()?))
            .stderr(Stdio::from(log));
        let child = cmd.spawn().map_err(|e| {
            SedarError::Config(format!(
                "fleet launch: cannot spawn shard {} ({}): {e}",
                self.plan.label(),
                ctx.bin.display()
            ))
        })?;
        let pid = child.id();
        // Track the handle before any further fallible step: a pid-file
        // write failure must fail the launch without orphaning the child
        // just spawned (Drop kills whatever `self.child` holds).
        self.child = Some(child);
        self.addr = None;
        self.last_heartbeat = None;
        self.last_advance = Instant::now();
        // The pid file is observability (and what the e2e kill test aims
        // at), not control flow — the supervisor holds the Child handle.
        std::fs::write(&self.paths.pid, format!("{pid}\n"))?;
        Ok(())
    }

    /// Is this shard's WAL a complete record of its slice? (The completion
    /// criterion: exit codes alone cannot distinguish "died mid-sweep"
    /// from "finished but the report verdict failed".)
    fn wal_complete(&self) -> bool {
        match read_wal(&self.paths.wal) {
            Ok((meta, outcomes)) => meta == self.expect && outcomes.len() == self.owned,
            Err(_) => false,
        }
    }

    /// Bounded relaunch, or give up and fail the launch.
    fn relaunch(&mut self, why: &str, ctx: &SpawnCtx<'_>) -> Result<()> {
        if self.restarts >= ctx.opts.max_restarts {
            return Err(SedarError::Config(format!(
                "fleet launch: shard {} {why} and exhausted its restart budget \
                 ({}) — see {}",
                self.plan.label(),
                ctx.opts.max_restarts,
                self.paths.log.display()
            )));
        }
        self.restarts += 1;
        eprintln!(
            "fleet: shard {} {why} — relaunch {}/{} (WAL replay skips finished tasks)",
            self.plan.label(),
            self.restarts,
            ctx.opts.max_restarts
        );
        self.spawn(ctx)
    }

    /// One supervision step: reap an exit, or poll status and check for a
    /// stall — relaunching as needed.
    fn step(&mut self, ctx: &SpawnCtx<'_>) -> Result<()> {
        let exited = match self.child.as_mut() {
            None => None,
            Some(c) => c.try_wait()?,
        };
        if let Some(status) = exited {
            self.child = None;
            if self.wal_complete() {
                self.finished = true;
                if !status.success() {
                    eprintln!(
                        "fleet: shard {} finished its slice with a failing verdict \
                         ({status}) — the merged report will carry it; see {}",
                        self.plan.label(),
                        self.paths.log.display()
                    );
                }
                return Ok(());
            }
            let why = format!("exited ({status}) before its slice was durable");
            return self.relaunch(&why, ctx);
        }

        // Alive: learn the OS-assigned endpoint, then poll it.
        if self.addr.is_none() {
            if let Ok(s) = std::fs::read_to_string(&self.paths.addr) {
                self.addr = s.trim().parse().ok();
            }
        }
        if let Some(addr) = self.addr {
            if let Ok(body) = http_get(addr, "/json", HTTP_TIMEOUT) {
                if let Some(snap) = Snapshot::parse(&body) {
                    if self.last_heartbeat != Some(snap.heartbeat) {
                        self.last_heartbeat = Some(snap.heartbeat);
                        self.last_advance = Instant::now();
                    }
                    self.snap = Some(snap);
                }
            }
        }
        if self.last_advance.elapsed() > ctx.opts.stall_timeout {
            if let Some(mut c) = self.child.take() {
                let _ = c.kill();
                let _ = c.wait();
            }
            let secs = ctx.opts.stall_timeout.as_secs();
            let why = format!("stalled (no heartbeat advance in {secs}s)");
            return self.relaunch(&why, ctx);
        }
        Ok(())
    }
}

/// Aggregate progress across the fleet, one line.
fn progress_line(fleet: &[ShardProc], total: usize) -> String {
    let mut done = 0usize;
    let mut passed = 0usize;
    let mut failed = 0usize;
    let mut restarts = 0usize;
    let mut parts = Vec::with_capacity(fleet.len());
    for p in fleet {
        let (d, pa, fa) = match &p.snap {
            Some(s) => (s.done, s.passed, s.failed),
            None => (0, 0, 0),
        };
        // A finished shard's last snapshot can be stale; its WAL is
        // complete by definition.
        let d = if p.finished { p.owned } else { d };
        done += d;
        passed += pa;
        failed += fa;
        restarts += p.restarts;
        let marker = if p.restarts > 0 {
            format!("(r{})", p.restarts)
        } else {
            String::new()
        };
        parts.push(format!("{}:{d}/{}{marker}", p.plan.label(), p.owned));
    }
    format!(
        "fleet: {done}/{total} task(s) done ({passed} pass, {failed} fail) \
         | {} | {restarts} restart(s)",
        parts.join(" ")
    )
}

/// Run the whole fleet: spawn, supervise, relaunch, merge. Blocks until
/// every shard's slice is durable, then returns the merged report (or the
/// first unrecoverable error — children are killed on the way out).
pub fn run_launch(opts: &LaunchOptions) -> Result<LaunchReport> {
    if opts.shards == 0 {
        return Err(SedarError::Config(
            "fleet launch: --shards must be >= 1".into(),
        ));
    }
    // Build the spec exactly as every child will, so the supervisor knows
    // each slice's size and identity (and can verify WALs against the
    // same sweep fingerprint the children stamp into them).
    let mut spec = CampaignSpec::new(opts.seed);
    if let Some(f) = &opts.filter {
        spec.apply_filter(f)?;
    }
    if let Some(k) = &opts.scenario {
        spec.apply_filter(&format!("scenario={k}"))?;
    }
    let tasks = build_tasks(&spec);
    if tasks.is_empty() {
        return Err(SedarError::Config(
            "campaign filter selects no tasks".into(),
        ));
    }
    let total = tasks.len();
    let fingerprint = sweep_fingerprint(opts.seed, &tasks);
    std::fs::create_dir_all(&opts.dir)?;
    let bin = match &opts.bin {
        Some(b) => b.clone(),
        None => std::env::current_exe()?,
    };
    let jobs = if opts.jobs > 0 {
        opts.jobs
    } else {
        (CampaignSpec::default_jobs() / opts.shards).max(1)
    };

    let mut fleet: Vec<ShardProc> = (0..opts.shards)
        .map(|i| {
            let plan = ShardPlan {
                index: i,
                count: opts.shards,
            };
            ShardProc {
                owned: plan.slice(&tasks).len(),
                expect: ShardMeta {
                    seed: opts.seed,
                    shard_index: i as u32,
                    shard_count: opts.shards as u32,
                    total_tasks: total as u64,
                    spec_hash: fingerprint,
                },
                paths: ShardPaths::new(&opts.dir, i + 1),
                child: None,
                restarts: 0,
                addr: None,
                snap: None,
                last_heartbeat: None,
                last_advance: Instant::now(),
                finished: false,
                wal_len: 0,
                plan,
            }
        })
        .collect();

    // The live partial aggregate spans the whole fleet; seed its identity
    // from shard 1's expected header (every shard must match it anyway).
    let aggregate = Arc::new(FleetAggregate::new(fleet[0].expect, total));
    let _agg_server: Option<StatusServer> = match opts.status_port {
        None => None,
        Some(port) => {
            let server = StatusServer::spawn(port, aggregate.clone())?;
            eprintln!(
                "fleet status endpoint: http://{}/ (and /json)",
                server.addr()
            );
            if let Some(path) = &opts.status_addr_file {
                // Write-then-rename: a watcher polling for this file must
                // never observe a half-written address.
                let tmp = path.with_extension("addr-tmp");
                std::fs::write(&tmp, format!("{}\n", server.addr()))?;
                std::fs::rename(&tmp, path)?;
            }
            Some(server)
        }
    };

    let ctx = SpawnCtx {
        opts,
        bin: &bin,
        jobs,
    };
    for p in fleet.iter_mut() {
        p.spawn(&ctx)?;
    }
    eprintln!(
        "fleet: launched {} shard(s) over {total} task(s) ({jobs} job(s) per shard, dir {})",
        opts.shards,
        opts.dir.display()
    );

    let mut last_line = String::new();
    let mut last_emit = Instant::now();
    loop {
        let mut all_done = true;
        for p in fleet.iter_mut() {
            if p.finished {
                continue;
            }
            all_done = false;
            p.step(&ctx)?;
            // Feed the live aggregate whenever the shard's WAL grew. The
            // metadata probe is cheap; the WAL reader tolerates a racing
            // writer's torn tail, so no coordination with the child is
            // needed.
            let len = std::fs::metadata(&p.paths.wal)
                .map(|m| m.len())
                .unwrap_or(0);
            if len != p.wal_len {
                p.wal_len = len;
                aggregate.refresh(&p.paths.wal);
            }
        }
        if all_done {
            break;
        }
        if !opts.quiet {
            let line = progress_line(&fleet, total);
            if line != last_line && last_emit.elapsed() >= Duration::from_millis(900) {
                eprintln!("{line}");
                last_line = line;
                last_emit = Instant::now();
            }
        }
        std::thread::sleep(opts.poll_interval);
    }

    // Every slice is durable. The final merge is one last STRICT ingest of
    // each WAL into the same merger the live aggregate used all along —
    // identity drift and overlap are re-verified here with real errors,
    // and the coverage check below is the completeness half. Because it is
    // the same object, "live aggregate at completion" and "final report"
    // cannot disagree.
    for p in &fleet {
        let (meta, outcomes) = read_wal(&p.paths.wal)?;
        aggregate.ingest(&meta, outcomes)?;
    }
    let report = aggregate.final_report()?;
    let stats = fleet
        .iter()
        .map(|p| ShardStat {
            label: p.plan.label(),
            owned: p.owned,
            restarts: p.restarts,
            resumed: p.snap.as_ref().map(|s| s.resumed).unwrap_or(0),
            executed: p.snap.as_ref().map(|s| s.executed).unwrap_or(0),
        })
        .collect();
    Ok(LaunchReport {
        shards: stats,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_parses_shard_level_scalars_not_cell_fields() {
        // A realistic board document: the cells repeat `done`/`total`/
        // `passed` keys with *different* values — the first (shard-level)
        // occurrence must win.
        let body = "{\"fleet\":\"shard 1/2\",\"seed\":7,\"total\":18,\"done\":5,\
                    \"passed\":4,\"failed\":1,\"executed\":3,\"resumed\":2,\
                    \"heartbeat\":5,\"cells\":[{\"app\":\"matmul\",\
                    \"strategy\":\"sys-ckpt\",\"total\":9,\"done\":9,\"passed\":9}]}";
        let s = Snapshot::parse(body).unwrap();
        assert_eq!(s.done, 5);
        assert_eq!(s.passed, 4);
        assert_eq!(s.failed, 1);
        assert_eq!(s.executed, 3);
        assert_eq!(s.resumed, 2);
        assert_eq!(s.heartbeat, 5);
    }

    #[test]
    fn snapshot_parse_rejects_incomplete_documents() {
        // A pre-extension snapshot (no heartbeat/resumed fields) must not
        // parse into zeros that defeat stall detection.
        let old = "{\"fleet\":\"shard 1/2\",\"seed\":7,\"total\":18,\"done\":5,\
                   \"passed\":4,\"failed\":1,\"cells\":[]}";
        assert!(Snapshot::parse(old).is_none());
        assert!(Snapshot::parse("").is_none());
        assert!(Snapshot::parse("not json at all").is_none());
    }

    #[test]
    fn launch_rejects_empty_fleets_and_empty_sweeps() {
        let opts = LaunchOptions {
            shards: 0,
            ..LaunchOptions::default()
        };
        assert!(run_launch(&opts).is_err());
        let opts = LaunchOptions {
            filter: Some("scenario=999".into()),
            dir: std::env::temp_dir().join(format!(
                "sedar-launch-empty-{}-{:?}",
                std::process::id(),
                std::thread::current().id()
            )),
            ..LaunchOptions::default()
        };
        let err = run_launch(&opts).unwrap_err();
        assert!(err.to_string().contains("no tasks"), "got: {err}");
        let _ = std::fs::remove_dir_all(&opts.dir);
    }

    #[test]
    fn progress_line_aggregates_and_marks_restarts() {
        let dir = std::env::temp_dir();
        let mk = |i: usize, snap: Option<Snapshot>, restarts: usize, finished: bool| ShardProc {
            plan: ShardPlan { index: i, count: 2 },
            owned: 5,
            expect: ShardMeta {
                seed: 1,
                shard_index: i as u32,
                shard_count: 2,
                total_tasks: 10,
                spec_hash: 0,
            },
            paths: ShardPaths::new(&dir, i + 1),
            child: None,
            restarts,
            addr: None,
            snap,
            last_heartbeat: None,
            last_advance: Instant::now(),
            finished,
            wal_len: 0,
        };
        let fleet = vec![
            mk(
                0,
                Some(Snapshot {
                    done: 3,
                    passed: 2,
                    failed: 1,
                    resumed: 0,
                    executed: 3,
                    heartbeat: 3,
                }),
                1,
                false,
            ),
            mk(1, None, 0, true),
        ];
        let line = progress_line(&fleet, 10);
        assert!(line.contains("8/10"), "got: {line}");
        assert!(line.contains("1/2:3/5(r1)"), "got: {line}");
        assert!(line.contains("2/2:5/5"), "got: {line}");
        assert!(line.contains("1 restart(s)"), "got: {line}");
    }

    #[test]
    fn fleet_aggregate_serves_partial_then_complete_unions() {
        let meta = |shard_index: u32| ShardMeta {
            seed: 9,
            shard_index,
            shard_count: 2,
            total_tasks: 2,
            spec_hash: 0xABCD,
        };
        let outcome = |index: usize, pass: bool| TaskOutcome {
            index,
            scenario_id: index as u32,
            app: crate::campaign::CampaignApp::Matmul,
            strategy: crate::config::Strategy::SysCkpt,
            collectives: crate::config::CollectiveImpl::PointToPoint,
            validation: crate::detect::ValidationMode::Full,
            netfault: crate::faultnet::NetFaultMode::None,
            faults: 1,
            completed: true,
            restarts: 0,
            injected: true,
            correct: Some(pass),
            first_detection: None,
            last_resume: None,
            pass,
            mismatches: vec![],
            wall: Duration::ZERO,
            metrics: Default::default(),
        };

        let agg = FleetAggregate::new(meta(0), 2);
        agg.ingest(&meta(0), vec![outcome(0, true)]).unwrap();

        // Mid-flight: a well-formed partial union.
        let json = agg.json_snapshot();
        assert!(json.contains("\"fleet\":\"launch\""), "got: {json}");
        assert!(json.contains("\"done\":1"), "got: {json}");
        assert!(json.contains("\"total\":2"), "got: {json}");
        assert!(json.contains("\"complete\":false"), "got: {json}");
        let text = agg.text_snapshot();
        assert!(text.contains("partial union"), "got: {text}");
        assert!(agg.final_report().is_err(), "partial must not finalize");

        // Completion: the same merger renders the final report.
        agg.ingest(&meta(1), vec![outcome(1, false)]).unwrap();
        let json = agg.json_snapshot();
        assert!(json.contains("\"complete\":true"), "got: {json}");
        assert!(json.contains("\"failed\":1"), "got: {json}");
        let prom = agg.prometheus_snapshot();
        assert!(prom.contains("sedar_fleet_complete 1"), "got: {prom}");
        assert!(prom.contains("sedar_fleet_tasks_done_total 2"), "got: {prom}");
        let report = agg.final_report().unwrap();
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 1);
    }
}
