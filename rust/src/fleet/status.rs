//! Live progress for long sweeps: a std-only TCP endpoint.
//!
//! A full 576-task sweep (or a wider beyond-paper one) runs for minutes to
//! hours; an operator driving N shard processes across machines needs to
//! see progress without grepping stderr. [`StatusBoard`] is the shared
//! counter the scheduler sink updates per finished task;
//! [`StatusServer::spawn`] serves a snapshot of it over plain HTTP —
//! `GET /` for human-readable text, `GET /json` for machine-readable JSON —
//! with nothing but `std::net`.
//!
//! The endpoint is observational only: it reads atomics and a small mutex-
//! guarded rollup, never touches the deterministic report path, and dies
//! with the sweep.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::campaign::shard::TaskOutcome;
use crate::campaign::CampaignTask;
use crate::error::{Result, SedarError};
use crate::report::json_escape;

/// Per-(app × strategy) progress cell.
#[derive(Debug, Default, Clone)]
struct Cell {
    total: usize,
    done: usize,
    passed: usize,
}

/// Shared progress state of one shard's sweep.
pub struct StatusBoard {
    label: String,
    seed: u64,
    total: usize,
    done: AtomicUsize,
    passed: AtomicUsize,
    failed: AtomicUsize,
    cells: Mutex<BTreeMap<(String, String), Cell>>,
}

impl StatusBoard {
    /// A board sized for `tasks` (this shard's slice), labelled for the
    /// operator (e.g. `"shard 2/4"`).
    pub fn new(label: &str, seed: u64, tasks: &[CampaignTask]) -> StatusBoard {
        let mut cells: BTreeMap<(String, String), Cell> = BTreeMap::new();
        for t in tasks {
            cells
                .entry((t.app.label().to_string(), t.strategy.label().to_string()))
                .or_default()
                .total += 1;
        }
        StatusBoard {
            label: label.to_string(),
            seed,
            total: tasks.len(),
            done: AtomicUsize::new(0),
            passed: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            cells: Mutex::new(cells),
        }
    }

    /// Record one finished (or journal-recovered) task.
    pub fn record(&self, outcome: &TaskOutcome) {
        self.done.fetch_add(1, Ordering::SeqCst);
        if outcome.pass {
            self.passed.fetch_add(1, Ordering::SeqCst);
        } else {
            self.failed.fetch_add(1, Ordering::SeqCst);
        }
        let key = (
            outcome.app.label().to_string(),
            outcome.strategy.label().to_string(),
        );
        let mut cells = self.cells.lock().unwrap();
        let cell = cells.entry(key).or_default();
        cell.done += 1;
        if outcome.pass {
            cell.passed += 1;
        }
    }

    /// Human-readable snapshot (the `GET /` body).
    pub fn text_snapshot(&self) -> String {
        let done = self.done.load(Ordering::SeqCst);
        let passed = self.passed.load(Ordering::SeqCst);
        let failed = self.failed.load(Ordering::SeqCst);
        let mut s = format!(
            "SEDAR fleet {} seed {}\ndone {done}/{} (pass {passed}, fail {failed})\n",
            self.label, self.seed, self.total
        );
        for ((app, strategy), cell) in self.cells.lock().unwrap().iter() {
            s.push_str(&format!(
                "  {app} × {strategy}: {}/{} done, {} passed\n",
                cell.done, cell.total, cell.passed
            ));
        }
        s
    }

    /// Machine-readable snapshot (the `GET /json` body).
    pub fn json_snapshot(&self) -> String {
        let done = self.done.load(Ordering::SeqCst);
        let passed = self.passed.load(Ordering::SeqCst);
        let failed = self.failed.load(Ordering::SeqCst);
        let cells: Vec<String> = self
            .cells
            .lock()
            .unwrap()
            .iter()
            .map(|((app, strategy), cell)| {
                format!(
                    "{{\"app\":\"{}\",\"strategy\":\"{}\",\"total\":{},\"done\":{},\"passed\":{}}}",
                    json_escape(app),
                    json_escape(strategy),
                    cell.total,
                    cell.done,
                    cell.passed
                )
            })
            .collect();
        format!(
            "{{\"fleet\":\"{}\",\"seed\":{},\"total\":{},\"done\":{done},\
             \"passed\":{passed},\"failed\":{failed},\"cells\":[{}]}}",
            json_escape(&self.label),
            self.seed,
            self.total,
            cells.join(",")
        )
    }
}

/// The listener thread serving a [`StatusBoard`]. Dropping the handle stops
/// the thread (it polls a stop flag between accepts).
pub struct StatusServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl StatusServer {
    /// Bind `127.0.0.1:port` (port 0 = OS-assigned; see [`StatusServer::addr`])
    /// and serve `board` until dropped.
    pub fn spawn(port: u16, board: Arc<StatusBoard>) -> Result<StatusServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .map_err(|e| SedarError::Config(format!("--status-port {port}: cannot bind: {e}")))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("sedar-status".into())
            .spawn(move || {
                loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // One request per connection; errors on a single
                            // connection never take the endpoint down.
                            let _ = serve_one(stream, &board);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            if stop_flag.load(Ordering::SeqCst) {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(_) => {
                            if stop_flag.load(Ordering::SeqCst) {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(25));
                        }
                    }
                }
            })?;
        Ok(StatusServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_one(mut stream: TcpStream, board: &StatusBoard) -> std::io::Result<()> {
    use std::io::{Read, Write};
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf).unwrap_or(0);
    let request_line = String::from_utf8_lossy(&buf[..n]);
    let want_json = request_line
        .lines()
        .next()
        .map(|l| l.split_whitespace().nth(1).unwrap_or("/") == "/json")
        .unwrap_or(false);
    let (content_type, body) = if want_json {
        ("application/json", board.json_snapshot())
    } else {
        ("text/plain; charset=utf-8", board.text_snapshot())
    };
    let response = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::{build_tasks, CampaignSpec};

    fn sample_board() -> (StatusBoard, Vec<crate::campaign::CampaignTask>) {
        let mut spec = CampaignSpec::new(5);
        spec.apply_filter("scenario=1-2").unwrap();
        let tasks = build_tasks(&spec);
        (StatusBoard::new("shard 1/1", 5, &tasks), tasks)
    }

    fn fake_outcome(t: &crate::campaign::CampaignTask, pass: bool) -> TaskOutcome {
        TaskOutcome {
            index: t.index,
            scenario_id: t.scenario.id,
            app: t.app,
            strategy: t.strategy,
            validation: t.validation,
            faults: t.faults,
            completed: true,
            restarts: 0,
            injected: true,
            correct: Some(true),
            first_detection: None,
            last_resume: None,
            pass,
            mismatches: vec![],
            wall: Duration::ZERO,
        }
    }

    #[test]
    fn board_counts_and_snapshots() {
        let (board, tasks) = sample_board();
        board.record(&fake_outcome(&tasks[0], true));
        board.record(&fake_outcome(&tasks[1], false));
        let text = board.text_snapshot();
        assert!(text.contains("done 2/18"), "got: {text}");
        assert!(text.contains("pass 1, fail 1"), "got: {text}");
        let json = board.json_snapshot();
        assert!(json.contains("\"done\":2"), "got: {json}");
        assert!(json.contains("\"seed\":5"), "got: {json}");
        assert!(json.contains("\"app\":\"matmul\""), "got: {json}");
    }

    #[test]
    fn endpoint_serves_text_and_json() {
        use std::io::{Read, Write};
        let (board, tasks) = sample_board();
        let board = Arc::new(board);
        board.record(&fake_outcome(&tasks[0], true));
        let server = StatusServer::spawn(0, board.clone()).unwrap();

        let fetch = |path: &str| -> String {
            let mut conn = TcpStream::connect(server.addr()).unwrap();
            conn.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())
                .unwrap();
            let mut out = String::new();
            conn.read_to_string(&mut out).unwrap();
            out
        };

        let text = fetch("/");
        assert!(text.starts_with("HTTP/1.0 200 OK"), "got: {text}");
        assert!(text.contains("done 1/18"), "got: {text}");
        let json = fetch("/json");
        assert!(json.contains("application/json"), "got: {json}");
        assert!(json.contains("\"done\":1"), "got: {json}");
        drop(server); // must join cleanly, not hang
    }
}
