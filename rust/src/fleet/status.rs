//! Live progress for long sweeps: a std-only TCP endpoint.
//!
//! A full 1152-task sweep (or a wider beyond-paper one) runs for minutes to
//! hours; an operator driving N shard processes across machines needs to
//! see progress without grepping stderr. [`StatusBoard`] is the shared
//! counter the scheduler sink updates per finished task;
//! [`StatusServer::spawn`] serves a snapshot of any [`StatusSource`] over
//! plain HTTP — `GET /` for human-readable text, `GET /json` for
//! machine-readable JSON — with nothing but `std::net`. The board is one
//! source; a sweep's live fleet aggregate ([`crate::fleet::sweep`]) is
//! another, served by the same listener.
//!
//! The endpoint is observational only: it reads atomics and a small mutex-
//! guarded rollup, never touches the deterministic report path, and dies
//! with the sweep.
//!
//! Snapshots carry what a *supervisor* needs, not just an operator: the
//! shard label, the `executed`/`resumed` split (how much of the progress
//! was recovered from the WAL vs run in this process), and a
//! monotonically increasing `heartbeat` counter — one tick per progress
//! event — that [`crate::fleet::supervisor`] watches for stall detection.
//! [`http_get`] is the matching std-only client half.
//!
//! Beyond progress counts, the board aggregates each finished task's
//! [`crate::metrics::MetricsSnapshot`] work counters live: cumulative
//! compared bytes and checkpointed bytes ride in `/json`, and
//! `GET /metrics` exposes the same numbers (plus a tasks/s rate) in
//! Prometheus text format for scrape-based monitoring.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::campaign::shard::TaskOutcome;
use crate::campaign::CampaignTask;
use crate::error::{Result, SedarError};
use crate::report::json_escape;

/// Anything a [`StatusServer`] can serve: the three snapshot bodies behind
/// `GET /`, `GET /json` and `GET /metrics`. Implementations must be cheap
/// and lock-light — a snapshot is taken per request on the serving thread.
pub trait StatusSource: Send + Sync {
    /// Human-readable snapshot (the `GET /` body).
    fn text_snapshot(&self) -> String;
    /// Machine-readable snapshot (the `GET /json` body).
    fn json_snapshot(&self) -> String;
    /// Prometheus text-format snapshot (the `GET /metrics` body).
    fn prometheus_snapshot(&self) -> String;
}

/// Per-(app × strategy) progress cell.
#[derive(Debug, Default, Clone)]
struct Cell {
    total: usize,
    done: usize,
    passed: usize,
}

/// Shared progress state of one shard's sweep.
pub struct StatusBoard {
    label: String,
    seed: u64,
    total: usize,
    done: AtomicUsize,
    passed: AtomicUsize,
    failed: AtomicUsize,
    /// Of `done`, how many were recovered from the WAL (not executed
    /// in this process). A supervisor reads the split to tell "this
    /// relaunch is skipping finished work" from "it is redoing it".
    resumed: AtomicUsize,
    /// Bumped on every progress event. Strictly monotonic while the sweep
    /// advances, frozen when it does not — the signal a supervisor's stall
    /// detector compares across polls (a wedged worker pool stops beating
    /// even while this serving thread stays healthy).
    heartbeat: AtomicU64,
    /// Cumulative bytes compared between replicas across finished tasks.
    compare_bytes: AtomicU64,
    /// Cumulative checkpoint bytes (system + user) across finished tasks.
    ckpt_bytes: AtomicU64,
    /// When the board was created — the denominator of the tasks/s rate.
    /// Wall time is fine here: the endpoint is observational, never on the
    /// deterministic report path.
    started: Instant,
    cells: Mutex<BTreeMap<(String, String), Cell>>,
}

impl StatusBoard {
    /// A board sized for `tasks` (this shard's slice), labelled for the
    /// operator (e.g. `"shard 2/4"`).
    pub fn new(label: &str, seed: u64, tasks: &[CampaignTask]) -> StatusBoard {
        let mut cells: BTreeMap<(String, String), Cell> = BTreeMap::new();
        for t in tasks {
            cells
                .entry((t.app.label().to_string(), t.strategy.label().to_string()))
                .or_default()
                .total += 1;
        }
        StatusBoard {
            label: label.to_string(),
            seed,
            total: tasks.len(),
            done: AtomicUsize::new(0),
            passed: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            resumed: AtomicUsize::new(0),
            heartbeat: AtomicU64::new(0),
            compare_bytes: AtomicU64::new(0),
            ckpt_bytes: AtomicU64::new(0),
            started: Instant::now(),
            cells: Mutex::new(cells),
        }
    }

    /// Record one task executed in this process.
    pub fn record(&self, outcome: &TaskOutcome) {
        self.record_inner(outcome, false);
    }

    /// Record one task recovered from the WAL (counted as done, and
    /// in the `resumed` split).
    pub fn record_resumed(&self, outcome: &TaskOutcome) {
        self.record_inner(outcome, true);
    }

    fn record_inner(&self, outcome: &TaskOutcome, resumed: bool) {
        self.done.fetch_add(1, Ordering::SeqCst);
        if resumed {
            self.resumed.fetch_add(1, Ordering::SeqCst);
        }
        if outcome.pass {
            self.passed.fetch_add(1, Ordering::SeqCst);
        } else {
            self.failed.fetch_add(1, Ordering::SeqCst);
        }
        self.compare_bytes
            .fetch_add(outcome.metrics.compare_bytes, Ordering::SeqCst);
        self.ckpt_bytes.fetch_add(
            outcome.metrics.sys_ckpt_bytes + outcome.metrics.user_ckpt_bytes,
            Ordering::SeqCst,
        );
        let key = (
            outcome.app.label().to_string(),
            outcome.strategy.label().to_string(),
        );
        {
            let mut cells = self.cells.lock().unwrap();
            let cell = cells.entry(key).or_default();
            cell.done += 1;
            if outcome.pass {
                cell.passed += 1;
            }
        }
        self.heartbeat.fetch_add(1, Ordering::SeqCst);
    }

    /// Human-readable snapshot (the `GET /` body).
    pub fn text_snapshot(&self) -> String {
        let done = self.done.load(Ordering::SeqCst);
        let passed = self.passed.load(Ordering::SeqCst);
        let failed = self.failed.load(Ordering::SeqCst);
        let resumed = self.resumed.load(Ordering::SeqCst);
        let mut s = format!(
            "SEDAR fleet {} seed {}\ndone {done}/{} (pass {passed}, fail {failed}; \
             {resumed} resumed, {} executed)\n",
            self.label,
            self.seed,
            self.total,
            done.saturating_sub(resumed)
        );
        for ((app, strategy), cell) in self.cells.lock().unwrap().iter() {
            s.push_str(&format!(
                "  {app} × {strategy}: {}/{} done, {} passed\n",
                cell.done, cell.total, cell.passed
            ));
        }
        s
    }

    /// Machine-readable snapshot (the `GET /json` body). Scalar fields
    /// come before `cells`, so a key's first occurrence in the document is
    /// always the shard-level value (the supervisor's field extractor
    /// relies on this).
    pub fn json_snapshot(&self) -> String {
        let done = self.done.load(Ordering::SeqCst);
        let passed = self.passed.load(Ordering::SeqCst);
        let failed = self.failed.load(Ordering::SeqCst);
        let resumed = self.resumed.load(Ordering::SeqCst);
        let heartbeat = self.heartbeat.load(Ordering::SeqCst);
        let compare_bytes = self.compare_bytes.load(Ordering::SeqCst);
        let ckpt_bytes = self.ckpt_bytes.load(Ordering::SeqCst);
        let cells: Vec<String> = self
            .cells
            .lock()
            .unwrap()
            .iter()
            .map(|((app, strategy), cell)| {
                format!(
                    "{{\"app\":\"{}\",\"strategy\":\"{}\",\"total\":{},\"done\":{},\"passed\":{}}}",
                    json_escape(app),
                    json_escape(strategy),
                    cell.total,
                    cell.done,
                    cell.passed
                )
            })
            .collect();
        format!(
            "{{\"fleet\":\"{}\",\"seed\":{},\"total\":{},\"done\":{done},\
             \"passed\":{passed},\"failed\":{failed},\"executed\":{},\
             \"resumed\":{resumed},\"heartbeat\":{heartbeat},\
             \"tasks_per_sec\":{:.3},\"compare_bytes\":{compare_bytes},\
             \"ckpt_bytes\":{ckpt_bytes},\"cells\":[{}]}}",
            json_escape(&self.label),
            self.seed,
            self.total,
            done.saturating_sub(resumed),
            self.tasks_per_sec(),
            cells.join(",")
        )
    }

    /// Finished-tasks rate over the board's lifetime (resumed tasks
    /// included — they are progress a supervisor sees).
    fn tasks_per_sec(&self) -> f64 {
        let done = self.done.load(Ordering::SeqCst) as f64;
        done / self.started.elapsed().as_secs_f64().max(1e-3)
    }

    /// Prometheus text-format snapshot (the `GET /metrics` body).
    pub fn prometheus_snapshot(&self) -> String {
        let mut s = String::new();
        let mut metric = |name: &str, kind: &str, help: &str, value: String| {
            s.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        };
        let load = |a: &AtomicUsize| a.load(Ordering::SeqCst).to_string();
        metric(
            "sedar_tasks_total",
            "gauge",
            "Tasks in this shard's slice of the sweep.",
            self.total.to_string(),
        );
        metric(
            "sedar_tasks_done_total",
            "counter",
            "Finished tasks (executed + resumed).",
            load(&self.done),
        );
        metric(
            "sedar_tasks_passed_total",
            "counter",
            "Finished tasks that passed their cell's oracle.",
            load(&self.passed),
        );
        metric(
            "sedar_tasks_failed_total",
            "counter",
            "Finished tasks that mismatched their cell's oracle.",
            load(&self.failed),
        );
        metric(
            "sedar_tasks_resumed_total",
            "counter",
            "Finished tasks recovered from the WAL, not executed here.",
            load(&self.resumed),
        );
        metric(
            "sedar_heartbeat_total",
            "counter",
            "Progress events (the stall-detection signal).",
            self.heartbeat.load(Ordering::SeqCst).to_string(),
        );
        metric(
            "sedar_compare_bytes_total",
            "counter",
            "Bytes compared between replicas across finished tasks.",
            self.compare_bytes.load(Ordering::SeqCst).to_string(),
        );
        metric(
            "sedar_ckpt_bytes_total",
            "counter",
            "Checkpoint bytes written (system + user) across finished tasks.",
            self.ckpt_bytes.load(Ordering::SeqCst).to_string(),
        );
        metric(
            "sedar_tasks_per_second",
            "gauge",
            "Finished-tasks rate over the board's lifetime.",
            format!("{:.3}", self.tasks_per_sec()),
        );
        s
    }
}

impl StatusSource for StatusBoard {
    fn text_snapshot(&self) -> String {
        StatusBoard::text_snapshot(self)
    }

    fn json_snapshot(&self) -> String {
        StatusBoard::json_snapshot(self)
    }

    fn prometheus_snapshot(&self) -> String {
        StatusBoard::prometheus_snapshot(self)
    }
}

/// The listener thread serving a [`StatusSource`]. Dropping the handle
/// stops the thread (it polls a stop flag between accepts).
pub struct StatusServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl StatusServer {
    /// Bind `127.0.0.1:port` (port 0 = OS-assigned; see [`StatusServer::addr`])
    /// and serve `board` until dropped.
    pub fn spawn(port: u16, board: Arc<dyn StatusSource>) -> Result<StatusServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .map_err(|e| SedarError::Config(format!("--status-port {port}: cannot bind: {e}")))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("sedar-status".into())
            .spawn(move || {
                loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // One request per connection; errors on a single
                            // connection never take the endpoint down.
                            let _ = serve_one(stream, board.as_ref());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            if stop_flag.load(Ordering::SeqCst) {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(_) => {
                            if stop_flag.load(Ordering::SeqCst) {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(25));
                        }
                    }
                }
            })?;
        Ok(StatusServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Hard cap on request bytes read before giving up on finding the end of
/// the request line (a client streaming garbage must not pin the thread).
const MAX_REQUEST: usize = 8 * 1024;

fn serve_one(mut stream: TcpStream, board: &dyn StatusSource) -> std::io::Result<()> {
    use std::io::{ErrorKind, Read, Write};
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    // Read until the request line is complete: a request split across TCP
    // segments must parse exactly like one that arrives whole (a single
    // fixed-size read() used to misroute segmented requests to the text
    // page). Bounded in bytes AND wall time — the accept loop serves
    // connections sequentially, so a byte-dribbling client must not pin
    // the endpoint (and thereby starve a supervisor's stall detector).
    let deadline = Instant::now() + Duration::from_secs(2);
    let mut buf: Vec<u8> = Vec::with_capacity(256);
    let mut chunk = [0u8; 512];
    while !buf.contains(&b'\n') && buf.len() < MAX_REQUEST && Instant::now() < deadline {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => break,
            Err(e) => return Err(e),
        }
    }
    let text = String::from_utf8_lossy(&buf);
    let target = text
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("/");
    // Route on the path component alone: `/json?since=3` is still /json.
    let path = target.split(['?', '#']).next().unwrap_or("/");
    let (status, content_type, body) = match path {
        "/" => ("200 OK", "text/plain; charset=utf-8", board.text_snapshot()),
        "/json" => ("200 OK", "application/json", board.json_snapshot()),
        "/metrics" => (
            "200 OK",
            "text/plain; version=0.0.4",
            board.prometheus_snapshot(),
        ),
        other => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            format!("no such path: {other} (try /, /json or /metrics)\n"),
        ),
    };
    let response = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Largest response a status/gateway client will buffer. Status bodies
/// are a few KiB and campaign reports tens of KiB; 1 MiB is an order of
/// magnitude of headroom, and past it the peer is misbehaving.
pub(crate) const MAX_RESPONSE: usize = 1 << 20;

/// Read a whole response to EOF under a hard wall-clock `deadline` and a
/// total-size `cap`. A naive `read_to_string` honors the socket's read
/// timeout only *per read*: a peer dribbling one byte per timeout window
/// can hold the caller hostage indefinitely (and an unbounded body can
/// balloon memory). Shared by [`http_get`] and the gateway's submission
/// client.
pub(crate) fn read_response(
    conn: &mut TcpStream,
    deadline: Instant,
    cap: usize,
) -> std::io::Result<Vec<u8>> {
    use std::io::Read;
    let mut raw = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "response deadline exceeded (server stalled mid-response)",
            ));
        }
        // Clamp the per-read window so the deadline check above runs at
        // least every 250 ms no matter how slowly bytes arrive; Some(ZERO)
        // is rejected by std, hence the 1 ms floor.
        conn.set_read_timeout(Some(
            left.min(Duration::from_millis(250))
                .max(Duration::from_millis(1)),
        ))?;
        match conn.read(&mut chunk) {
            Ok(0) => return Ok(raw),
            Ok(n) => {
                if raw.len() + n > cap {
                    return Err(std::io::Error::other(format!(
                        "response exceeds {cap} byte cap"
                    )));
                }
                raw.extend_from_slice(&chunk[..n]);
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e),
        }
    }
}

/// Split a raw HTTP response, returning the body iff the status line says
/// 200.
pub(crate) fn parse_ok_body(raw: &str) -> std::io::Result<String> {
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| std::io::Error::other("malformed HTTP response (no header break)"))?;
    let status_line = head.lines().next().unwrap_or("");
    if status_line.split_whitespace().nth(1) != Some("200") {
        return Err(std::io::Error::other(format!(
            "HTTP status not 200: {status_line}"
        )));
    }
    Ok(body.to_string())
}

/// Minimal std-only HTTP GET against a status endpoint: one HTTP/1.0
/// request, the whole response read to EOF, the body returned iff the
/// status line says 200. `timeout` bounds the **entire** exchange —
/// connect, write and all reads share one deadline — and the response is
/// capped at [`MAX_RESPONSE`] bytes, so one stalled or runaway endpoint
/// can never wedge the supervisor poll loop. The fleet supervisor's poll
/// path, the serve gateway's clients and the tests share this helper.
pub fn http_get(addr: SocketAddr, path: &str, timeout: Duration) -> std::io::Result<String> {
    use std::io::Write;
    let deadline = Instant::now() + timeout;
    let mut conn = TcpStream::connect_timeout(&addr, timeout)?;
    conn.set_write_timeout(Some(timeout))?;
    conn.write_all(format!("GET {path} HTTP/1.0\r\nConnection: close\r\n\r\n").as_bytes())?;
    let raw = read_response(&mut conn, deadline, MAX_RESPONSE)?;
    parse_ok_body(&String::from_utf8_lossy(&raw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::{build_tasks, CampaignSpec};

    fn sample_board() -> (StatusBoard, Vec<crate::campaign::CampaignTask>) {
        let mut spec = CampaignSpec::new(5);
        spec.apply_filter("scenario=1-2").unwrap();
        let tasks = build_tasks(&spec);
        (StatusBoard::new("shard 1/1", 5, &tasks), tasks)
    }

    fn fake_outcome(t: &crate::campaign::CampaignTask, pass: bool) -> TaskOutcome {
        TaskOutcome {
            index: t.index,
            scenario_id: t.scenario.id,
            app: t.app,
            strategy: t.strategy,
            collectives: t.collectives,
            validation: t.validation,
            netfault: t.netfault,
            faults: t.faults,
            completed: true,
            restarts: 0,
            injected: true,
            correct: Some(true),
            first_detection: None,
            last_resume: None,
            pass,
            mismatches: vec![],
            wall: Duration::ZERO,
            metrics: crate::metrics::MetricsSnapshot {
                compare_bytes: 100,
                sys_ckpt_bytes: 30,
                user_ckpt_bytes: 10,
                ..Default::default()
            },
        }
    }

    #[test]
    fn board_counts_and_snapshots() {
        let (board, tasks) = sample_board();
        board.record(&fake_outcome(&tasks[0], true));
        board.record(&fake_outcome(&tasks[1], false));
        let text = board.text_snapshot();
        assert!(text.contains("done 2/36"), "got: {text}");
        assert!(text.contains("pass 1, fail 1"), "got: {text}");
        let json = board.json_snapshot();
        assert!(json.contains("\"done\":2"), "got: {json}");
        assert!(json.contains("\"seed\":5"), "got: {json}");
        assert!(json.contains("\"app\":\"matmul\""), "got: {json}");
        // Work counters aggregate across finished tasks.
        assert!(json.contains("\"compare_bytes\":200"), "got: {json}");
        assert!(json.contains("\"ckpt_bytes\":80"), "got: {json}");
        assert!(json.contains("\"tasks_per_sec\":"), "got: {json}");
    }

    #[test]
    fn prometheus_snapshot_exposes_counters() {
        let (board, tasks) = sample_board();
        board.record(&fake_outcome(&tasks[0], true));
        board.record(&fake_outcome(&tasks[1], false));
        let prom = board.prometheus_snapshot();
        assert!(prom.contains("sedar_tasks_total 36"), "got: {prom}");
        assert!(prom.contains("sedar_tasks_done_total 2"), "got: {prom}");
        assert!(prom.contains("sedar_tasks_passed_total 1"), "got: {prom}");
        assert!(prom.contains("sedar_compare_bytes_total 200"), "got: {prom}");
        assert!(prom.contains("sedar_ckpt_bytes_total 80"), "got: {prom}");
        assert!(prom.contains("# TYPE sedar_tasks_done_total counter"), "got: {prom}");
        assert!(prom.contains("sedar_tasks_per_second "), "got: {prom}");
    }

    #[test]
    fn resumed_split_and_heartbeat_advance() {
        let (board, tasks) = sample_board();
        board.record_resumed(&fake_outcome(&tasks[0], true));
        let json = board.json_snapshot();
        assert!(json.contains("\"done\":1"), "got: {json}");
        assert!(json.contains("\"resumed\":1"), "got: {json}");
        assert!(json.contains("\"executed\":0"), "got: {json}");
        assert!(json.contains("\"heartbeat\":1"), "got: {json}");
        board.record(&fake_outcome(&tasks[1], true));
        board.record(&fake_outcome(&tasks[2], false));
        let json = board.json_snapshot();
        assert!(json.contains("\"resumed\":1"), "got: {json}");
        assert!(json.contains("\"executed\":2"), "got: {json}");
        // One tick per progress event, resumed or executed.
        assert!(json.contains("\"heartbeat\":3"), "got: {json}");
        let text = board.text_snapshot();
        assert!(text.contains("1 resumed, 2 executed"), "got: {text}");
    }

    #[test]
    fn endpoint_serves_text_and_json() {
        use std::io::{Read, Write};
        let (board, tasks) = sample_board();
        let board = Arc::new(board);
        board.record(&fake_outcome(&tasks[0], true));
        let server = StatusServer::spawn(0, board.clone()).unwrap();

        let fetch = |path: &str| -> String {
            let mut conn = TcpStream::connect(server.addr()).unwrap();
            conn.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())
                .unwrap();
            let mut out = String::new();
            conn.read_to_string(&mut out).unwrap();
            out
        };

        let text = fetch("/");
        assert!(text.starts_with("HTTP/1.0 200 OK"), "got: {text}");
        assert!(text.contains("done 1/36"), "got: {text}");
        let json = fetch("/json");
        assert!(json.contains("application/json"), "got: {json}");
        assert!(json.contains("\"done\":1"), "got: {json}");
        drop(server); // must join cleanly, not hang
    }

    #[test]
    fn segmented_requests_query_strings_and_404s() {
        use std::io::{Read, Write};
        let (board, tasks) = sample_board();
        let board = Arc::new(board);
        board.record(&fake_outcome(&tasks[0], true));
        let server = StatusServer::spawn(0, board.clone()).unwrap();

        // A request split across TCP segments must parse like a whole one
        // (the old single-read parser fell back to the text page here).
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(b"GET /js").unwrap();
        conn.flush().unwrap();
        std::thread::sleep(Duration::from_millis(30));
        conn.write_all(b"on HTTP/1.0\r\n\r\n").unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        assert!(out.contains("application/json"), "got: {out}");
        assert!(out.contains("\"done\":1"), "got: {out}");

        let fetch = |path: &str| -> String {
            let mut conn = TcpStream::connect(server.addr()).unwrap();
            conn.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())
                .unwrap();
            let mut out = String::new();
            conn.read_to_string(&mut out).unwrap();
            out
        };

        // The path component routes; query strings must not demote /json
        // to the text fallback.
        let json = fetch("/json?since=3");
        assert!(json.contains("application/json"), "got: {json}");
        assert!(json.contains("\"heartbeat\":"), "got: {json}");

        // Unknown paths are a 404, not silently the text page.
        let missing = fetch("/nope");
        assert!(missing.starts_with("HTTP/1.0 404"), "got: {missing}");

        // The Prometheus route serves the text exposition format.
        let prom = fetch("/metrics");
        assert!(prom.starts_with("HTTP/1.0 200 OK"), "got: {prom}");
        assert!(prom.contains("sedar_tasks_done_total 1"), "got: {prom}");

        // The std-only client helper round-trips against the same server.
        let body = http_get(server.addr(), "/json", Duration::from_secs(2)).unwrap();
        assert!(body.starts_with('{') && body.contains("\"done\":1"), "got: {body}");
        assert!(http_get(server.addr(), "/nope", Duration::from_secs(2)).is_err());
        drop(server);
    }

    #[test]
    fn http_get_bounds_a_stalled_server_by_the_total_deadline() {
        use std::io::Write;
        // A malicious/stuck server that dribbles one byte per 50 ms after
        // the headers, forever. Each dribble resets a naive per-read
        // timeout, so only a *total* deadline gets the client out.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            if let Ok((mut conn, _)) = listener.accept() {
                let _ = conn.write_all(b"HTTP/1.0 200 OK\r\nContent-Type: text/plain\r\n\r\n");
                while !stop2.load(Ordering::Relaxed) {
                    if conn.write_all(b"x").is_err() {
                        break;
                    }
                    let _ = conn.flush();
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        });

        let start = Instant::now();
        let err = http_get(addr, "/", Duration::from_millis(300)).unwrap_err();
        let elapsed = start.elapsed();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "got: {err}");
        assert!(
            elapsed < Duration::from_secs(3),
            "client hostage for {elapsed:?}"
        );
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn http_get_caps_a_runaway_response_body() {
        use std::io::Write;
        // A server that streams far past MAX_RESPONSE as fast as it can:
        // the client must give up at the cap instead of buffering it all.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            if let Ok((mut conn, _)) = listener.accept() {
                let _ = conn.write_all(b"HTTP/1.0 200 OK\r\nContent-Type: text/plain\r\n\r\n");
                let chunk = vec![b'y'; 64 * 1024];
                for _ in 0..40 {
                    // 2.5 MiB total, > the 1 MiB cap
                    if conn.write_all(&chunk).is_err() {
                        break;
                    }
                }
            }
        });

        let err = http_get(addr, "/", Duration::from_secs(5)).unwrap_err();
        assert!(err.to_string().contains("byte cap"), "got: {err}");
        server.join().unwrap();
    }
}
