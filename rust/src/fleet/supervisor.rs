//! The shard-set supervisor: spawn / poll / restart / stall logic for one
//! set of `sedar campaign --shard i/N` workers, extracted from the launch
//! driver so that both `fleet launch` (one sweep, all shards at once) and
//! the `sedar serve` gateway (many sweeps multiplexed onto a pooled worker
//! budget) drive the same supervision machinery.
//!
//! The pieces:
//!
//! * [`Spawner`] — how a shard process comes into being. The default
//!   [`LocalSpawner`] runs `Command::new(bin)` on this machine; the trait
//!   is the remote-spawn seam (an ssh spawner needs only "start this
//!   command, report exit, kill on demand" — the supervisor itself talks
//!   to shards exclusively through their WAL files and status endpoints,
//!   both of which already work across machines given a shared directory
//!   and reachable addresses);
//! * [`ShardHandle`] — one live incarnation: exit probing and kill. The
//!   [`ExitReport`] it yields carries a human-readable description so the
//!   supervisor's messages stay byte-identical to the pre-refactor ones
//!   for local children (`exit status: 0`, `signal: 9 (SIGKILL)`, …);
//! * [`ShardProc`] — one supervised shard across incarnations: its plan,
//!   expected WAL identity, restart budget accounting, status polling and
//!   stall detection;
//! * [`Supervisor`] — the shard set. `fleet launch` calls
//!   [`Supervisor::spawn_all`]; the gateway starts shards one at a time
//!   via [`Supervisor::start_next`] as pooled slots free up.
//!
//! Completion is judged by the WAL, never the exit code: a shard is done
//! when its log holds its whole slice ([`ShardProc::wal_complete`]), so
//! "died mid-sweep" and "finished but the report verdict failed" are
//! distinguishable. A shard whose WAL is already complete when it is
//! started (service restart adoption, or a re-launch over a finished
//! directory) is marked finished without spawning anything — resuming a
//! finished shard is provably free, so the supervisor does not even pay
//! the process.

use std::fs::OpenOptions;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Result, SedarError};

use super::plan::ShardPlan;
use super::snapshot::read_wal;
use super::status::http_get;
use super::wal::ShardMeta;

/// Per-poll timeout for one status GET (children live on loopback — a
/// healthy endpoint answers in microseconds, a dead one refuses at once).
const HTTP_TIMEOUT: Duration = Duration::from_millis(400);

/// How one shard incarnation ended. `describe` is what the supervisor
/// prints (`exit status: 1`, `signal: 9 (SIGKILL)`); keeping it a plain
/// string is what lets a mock (or a remote spawner, which has no
/// `std::process::ExitStatus` to show) report exits at all.
#[derive(Debug, Clone)]
pub struct ExitReport {
    pub success: bool,
    pub describe: String,
}

/// One live shard incarnation, however it was started.
pub trait ShardHandle: Send {
    /// Non-blocking exit probe: `Some` once the process is gone.
    fn try_wait(&mut self) -> Result<Option<ExitReport>>;
    /// Kill the process and reap it (best-effort; used on stalls and on
    /// supervisor teardown).
    fn kill_and_wait(&mut self);
    /// The worker's process id (observability: written to the pid file the
    /// e2e kill tests aim at; a remote spawner reports the remote pid).
    fn pid(&self) -> u32;
}

/// What every (re)spawn of any shard in the set shares: the resolved
/// binary, the campaign identity and the per-shard worker budget. The
/// per-shard half of the spawn (plan label, file paths) rides in the
/// [`ShardPlan`] and [`ShardPaths`] arguments.
#[derive(Debug, Clone)]
pub struct SpawnSpec {
    pub bin: PathBuf,
    pub seed: u64,
    pub jobs: usize,
    pub filter: Option<String>,
    pub scenario: Option<String>,
}

/// How shard processes come into being. Implementations must be cheap to
/// call repeatedly (relaunches) and must not block on the child's
/// lifetime.
pub trait Spawner: Send + Sync {
    fn spawn(
        &self,
        spec: &SpawnSpec,
        plan: &ShardPlan,
        paths: &ShardPaths,
    ) -> Result<Box<dyn ShardHandle>>;
}

/// The default spawner: a local `sedar campaign` child process with its
/// stdout/stderr appended to the shard's log file.
pub struct LocalSpawner;

struct LocalHandle(Child);

impl ShardHandle for LocalHandle {
    fn try_wait(&mut self) -> Result<Option<ExitReport>> {
        Ok(self.0.try_wait()?.map(|status| ExitReport {
            success: status.success(),
            describe: status.to_string(),
        }))
    }

    fn kill_and_wait(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }

    fn pid(&self) -> u32 {
        self.0.id()
    }
}

impl Spawner for LocalSpawner {
    fn spawn(
        &self,
        spec: &SpawnSpec,
        plan: &ShardPlan,
        paths: &ShardPaths,
    ) -> Result<Box<dyn ShardHandle>> {
        let log = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&paths.log)?;
        let mut cmd = Command::new(&spec.bin);
        cmd.arg("campaign")
            .arg("--seed")
            .arg(spec.seed.to_string())
            .arg("--jobs")
            .arg(spec.jobs.to_string())
            .arg("--shard")
            .arg(plan.label())
            .arg("--wal")
            .arg(&paths.wal)
            .arg("--status-port")
            .arg("0")
            .arg("--status-addr-file")
            .arg(&paths.addr)
            .arg("--run-dir")
            .arg(&paths.run_dir)
            .arg("--quiet");
        if let Some(f) = &spec.filter {
            cmd.arg("--filter").arg(f);
        }
        if let Some(k) = &spec.scenario {
            cmd.arg("--scenario").arg(k);
        }
        cmd.stdin(Stdio::null())
            .stdout(Stdio::from(log.try_clone()?))
            .stderr(Stdio::from(log));
        let child = cmd.spawn().map_err(|e| {
            SedarError::Config(format!(
                "fleet launch: cannot spawn shard {} ({}): {e}",
                plan.label(),
                spec.bin.display()
            ))
        })?;
        Ok(Box::new(LocalHandle(child)))
    }
}

/// Restart budget and stall policy for a shard set.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Relaunch budget per shard; exceeding it fails the sweep.
    pub max_restarts: usize,
    /// No heartbeat advance for this long ⇒ the shard is stalled and gets
    /// killed + relaunched. Must exceed the slowest single task.
    pub stall_timeout: Duration,
}

/// Shard-level scalars of one `/json` status snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Snapshot {
    pub(crate) done: usize,
    pub(crate) passed: usize,
    pub(crate) failed: usize,
    pub(crate) resumed: usize,
    pub(crate) executed: usize,
    pub(crate) heartbeat: u64,
}

/// First occurrence of `"key":<digits>` in `body`. The board emits every
/// shard-level scalar before the `cells` array, so the first occurrence is
/// always the shard-level value even though cells repeat `done`/`total`.
fn json_u64_field(body: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat)? + pat.len();
    let digits: String = body[at..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().ok()
}

impl Snapshot {
    fn parse(body: &str) -> Option<Snapshot> {
        Some(Snapshot {
            done: json_u64_field(body, "done")? as usize,
            passed: json_u64_field(body, "passed")? as usize,
            failed: json_u64_field(body, "failed")? as usize,
            resumed: json_u64_field(body, "resumed")? as usize,
            executed: json_u64_field(body, "executed")? as usize,
            heartbeat: json_u64_field(body, "heartbeat")?,
        })
    }
}

/// Where one shard's files live under its sweep directory.
pub struct ShardPaths {
    /// The shard's single durable file: its write-ahead log.
    pub wal: PathBuf,
    pub addr: PathBuf,
    pub pid: PathBuf,
    pub log: PathBuf,
    pub run_dir: PathBuf,
}

impl ShardPaths {
    pub fn new(dir: &Path, member: usize) -> ShardPaths {
        ShardPaths {
            wal: dir.join(format!("shard-{member}.wal")),
            addr: dir.join(format!("shard-{member}.addr")),
            pid: dir.join(format!("shard-{member}.pid")),
            log: dir.join(format!("shard-{member}.log")),
            run_dir: dir.join(format!("run-{member}")),
        }
    }
}

/// One supervised shard process (its current incarnation, if any).
pub struct ShardProc {
    pub(crate) plan: ShardPlan,
    pub(crate) owned: usize,
    pub(crate) expect: ShardMeta,
    pub(crate) paths: ShardPaths,
    pub(crate) child: Option<Box<dyn ShardHandle>>,
    pub(crate) restarts: usize,
    pub(crate) addr: Option<SocketAddr>,
    pub(crate) snap: Option<Snapshot>,
    pub(crate) last_heartbeat: Option<u64>,
    pub(crate) last_advance: Instant,
    pub(crate) started: bool,
    pub(crate) finished: bool,
    /// Last observed WAL byte length — the cheap change detector that
    /// gates re-reading the file into the live aggregate.
    pub(crate) wal_len: u64,
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        // An early supervisor exit (error path) must not leak children.
        if let Some(mut c) = self.child.take() {
            c.kill_and_wait();
        }
    }
}

impl ShardProc {
    pub(crate) fn new(plan: ShardPlan, owned: usize, expect: ShardMeta, paths: ShardPaths) -> Self {
        ShardProc {
            plan,
            owned,
            expect,
            paths,
            child: None,
            restarts: 0,
            addr: None,
            snap: None,
            last_heartbeat: None,
            last_advance: Instant::now(),
            started: false,
            finished: false,
            wal_len: 0,
        }
    }

    /// Spawn (or respawn) this shard's worker. The WAL path is stable
    /// across incarnations — that is what makes a relaunch a *resume*.
    fn spawn(&mut self, spawner: &dyn Spawner, spec: &SpawnSpec) -> Result<()> {
        let _ = std::fs::remove_file(&self.paths.addr);
        let child = spawner.spawn(spec, &self.plan, &self.paths)?;
        let pid = child.pid();
        // Track the handle before any further fallible step: a pid-file
        // write failure must fail the launch without orphaning the child
        // just spawned (Drop kills whatever `self.child` holds).
        self.child = Some(child);
        self.addr = None;
        self.last_heartbeat = None;
        self.last_advance = Instant::now();
        // The pid file is observability (and what the e2e kill tests aim
        // at), not control flow — the supervisor holds the handle.
        std::fs::write(&self.paths.pid, format!("{pid}\n"))?;
        Ok(())
    }

    /// Is this shard's WAL a complete record of its slice? (The completion
    /// criterion: exit codes alone cannot distinguish "died mid-sweep"
    /// from "finished but the report verdict failed".)
    pub(crate) fn wal_complete(&self) -> bool {
        match read_wal(&self.paths.wal) {
            Ok((meta, outcomes)) => meta == self.expect && outcomes.len() == self.owned,
            Err(_) => false,
        }
    }

    /// Bounded relaunch, or give up and fail the sweep.
    fn relaunch(&mut self, why: &str, spawner: &dyn Spawner, spec: &SpawnSpec, config: &SupervisorConfig) -> Result<()> {
        if self.restarts >= config.max_restarts {
            return Err(SedarError::Config(format!(
                "fleet launch: shard {} {why} and exhausted its restart budget \
                 ({}) — see {}",
                self.plan.label(),
                config.max_restarts,
                self.paths.log.display()
            )));
        }
        self.restarts += 1;
        eprintln!(
            "fleet: shard {} {why} — relaunch {}/{} (WAL replay skips finished tasks)",
            self.plan.label(),
            self.restarts,
            config.max_restarts
        );
        self.spawn(spawner, spec)
    }

    /// One supervision step: reap an exit, or poll status and check for a
    /// stall — relaunching as needed.
    fn step(&mut self, spawner: &dyn Spawner, spec: &SpawnSpec, config: &SupervisorConfig) -> Result<()> {
        let exited = match self.child.as_mut() {
            None => None,
            Some(c) => c.try_wait()?,
        };
        if let Some(report) = exited {
            self.child = None;
            if self.wal_complete() {
                self.finished = true;
                if !report.success {
                    eprintln!(
                        "fleet: shard {} finished its slice with a failing verdict \
                         ({}) — the merged report will carry it; see {}",
                        self.plan.label(),
                        report.describe,
                        self.paths.log.display()
                    );
                }
                return Ok(());
            }
            let why = format!("exited ({}) before its slice was durable", report.describe);
            return self.relaunch(&why, spawner, spec, config);
        }

        // Alive: learn the OS-assigned endpoint, then poll it.
        if self.addr.is_none() {
            if let Ok(s) = std::fs::read_to_string(&self.paths.addr) {
                self.addr = s.trim().parse().ok();
            }
        }
        if let Some(addr) = self.addr {
            if let Ok(body) = http_get(addr, "/json", HTTP_TIMEOUT) {
                if let Some(snap) = Snapshot::parse(&body) {
                    if self.last_heartbeat != Some(snap.heartbeat) {
                        self.last_heartbeat = Some(snap.heartbeat);
                        self.last_advance = Instant::now();
                    }
                    self.snap = Some(snap);
                }
            }
        }
        if self.last_advance.elapsed() > config.stall_timeout {
            if let Some(mut c) = self.child.take() {
                c.kill_and_wait();
            }
            let secs = config.stall_timeout.as_secs();
            let why = format!("stalled (no heartbeat advance in {secs}s)");
            return self.relaunch(&why, spawner, spec, config);
        }
        Ok(())
    }
}

/// The shard set: every [`ShardProc`] of one sweep plus the spawner and
/// policy they share.
pub struct Supervisor {
    shards: Vec<ShardProc>,
    spawner: Arc<dyn Spawner>,
    spec: SpawnSpec,
    config: SupervisorConfig,
}

impl Supervisor {
    pub fn new(
        shards: Vec<ShardProc>,
        spawner: Arc<dyn Spawner>,
        spec: SpawnSpec,
        config: SupervisorConfig,
    ) -> Supervisor {
        Supervisor {
            shards,
            spawner,
            spec,
            config,
        }
    }

    fn start_shard(&mut self, i: usize) -> Result<()> {
        self.shards[i].started = true;
        // Adoption short-circuit: a shard whose WAL already covers its
        // slice (service restart over a finished directory) has nothing
        // left to do — spawning a child just to replay and exit would be
        // correct but wasteful.
        if self.shards[i].wal_complete() {
            self.shards[i].finished = true;
            return Ok(());
        }
        let spawner = self.spawner.clone();
        self.shards[i].spawn(spawner.as_ref(), &self.spec)
    }

    /// Start every shard now (the `fleet launch` shape: one sweep gets the
    /// whole machine).
    pub fn spawn_all(&mut self) -> Result<()> {
        for i in 0..self.shards.len() {
            self.start_shard(i)?;
        }
        Ok(())
    }

    /// Start the first not-yet-started shard, if any (the pooled-gateway
    /// shape: one shard per free worker slot). Returns whether one was
    /// started (or adopted as already complete).
    pub fn start_next(&mut self) -> Result<bool> {
        for i in 0..self.shards.len() {
            if !self.shards[i].started {
                self.start_shard(i)?;
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// One supervision pass over every started, unfinished shard.
    pub fn step(&mut self) -> Result<()> {
        let spawner = self.spawner.clone();
        for p in self.shards.iter_mut() {
            if p.started && !p.finished {
                p.step(spawner.as_ref(), &self.spec, &self.config)?;
            }
        }
        Ok(())
    }

    /// Every shard's slice is durable.
    pub fn all_done(&self) -> bool {
        self.shards.iter().all(|p| p.finished)
    }

    /// Live child processes right now (what a pooled scheduler budgets).
    pub fn running(&self) -> usize {
        self.shards.iter().filter(|p| p.child.is_some()).count()
    }

    /// Shards not yet handed a worker slot.
    pub fn unstarted(&self) -> usize {
        self.shards.iter().filter(|p| !p.started).count()
    }

    pub fn total_restarts(&self) -> usize {
        self.shards.iter().map(|p| p.restarts).sum()
    }

    /// Kill every live child (sweep teardown on failure).
    pub fn kill_all(&mut self) {
        for p in self.shards.iter_mut() {
            if let Some(mut c) = p.child.take() {
                c.kill_and_wait();
            }
        }
    }

    pub(crate) fn shards(&self) -> &[ShardProc] {
        &self.shards
    }

    pub(crate) fn shards_mut(&mut self) -> &mut [ShardProc] {
        &mut self.shards
    }
}

/// Aggregate progress across the shard set, one line.
pub(crate) fn progress_line(fleet: &[ShardProc], total: usize) -> String {
    let mut done = 0usize;
    let mut passed = 0usize;
    let mut failed = 0usize;
    let mut restarts = 0usize;
    let mut parts = Vec::with_capacity(fleet.len());
    for p in fleet {
        let (d, pa, fa) = match &p.snap {
            Some(s) => (s.done, s.passed, s.failed),
            None => (0, 0, 0),
        };
        // A finished shard's last snapshot can be stale; its WAL is
        // complete by definition.
        let d = if p.finished { p.owned } else { d };
        done += d;
        passed += pa;
        failed += fa;
        restarts += p.restarts;
        let marker = if p.restarts > 0 {
            format!("(r{})", p.restarts)
        } else {
            String::new()
        };
        parts.push(format!("{}:{d}/{}{marker}", p.plan.label(), p.owned));
    }
    format!(
        "fleet: {done}/{total} task(s) done ({passed} pass, {failed} fail) \
         | {} | {restarts} restart(s)",
        parts.join(" ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn snapshot_parses_shard_level_scalars_not_cell_fields() {
        // A realistic board document: the cells repeat `done`/`total`/
        // `passed` keys with *different* values — the first (shard-level)
        // occurrence must win.
        let body = "{\"fleet\":\"shard 1/2\",\"seed\":7,\"total\":18,\"done\":5,\
                    \"passed\":4,\"failed\":1,\"executed\":3,\"resumed\":2,\
                    \"heartbeat\":5,\"cells\":[{\"app\":\"matmul\",\
                    \"strategy\":\"sys-ckpt\",\"total\":9,\"done\":9,\"passed\":9}]}";
        let s = Snapshot::parse(body).unwrap();
        assert_eq!(s.done, 5);
        assert_eq!(s.passed, 4);
        assert_eq!(s.failed, 1);
        assert_eq!(s.executed, 3);
        assert_eq!(s.resumed, 2);
        assert_eq!(s.heartbeat, 5);
    }

    #[test]
    fn snapshot_parse_rejects_incomplete_documents() {
        // A pre-extension snapshot (no heartbeat/resumed fields) must not
        // parse into zeros that defeat stall detection.
        let old = "{\"fleet\":\"shard 1/2\",\"seed\":7,\"total\":18,\"done\":5,\
                   \"passed\":4,\"failed\":1,\"cells\":[]}";
        assert!(Snapshot::parse(old).is_none());
        assert!(Snapshot::parse("").is_none());
        assert!(Snapshot::parse("not json at all").is_none());
    }

    fn meta(i: u32, count: u32, total: u64) -> ShardMeta {
        ShardMeta {
            seed: 1,
            shard_index: i,
            shard_count: count,
            total_tasks: total,
            spec_hash: 0xFEED,
        }
    }

    #[test]
    fn progress_line_aggregates_and_marks_restarts() {
        let dir = std::env::temp_dir();
        let mk = |i: usize, snap: Option<Snapshot>, restarts: usize, finished: bool| {
            let mut p = ShardProc::new(
                ShardPlan { index: i, count: 2 },
                5,
                meta(i as u32, 2, 10),
                ShardPaths::new(&dir, i + 1),
            );
            p.snap = snap;
            p.restarts = restarts;
            p.finished = finished;
            p
        };
        let fleet = vec![
            mk(
                0,
                Some(Snapshot {
                    done: 3,
                    passed: 2,
                    failed: 1,
                    resumed: 0,
                    executed: 3,
                    heartbeat: 3,
                }),
                1,
                false,
            ),
            mk(1, None, 0, true),
        ];
        let line = progress_line(&fleet, 10);
        assert!(line.contains("8/10"), "got: {line}");
        assert!(line.contains("1/2:3/5(r1)"), "got: {line}");
        assert!(line.contains("2/2:5/5"), "got: {line}");
        assert!(line.contains("1 restart(s)"), "got: {line}");
    }

    /// A scripted spawner: every spawn yields a handle that reports the
    /// same exit immediately. This is the remote-spawn seam under test —
    /// the supervisor never touches `std::process` through it.
    struct MockSpawner {
        spawned: AtomicUsize,
        success: bool,
    }

    struct MockHandle {
        report: ExitReport,
    }

    impl ShardHandle for MockHandle {
        fn try_wait(&mut self) -> Result<Option<ExitReport>> {
            Ok(Some(self.report.clone()))
        }
        fn kill_and_wait(&mut self) {}
        fn pid(&self) -> u32 {
            4242
        }
    }

    impl Spawner for MockSpawner {
        fn spawn(
            &self,
            _spec: &SpawnSpec,
            _plan: &ShardPlan,
            _paths: &ShardPaths,
        ) -> Result<Box<dyn ShardHandle>> {
            self.spawned.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(MockHandle {
                report: ExitReport {
                    success: self.success,
                    describe: "mock exit".into(),
                },
            }))
        }
    }

    fn test_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "sedar-supervisor-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn test_spec() -> SpawnSpec {
        SpawnSpec {
            bin: PathBuf::from("sedar-mock"),
            seed: 1,
            jobs: 1,
            filter: None,
            scenario: None,
        }
    }

    #[test]
    fn mock_spawner_relaunches_until_budget_exhausted() {
        let dir = test_dir("budget");
        // An "exit status: 0" child whose WAL never covers its slice must
        // still be relaunched — exit codes are not the completion signal.
        let spawner = Arc::new(MockSpawner {
            spawned: AtomicUsize::new(0),
            success: true,
        });
        let shard = ShardProc::new(
            ShardPlan { index: 0, count: 1 },
            4,
            meta(0, 1, 4),
            ShardPaths::new(&dir, 1),
        );
        let mut sup = Supervisor::new(
            vec![shard],
            spawner.clone(),
            test_spec(),
            SupervisorConfig {
                max_restarts: 2,
                stall_timeout: Duration::from_secs(300),
            },
        );
        sup.spawn_all().unwrap();
        assert_eq!(spawner.spawned.load(Ordering::SeqCst), 1);
        assert_eq!(sup.running(), 1);
        // Each step reaps the scripted exit, finds the WAL incomplete and
        // respawns through the trait — until the budget runs out.
        sup.step().unwrap();
        assert_eq!(sup.shards()[0].restarts, 1);
        sup.step().unwrap();
        assert_eq!(sup.shards()[0].restarts, 2);
        assert_eq!(spawner.spawned.load(Ordering::SeqCst), 3);
        let err = sup.step().unwrap_err().to_string();
        assert!(err.contains("exhausted its restart budget (2)"), "got: {err}");
        assert!(err.contains("before its slice was durable"), "got: {err}");
        assert!(!sup.all_done());
        // The pid file recorded the mock's pid — the seam carries
        // observability too.
        let pid = std::fs::read_to_string(dir.join("shard-1.pid")).unwrap();
        assert_eq!(pid.trim(), "4242");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn complete_wal_short_circuits_the_spawn() {
        use crate::fleet::wal::Wal;
        let dir = test_dir("adopt");
        let expect = meta(0, 1, 0);
        let paths = ShardPaths::new(&dir, 1);
        // A WAL that already covers the shard's (empty) slice: header only.
        let (mut w, prior) = Wal::open(&paths.wal, &expect).unwrap();
        assert!(prior.is_empty());
        w.finalize().unwrap();
        drop(w);

        let spawner = Arc::new(MockSpawner {
            spawned: AtomicUsize::new(0),
            success: true,
        });
        let shard = ShardProc::new(ShardPlan { index: 0, count: 1 }, 0, expect, paths);
        let mut sup = Supervisor::new(
            vec![shard],
            spawner.clone(),
            test_spec(),
            SupervisorConfig {
                max_restarts: 2,
                stall_timeout: Duration::from_secs(300),
            },
        );
        assert_eq!(sup.unstarted(), 1);
        assert!(sup.start_next().unwrap());
        // Adopted as finished: no process was ever spawned.
        assert_eq!(spawner.spawned.load(Ordering::SeqCst), 0);
        assert!(sup.all_done());
        assert_eq!(sup.running(), 0);
        // Nothing left to start.
        assert!(!sup.start_next().unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
