//! Deterministic shard plans: which slice of the canonical task list a
//! fleet member owns.
//!
//! A plan is `i/N` — member `i` of an `N`-way split (1-based on the command
//! line, 0-based internally). Ownership is round-robin over the canonical
//! task index: shard `i` owns every task whose `index % N == i - 1`. Round-
//! robin (rather than contiguous ranges) interleaves scenarios, apps and
//! strategies across shards, so every shard carries a representative mix
//! and the slowest cells (TOE scenarios, multi-fault cells) spread evenly
//! instead of landing on one unlucky member.
//!
//! The plan is a pure function of `(task index, i, N)` — no coordination,
//! no state — which is what lets N processes on N machines partition one
//! sweep with nothing shared but the spec.

use crate::campaign::CampaignTask;
use crate::error::{Result, SedarError};

/// One member's slice of an `N`-way split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// 0-based member index (`< count`).
    pub index: usize,
    /// Total members in the split (≥ 1).
    pub count: usize,
}

impl ShardPlan {
    /// The trivial plan: one member owning everything.
    pub fn full() -> ShardPlan {
        ShardPlan { index: 0, count: 1 }
    }

    /// Parse the CLI form `i/N` (1-based `i`, e.g. `--shard 2/4`).
    pub fn parse(s: &str) -> Result<ShardPlan> {
        let bad = |why: &str| {
            SedarError::Config(format!("shard '{s}': {why} (expected i/N, e.g. 2/4)"))
        };
        let (i, n) = s.trim().split_once('/').ok_or_else(|| bad("missing '/'"))?;
        let i: usize = i.trim().parse().map_err(|_| bad("bad member index"))?;
        let n: usize = n.trim().parse().map_err(|_| bad("bad member count"))?;
        if n == 0 {
            return Err(bad("member count must be >= 1"));
        }
        if i == 0 || i > n {
            return Err(bad("member index is 1-based and must be <= N"));
        }
        Ok(ShardPlan {
            index: i - 1,
            count: n,
        })
    }

    /// The CLI/display form (1-based).
    pub fn label(&self) -> String {
        format!("{}/{}", self.index + 1, self.count)
    }

    /// Does this member own canonical task index `task_index`?
    pub fn owns(&self, task_index: usize) -> bool {
        task_index % self.count == self.index
    }

    /// This member's slice of the canonical task list, in task order.
    pub fn slice(&self, tasks: &[CampaignTask]) -> Vec<CampaignTask> {
        tasks
            .iter()
            .filter(|t| self.owns(t.index))
            .cloned()
            .collect()
    }
}

impl std::fmt::Display for ShardPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::{build_tasks, CampaignSpec};

    #[test]
    fn parse_accepts_one_based_forms() {
        assert_eq!(ShardPlan::parse("1/1").unwrap(), ShardPlan::full());
        assert_eq!(
            ShardPlan::parse(" 2/4 ").unwrap(),
            ShardPlan { index: 1, count: 4 }
        );
        assert_eq!(ShardPlan::parse("4/4").unwrap().label(), "4/4");
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "2", "0/4", "5/4", "a/4", "2/b", "2/0", "-1/4"] {
            assert!(ShardPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn every_split_partitions_the_sweep() {
        let mut spec = CampaignSpec::new(3);
        spec.apply_filter("scenario=1-8").unwrap();
        let tasks = build_tasks(&spec);
        for n in 1..=7usize {
            let mut seen = vec![0u32; tasks.len()];
            for i in 0..n {
                let plan = ShardPlan { index: i, count: n };
                for t in plan.slice(&tasks) {
                    seen[t.index] += 1;
                }
            }
            assert!(
                seen.iter().all(|&c| c == 1),
                "split {n}: tasks not covered exactly once: {seen:?}"
            );
        }
    }

    #[test]
    fn round_robin_interleaves_cells() {
        let spec = CampaignSpec::new(3);
        let tasks = build_tasks(&spec);
        let plan = ShardPlan { index: 0, count: 2 };
        let slice = plan.slice(&tasks);
        // Each shard of a 2-way split sees every app and every strategy.
        for app in crate::campaign::CampaignApp::ALL {
            assert!(slice.iter().any(|t| t.app == app), "missing {app:?}");
        }
        for s in crate::campaign::STRATEGIES {
            assert!(slice.iter().any(|t| t.strategy == s), "missing {s:?}");
        }
    }
}
