//! One sweep as an owned object: a submission's plan, WAL directory, live
//! [`IncrementalMerger`] aggregate and lifecycle state.
//!
//! `fleet launch` runs exactly one [`Sweep`] and blocks on it; the
//! `sedar serve` gateway owns many at once and advances each a step at a
//! time from its scheduler loop. Both get the same invariants:
//!
//! * the sweep's durable state is its directory — one WAL per shard — so
//!   re-creating a `Sweep` over an existing directory *is* crash recovery
//!   (complete shards are adopted without spawning, partial ones resume
//!   via WAL replay);
//! * the live aggregate is the **same** [`IncrementalMerger`] that renders
//!   the final report, so "live view at completion" and "final report"
//!   cannot disagree;
//! * the final report is byte-identical to the single-process
//!   `sedar campaign` run of the same spec (the merge invariant the fleet
//!   layer has carried since PR 2).
//!
//! Lifecycle: queued → running → merged | failed. The state is
//! advisory — transitions are driven by the owner calling
//! [`Sweep::start_all`]/[`Sweep::start_one`], [`Sweep::poll`] and
//! [`Sweep::finalize`] — but it is what the gateway reports per
//! submission.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::campaign::aggregate::IncrementalMerger;
use crate::campaign::shard::TaskOutcome;
use crate::campaign::{build_tasks, sweep_fingerprint, CampaignReport, CampaignSpec};
use crate::error::{Result, SedarError};

use super::plan::ShardPlan;
use super::snapshot::read_wal;
use super::status::StatusSource;
use super::supervisor::{
    ShardPaths, ShardProc, SpawnSpec, Spawner, Supervisor, SupervisorConfig,
};
use super::wal::ShardMeta;

/// The sweep-wide live partial aggregate: one [`IncrementalMerger`] re-fed
/// from each shard's WAL as it grows.
///
/// Ingest is idempotent per shard (a re-read *replaces* that shard's
/// outcome set), so the supervisor can refresh as often as it likes; the
/// WAL reader is lenient about a racing writer's torn tail, so the refresh
/// never needs a lock against the children. When the sweep completes, the
/// **same** merger renders the final report — the "live aggregate at
/// completion equals the final report" invariant holds by construction,
/// not by comparison.
pub struct FleetAggregate {
    total: usize,
    merger: Mutex<IncrementalMerger>,
}

impl FleetAggregate {
    pub fn new(first: ShardMeta, total: usize) -> FleetAggregate {
        FleetAggregate {
            total,
            merger: Mutex::new(IncrementalMerger::new(first)),
        }
    }

    /// Best-effort live refresh from one shard's WAL. A file that is
    /// missing, mid-creation or identity-drifted is skipped — the strict
    /// final ingest surfaces real problems with real errors.
    pub fn refresh(&self, path: &Path) {
        if let Ok((meta, outcomes)) = read_wal(path) {
            let _ = self.merger.lock().unwrap().ingest(&meta, outcomes);
        }
    }

    /// Strict ingest (the final-merge path): every error is fatal.
    pub fn ingest(&self, meta: &ShardMeta, outcomes: Vec<TaskOutcome>) -> Result<()> {
        self.merger.lock().unwrap().ingest(meta, outcomes)
    }

    /// Distinct finished tasks in the current union.
    pub fn done(&self) -> usize {
        self.merger.lock().unwrap().done()
    }

    /// Render the final report, requiring full coverage.
    pub fn final_report(&self) -> Result<CampaignReport> {
        let merger = self.merger.lock().unwrap();
        if merger.done() != self.total {
            return Err(SedarError::Config(format!(
                "fleet launch: merged union covers {} of {} task(s) — \
                 a shard WAL is incomplete",
                merger.done(),
                self.total
            )));
        }
        merger.report()
    }
}

impl StatusSource for FleetAggregate {
    fn text_snapshot(&self) -> String {
        let m = self.merger.lock().unwrap();
        let mut s = format!(
            "SEDAR fleet launch seed {}\ndone {}/{} (pass {}, fail {}) — {}\n",
            m.seed(),
            m.done(),
            self.total,
            m.passed(),
            m.failed(),
            if m.done() == self.total {
                "complete"
            } else {
                "partial union of live WALs"
            }
        );
        for (shard, done) in m.shard_progress() {
            s.push_str(&format!("  shard {}: {done} outcome(s)\n", shard + 1));
        }
        s
    }

    fn json_snapshot(&self) -> String {
        let m = self.merger.lock().unwrap();
        let shards: Vec<String> = m
            .shard_progress()
            .iter()
            .map(|(shard, done)| format!("{{\"shard\":{},\"done\":{done}}}", shard + 1))
            .collect();
        format!(
            "{{\"fleet\":\"launch\",\"seed\":{},\"total\":{},\"done\":{},\
             \"passed\":{},\"failed\":{},\"complete\":{},\"shards\":[{}]}}",
            m.seed(),
            self.total,
            m.done(),
            m.passed(),
            m.failed(),
            m.done() == self.total,
            shards.join(",")
        )
    }

    fn prometheus_snapshot(&self) -> String {
        let m = self.merger.lock().unwrap();
        let mut s = String::new();
        let mut metric = |name: &str, kind: &str, help: &str, value: String| {
            s.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
            ));
        };
        metric(
            "sedar_fleet_tasks_total",
            "gauge",
            "Tasks in the whole sweep across all shards.",
            self.total.to_string(),
        );
        metric(
            "sedar_fleet_tasks_done_total",
            "counter",
            "Distinct finished tasks across the live WAL union.",
            m.done().to_string(),
        );
        metric(
            "sedar_fleet_tasks_passed_total",
            "counter",
            "Finished tasks that passed their cell's oracle.",
            m.passed().to_string(),
        );
        metric(
            "sedar_fleet_tasks_failed_total",
            "counter",
            "Finished tasks that mismatched their cell's oracle.",
            m.failed().to_string(),
        );
        metric(
            "sedar_fleet_complete",
            "gauge",
            "1 once the union covers every task of the sweep.",
            if m.done() == self.total { "1" } else { "0" }.to_string(),
        );
        s
    }
}

/// Where a sweep is in its life. `Failed` carries the operator-facing
/// reason (restart budget exhausted, identity drift, …).
#[derive(Debug, Clone, PartialEq)]
pub enum SweepState {
    Queued,
    Running,
    Merged,
    Failed(String),
}

impl SweepState {
    pub fn label(&self) -> &'static str {
        match self {
            SweepState::Queued => "queued",
            SweepState::Running => "running",
            SweepState::Merged => "merged",
            SweepState::Failed(_) => "failed",
        }
    }
}

/// What defines a sweep: the campaign identity plus how to split it.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub seed: u64,
    /// Number of shard processes (the `N` of `--shard i/N`).
    pub shards: usize,
    /// Worker threads per shard (`0` = split the machine's default budget
    /// evenly across the shards, at least 1 each).
    pub jobs: usize,
    pub filter: Option<String>,
    pub scenario: Option<String>,
}

/// One sweep: its plan, directory, supervisor and live aggregate.
pub struct Sweep {
    config: SweepConfig,
    dir: PathBuf,
    total: usize,
    jobs: usize,
    aggregate: Arc<FleetAggregate>,
    supervisor: Supervisor,
    state: SweepState,
}

impl Sweep {
    /// Plan a sweep over `dir`, creating the directory if needed. Building
    /// over a directory with existing WALs is the resume/adoption path —
    /// complete shards will be marked finished without spawning anything
    /// when started.
    pub fn new(
        config: SweepConfig,
        dir: PathBuf,
        bin: Option<PathBuf>,
        sup: SupervisorConfig,
        spawner: Arc<dyn Spawner>,
    ) -> Result<Sweep> {
        if config.shards == 0 {
            return Err(SedarError::Config(
                "fleet launch: --shards must be >= 1".into(),
            ));
        }
        // Build the spec exactly as every child will, so the supervisor
        // knows each slice's size and identity (and can verify WALs
        // against the same sweep fingerprint the children stamp into
        // them).
        let mut spec = CampaignSpec::new(config.seed);
        if let Some(f) = &config.filter {
            spec.apply_filter(f)?;
        }
        if let Some(k) = &config.scenario {
            spec.apply_filter(&format!("scenario={k}"))?;
        }
        let tasks = build_tasks(&spec);
        if tasks.is_empty() {
            return Err(SedarError::Config(
                "campaign filter selects no tasks".into(),
            ));
        }
        let total = tasks.len();
        let fingerprint = sweep_fingerprint(config.seed, &tasks);
        std::fs::create_dir_all(&dir)?;
        let bin = match bin {
            Some(b) => b,
            None => std::env::current_exe()?,
        };
        let jobs = if config.jobs > 0 {
            config.jobs
        } else {
            (CampaignSpec::default_jobs() / config.shards).max(1)
        };

        let shards: Vec<ShardProc> = (0..config.shards)
            .map(|i| {
                let plan = ShardPlan {
                    index: i,
                    count: config.shards,
                };
                ShardProc::new(
                    plan,
                    plan.slice(&tasks).len(),
                    ShardMeta {
                        seed: config.seed,
                        shard_index: i as u32,
                        shard_count: config.shards as u32,
                        total_tasks: total as u64,
                        spec_hash: fingerprint,
                    },
                    ShardPaths::new(&dir, i + 1),
                )
            })
            .collect();

        // The live partial aggregate spans the whole sweep; seed its
        // identity from shard 1's expected header (every shard must match
        // it anyway).
        let aggregate = Arc::new(FleetAggregate::new(shards[0].expect, total));
        let spec = SpawnSpec {
            bin,
            seed: config.seed,
            jobs,
            filter: config.filter.clone(),
            scenario: config.scenario.clone(),
        };
        Ok(Sweep {
            config,
            dir,
            total,
            jobs,
            aggregate,
            supervisor: Supervisor::new(shards, spawner, spec, sup),
            state: SweepState::Queued,
        })
    }

    pub fn config(&self) -> &SweepConfig {
        &self.config
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Tasks in the whole sweep.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Resolved worker threads per shard.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    pub fn state(&self) -> &SweepState {
        &self.state
    }

    /// The live aggregate, shareable with a status server.
    pub fn aggregate(&self) -> Arc<FleetAggregate> {
        self.aggregate.clone()
    }

    pub(crate) fn supervisor(&self) -> &Supervisor {
        &self.supervisor
    }

    /// Live shard processes right now.
    pub fn running(&self) -> usize {
        self.supervisor.running()
    }

    /// Shards not yet handed a worker slot.
    pub fn unstarted(&self) -> usize {
        self.supervisor.unstarted()
    }

    pub fn total_restarts(&self) -> usize {
        self.supervisor.total_restarts()
    }

    /// Start every shard now (the `fleet launch` shape).
    pub fn start_all(&mut self) -> Result<()> {
        self.supervisor.spawn_all()?;
        self.state = SweepState::Running;
        Ok(())
    }

    /// Start one more shard if any remain unstarted (the pooled-gateway
    /// shape). Returns whether one was started.
    pub fn start_one(&mut self) -> Result<bool> {
        let started = self.supervisor.start_next()?;
        if started {
            self.state = SweepState::Running;
        }
        Ok(started)
    }

    /// One supervision pass plus a live-aggregate refresh for every shard
    /// whose WAL grew since the last poll.
    pub fn poll(&mut self) -> Result<()> {
        self.supervisor.step()?;
        for p in self.supervisor.shards_mut() {
            let len = std::fs::metadata(&p.paths.wal)
                .map(|m| m.len())
                .unwrap_or(0);
            if len != p.wal_len {
                p.wal_len = len;
                self.aggregate.refresh(&p.paths.wal);
            }
        }
        Ok(())
    }

    /// Every shard's slice is durable.
    pub fn done(&self) -> bool {
        self.supervisor.all_done()
    }

    /// Final merge: one last STRICT ingest of each WAL into the same
    /// merger the live aggregate used all along — identity drift and
    /// overlap are re-verified here with real errors, and the coverage
    /// check in [`FleetAggregate::final_report`] is the completeness half.
    /// Because it is the same object, "live aggregate at completion" and
    /// "final report" cannot disagree.
    pub fn finalize(&mut self) -> Result<CampaignReport> {
        for p in self.supervisor.shards() {
            let (meta, outcomes) = read_wal(&p.paths.wal)?;
            self.aggregate.ingest(&meta, outcomes)?;
        }
        let report = self.aggregate.final_report()?;
        self.state = SweepState::Merged;
        Ok(report)
    }

    /// Tear the sweep down as failed: kill every live shard, record why.
    pub fn fail(&mut self, why: String) {
        self.supervisor.kill_all();
        self.state = SweepState::Failed(why);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fleet_aggregate_serves_partial_then_complete_unions() {
        let meta = |shard_index: u32| ShardMeta {
            seed: 9,
            shard_index,
            shard_count: 2,
            total_tasks: 2,
            spec_hash: 0xABCD,
        };
        let outcome = |index: usize, pass: bool| TaskOutcome {
            index,
            scenario_id: index as u32,
            app: crate::campaign::CampaignApp::Matmul,
            strategy: crate::config::Strategy::SysCkpt,
            collectives: crate::config::CollectiveImpl::PointToPoint,
            validation: crate::detect::ValidationMode::Full,
            netfault: crate::faultnet::NetFaultMode::None,
            faults: 1,
            completed: true,
            restarts: 0,
            injected: true,
            correct: Some(pass),
            first_detection: None,
            last_resume: None,
            pass,
            mismatches: vec![],
            wall: Duration::ZERO,
            metrics: Default::default(),
        };

        let agg = FleetAggregate::new(meta(0), 2);
        agg.ingest(&meta(0), vec![outcome(0, true)]).unwrap();

        // Mid-flight: a well-formed partial union.
        let json = agg.json_snapshot();
        assert!(json.contains("\"fleet\":\"launch\""), "got: {json}");
        assert!(json.contains("\"done\":1"), "got: {json}");
        assert!(json.contains("\"total\":2"), "got: {json}");
        assert!(json.contains("\"complete\":false"), "got: {json}");
        let text = agg.text_snapshot();
        assert!(text.contains("partial union"), "got: {text}");
        assert!(agg.final_report().is_err(), "partial must not finalize");

        // Completion: the same merger renders the final report.
        agg.ingest(&meta(1), vec![outcome(1, false)]).unwrap();
        let json = agg.json_snapshot();
        assert!(json.contains("\"complete\":true"), "got: {json}");
        assert!(json.contains("\"failed\":1"), "got: {json}");
        let prom = agg.prometheus_snapshot();
        assert!(prom.contains("sedar_fleet_complete 1"), "got: {prom}");
        assert!(prom.contains("sedar_fleet_tasks_done_total 2"), "got: {prom}");
        let report = agg.final_report().unwrap();
        assert_eq!(report.total(), 2);
        assert_eq!(report.failed(), 1);
    }

    #[test]
    fn sweep_lifecycle_labels_and_rejections() {
        assert_eq!(SweepState::Queued.label(), "queued");
        assert_eq!(SweepState::Running.label(), "running");
        assert_eq!(SweepState::Merged.label(), "merged");
        assert_eq!(SweepState::Failed("x".into()).label(), "failed");

        let sup = SupervisorConfig {
            max_restarts: 1,
            stall_timeout: Duration::from_secs(300),
        };
        let dir = std::env::temp_dir().join(format!(
            "sedar-sweep-reject-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        // Zero shards and empty filters are rejected before any file or
        // process side effects.
        let cfg = SweepConfig {
            seed: 1,
            shards: 0,
            jobs: 1,
            filter: None,
            scenario: None,
        };
        let err = Sweep::new(
            cfg,
            dir.clone(),
            None,
            sup.clone(),
            Arc::new(super::super::supervisor::LocalSpawner),
        )
        .unwrap_err();
        assert!(err.to_string().contains("--shards must be >= 1"), "got: {err}");
        let cfg = SweepConfig {
            seed: 1,
            shards: 2,
            jobs: 1,
            filter: Some("scenario=999".into()),
            scenario: None,
        };
        let err = Sweep::new(
            cfg,
            dir.clone(),
            None,
            sup,
            Arc::new(super::super::supervisor::LocalSpawner),
        )
        .unwrap_err();
        assert!(err.to_string().contains("no tasks"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
