//! The fleet layer: scaling the campaign sweep beyond one process.
//!
//! The paper's validation argument is exhaustive — *every* fault scenario,
//! on every application, under every protection level (§4.1–§4.2). The
//! [`crate::campaign`] engine made that sweep parallel inside one process;
//! this module makes it **sharded, durable and resumable** across
//! processes and machines:
//!
//! * [`plan`] — deterministic `i/N` partitions of the canonical task list
//!   (pure round-robin over task indices: no coordination, no shared
//!   state);
//! * [`wal`] — **one** durable file per shard: an append-only,
//!   CRC-framed write-ahead log (`SDWL`) that records each
//!   [`TaskOutcome`] as it finishes, SEDAR-level-2 style — the sweep
//!   checkpointing itself;
//! * [`snapshot`] — the WAL read side: periodic compaction snapshots (the
//!   watermark readers resume from), the single lenient replay path that
//!   resume, merge, completeness probing and live aggregation all share,
//!   and the streaming shard merge;
//! * [`status`] — a std-only TCP endpoint serving live progress snapshots
//!   for long sweeps;
//! * [`supervisor`] — spawn / poll / restart / stall machinery for one
//!   shard set, generic over a [`supervisor::Spawner`] (local `Command`
//!   today, the ssh remote-spawn seam tomorrow);
//! * [`sweep`] — one submission as an owned object: plan, WAL directory,
//!   live [`sweep::FleetAggregate`] and queued → running → merged/failed
//!   lifecycle — `fleet launch` drives one, the `sedar serve` gateway
//!   multiplexes many.
//!
//! The end-to-end invariant (enforced by
//! `rust/tests/fleet_shard_equivalence.rs` and the CI sharded-sweep job):
//! splitting a sweep into any `N` shards, merging the WALs and rendering
//! produces a report **byte-identical** to the single-process run with the
//! same `--seed`. Task outcomes are pure functions of task seeds, and task
//! seeds never see shard geometry — sharding is pure partition, so
//! redundancy plus durable intermediate state turns one validation run
//! into a guarantee that survives interruption.

pub mod launch;
pub mod plan;
pub mod snapshot;
pub mod status;
pub mod supervisor;
pub mod sweep;
pub mod wal;

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::campaign::shard::TaskOutcome;
use crate::campaign::{
    aggregate, build_tasks, collective_label, scheduler, sweep_fingerprint, validation_label,
    CampaignSpec, CampaignTask,
};
use crate::error::{Result, SedarError};

use plan::ShardPlan;
use status::{StatusBoard, StatusServer};
use wal::{ShardMeta, Wal};

/// Fsync the directory holding `path`, so a crash right after a file is
/// created (or renamed into place) cannot lose the *directory entry* —
/// per-record `sync_data` protects the WAL's bytes, but until the
/// parent directory is synced the file's name itself is volatile. Unix
/// only; elsewhere this is a no-op (NTFS journals metadata itself).
pub(crate) fn sync_parent_dir(path: &std::path::Path) -> Result<()> {
    #[cfg(unix)]
    {
        let parent = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => std::path::Path::new("."),
        };
        std::fs::File::open(parent)?.sync_all()?;
    }
    #[cfg(not(unix))]
    {
        let _ = path;
    }
    Ok(())
}

/// How a shard run is wired to the world (all optional — the defaults are
/// a plain single-process sweep).
#[derive(Debug, Clone, Default)]
pub struct FleetOptions {
    /// This member's slice (`None` = the full sweep, i.e. plan `1/1`).
    pub plan: Option<ShardPlan>,
    /// The shard's write-ahead log: completed tasks are appended here as
    /// they finish, and if the file already holds this sweep's records the
    /// run resumes from them instead of re-executing. One file is the
    /// shard's entire durable footprint — resume, merge and the live
    /// aggregate all read it.
    pub wal_path: Option<PathBuf>,
    /// Serve live progress on `127.0.0.1:port` while the sweep runs
    /// (port 0 = OS-assigned).
    pub status_port: Option<u16>,
    /// After the status server binds, atomically write its actual address
    /// (`127.0.0.1:port`) to this file — the handshake that lets a
    /// supervisor ([`launch`]) find a child whose port was OS-assigned.
    pub status_addr_file: Option<PathBuf>,
}

/// What a finished shard run reports back.
pub struct ShardRun {
    pub plan: ShardPlan,
    /// Tasks this shard owns (its slice of the canonical list).
    pub owned: usize,
    /// Outcomes recovered from the WAL and *not* re-executed.
    pub resumed: usize,
    /// Tasks actually executed in this process.
    pub executed: usize,
    /// The shard's complete outcome set (resumed ∪ executed), task order.
    pub outcomes: Vec<TaskOutcome>,
    /// Where the durable WAL lives, if one was written.
    pub wal_path: Option<PathBuf>,
}

impl ShardRun {
    /// One-line operator summary.
    pub fn summary_line(&self) -> String {
        format!(
            "shard {}: {} task(s) owned, {} resumed from WAL, {} executed",
            self.plan.label(),
            self.owned,
            self.resumed,
            self.executed
        )
    }
}

/// Verify a WAL-recovered outcome against the task the canonical list
/// holds at its index — a mismatch means the WAL was produced under a
/// different filter set than this invocation (the header catches seed and
/// plan drift; this catches filter drift, which changes what each index
/// *means*).
fn verify_recovered(o: &TaskOutcome, task: &CampaignTask) -> Result<()> {
    if o.scenario_id != task.scenario.id
        || o.app != task.app
        || o.strategy != task.strategy
        || o.collectives != task.collectives
        || o.validation != task.validation
        || o.faults != task.faults
    {
        return Err(SedarError::Config(format!(
            "WAL record for task {} does not match this sweep's task list \
             (WAL: sc{} {} × {} coll={} val={} faults={}; \
             spec: sc{} {} × {} coll={} val={} faults={}) — was the --filter changed?",
            o.index,
            o.scenario_id,
            o.app.label(),
            o.strategy.label(),
            collective_label(o.collectives),
            validation_label(o.validation),
            o.faults,
            task.scenario.id,
            task.app.label(),
            task.strategy.label(),
            collective_label(task.collectives),
            validation_label(task.validation),
            task.faults
        )));
    }
    Ok(())
}

/// Run one shard of the sweep: slice the canonical task list per the plan,
/// recover finished tasks from the WAL (if any), execute the rest over the
/// worker pool — appending to the WAL and publishing status as tasks
/// finish — and compact the WAL with a final snapshot on clean completion.
pub fn run_shard(spec: &CampaignSpec, opts: &FleetOptions) -> Result<ShardRun> {
    let plan = opts.plan.unwrap_or_else(ShardPlan::full);
    let tasks = build_tasks(spec);
    if tasks.is_empty() {
        return Err(SedarError::Config(
            "campaign filter selects no tasks".into(),
        ));
    }
    let owned = plan.slice(&tasks);
    let meta = ShardMeta {
        seed: spec.seed,
        shard_index: plan.index as u32,
        shard_count: plan.count as u32,
        total_tasks: tasks.len() as u64,
        spec_hash: sweep_fingerprint(spec.seed, &tasks),
    };

    // Recover prior progress. The WAL stays open for appending.
    let mut recovered: Vec<TaskOutcome> = Vec::new();
    let wal: Option<Mutex<Wal>> = match &opts.wal_path {
        None => None,
        Some(path) => {
            let (w, prior) = Wal::open(path, &meta)?;
            recovered = prior;
            Some(Mutex::new(w))
        }
    };
    for o in &recovered {
        let task = tasks.get(o.index).ok_or_else(|| {
            SedarError::Config(format!(
                "WAL record for task {} is outside this sweep ({} tasks)",
                o.index,
                tasks.len()
            ))
        })?;
        if !plan.owns(o.index) {
            return Err(SedarError::Config(format!(
                "WAL record for task {} is not owned by shard {}",
                o.index,
                plan.label()
            )));
        }
        verify_recovered(o, task)?;
    }
    let done: std::collections::HashSet<usize> = recovered.iter().map(|o| o.index).collect();
    let remaining: Vec<CampaignTask> = owned
        .iter()
        .filter(|t| !done.contains(&t.index))
        .cloned()
        .collect();

    // Live status: totals over the whole slice, with recovered tasks
    // already counted as done.
    let label = format!("shard {}", plan.label());
    let board = Arc::new(StatusBoard::new(&label, spec.seed, &owned));
    for o in &recovered {
        board.record_resumed(o);
    }
    let _server: Option<StatusServer> = match opts.status_port {
        None => None,
        Some(port) => {
            let server = StatusServer::spawn(port, board.clone())?;
            eprintln!("status endpoint: http://{}/ (and /json)", server.addr());
            if let Some(path) = &opts.status_addr_file {
                // Write-then-rename: the supervisor polls for this file
                // and must never observe a half-written address.
                let tmp = path.with_extension("addr-tmp");
                std::fs::write(&tmp, format!("{}\n", server.addr()))?;
                std::fs::rename(&tmp, path)?;
            }
            Some(server)
        }
    };

    // Execute the remainder; every finished task goes to the WAL and the
    // status board from the worker that completed it.
    let sink_board = board.clone();
    let sink_wal = &wal;
    let sink = move |_done: usize, _total: usize, outcome: &TaskOutcome| {
        if let Some(w) = sink_wal {
            if let Err(e) = w.lock().unwrap().append(outcome) {
                // The WAL is resilience, not correctness: losing a record
                // costs a re-execution on resume, not the sweep.
                eprintln!("fleet: WAL append failed for task {}: {e}", outcome.index);
            }
        }
        sink_board.record(outcome);
    };
    let fresh = scheduler::run_tasks(spec, &remaining, &sink)?;

    // Clean completion: compact with a final snapshot so the next reader
    // replays one record. A no-op resume (nothing executed) appends
    // nothing and leaves the file byte-identical.
    if let Some(w) = &wal {
        w.lock().unwrap().finalize()?;
    }

    let resumed = recovered.len();
    let executed = fresh.len();
    // Overlap here is impossible by construction (remaining excludes every
    // recovered index); merge re-checks anyway — defense in depth on the
    // path that feeds the durable log.
    let outcomes = aggregate::merge(vec![recovered, fresh])?;

    Ok(ShardRun {
        plan,
        owned: owned.len(),
        resumed,
        executed,
        outcomes,
        wal_path: opts.wal_path.clone(),
    })
}
