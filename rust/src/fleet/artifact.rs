//! Durable shard artifacts: one file per fleet member, holding the
//! [`TaskOutcome`]s of that member's slice of the sweep.
//!
//! The payload is a versioned binary record stream wrapped in the same
//! length-prefixed, CRC-checked frame the checkpoints use
//! ([`crate::checkpoint::snapshot`]) — zero new formats to audit, and
//! storage corruption of a shard file surfaces as a recoverable error at
//! merge time, exactly like a corrupt checkpoint at restart time.
//!
//! ```text
//! frame payload:
//!   "SDSH" | version u32 | seed u64 | shard u32 | of u32 | total u64
//!   | spec_hash u64 | n u64 | then n × outcome records (encode_outcome)
//! ```
//!
//! Every field of [`TaskOutcome`] round-trips — including the mismatch
//! notes (arbitrary UTF-8) and the informational wall time — so a merged
//! report is byte-identical to the single-process run's.

use std::path::Path;

use crate::campaign::shard::TaskOutcome;
use crate::campaign::{
    collective_from_ordinal, collective_ordinal, netfault_from_ordinal, netfault_ordinal,
    strategy_from_ordinal, strategy_ordinal, validation_from_ordinal, validation_ordinal,
    CampaignApp,
};
use crate::checkpoint::snapshot::{read_frame, write_frame, Codec};
use crate::error::{FaultClass, Result, SedarError};
use crate::recovery::ResumeFrom;

const MAGIC: &[u8; 4] = b"SDSH";
/// Bumped to 2 when the collectives axis joined the outcome record (a
/// per-record ordinal byte after the strategy's); version-1 artifacts
/// cannot carry the axis and are rejected rather than mis-decoded.
/// Bumped to 3 when the per-task [`crate::metrics::MetricsSnapshot`]
/// joined the record (14 trailing u64 counters); version-2 artifacts
/// cannot carry the observability fields and are rejected rather than
/// mis-decoded.
/// Bumped to 4 when the netfault axis joined the outcome record (a
/// per-record ordinal byte after the validation's); version-3 artifacts
/// cannot carry the axis and are rejected rather than mis-decoded.
const VERSION: u32 = 4;

/// Identity of a shard artifact: which sweep it belongs to and which slice
/// it claims. `total_tasks` is the canonical task-list length of the sweep
/// (after filters), so a merge can tell "complete" from "partial";
/// `spec_hash` ([`crate::campaign::sweep_fingerprint`]) pins the exact
/// cell list, so shards of same-seed, same-width but differently-filtered
/// sweeps can never be silently mixed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMeta {
    pub seed: u64,
    /// 0-based member index of the producing [`super::plan::ShardPlan`].
    pub shard_index: u32,
    pub shard_count: u32,
    pub total_tasks: u64,
    /// Fingerprint of the sweep's canonical task list (seed + filters).
    pub spec_hash: u64,
}

/// Bounds-checked little-endian reader over a decoded payload.
pub struct ByteReader<'a> {
    data: &'a [u8],
    pos: usize,
    /// Context for error messages ("shard artifact", "fleet journal", …).
    what: &'static str,
}

impl<'a> ByteReader<'a> {
    pub fn new(data: &'a [u8], what: &'static str) -> ByteReader<'a> {
        ByteReader { data, pos: 0, what }
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    fn truncated<T>(&self) -> Result<T> {
        Err(SedarError::Checkpoint(format!(
            "{} truncated at offset {}",
            self.what, self.pos
        )))
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return self.truncated();
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        // Defensive cap: a corrupt length must not allocate the moon. Any
        // legitimate site/mismatch string is far below this.
        if len > 1 << 20 {
            return Err(SedarError::Checkpoint(format!(
                "{}: implausible string length {len}",
                self.what
            )));
        }
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| {
            SedarError::Checkpoint(format!("{}: non-UTF-8 string payload", self.what))
        })
    }
}

fn push_string(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn fault_class_ordinal(c: FaultClass) -> u8 {
    match c {
        FaultClass::Tdc => 0,
        FaultClass::Fsc => 1,
        FaultClass::Le => 2,
        FaultClass::Toe => 3,
        FaultClass::CkptCorrupt => 4,
    }
}

fn fault_class_from_ordinal(ord: u8) -> Option<FaultClass> {
    [
        FaultClass::Tdc,
        FaultClass::Fsc,
        FaultClass::Le,
        FaultClass::Toe,
        FaultClass::CkptCorrupt,
    ]
    .into_iter()
    .find(|c| fault_class_ordinal(*c) == ord)
}

/// Append one outcome's binary record to `out`.
pub fn encode_outcome(o: &TaskOutcome, out: &mut Vec<u8>) {
    out.extend_from_slice(&(o.index as u64).to_le_bytes());
    out.extend_from_slice(&o.scenario_id.to_le_bytes());
    out.push(o.app.ordinal() as u8);
    out.push(strategy_ordinal(o.strategy) as u8);
    out.push(collective_ordinal(o.collectives) as u8);
    out.push(validation_ordinal(o.validation) as u8);
    out.push(netfault_ordinal(o.netfault) as u8);
    out.extend_from_slice(&o.faults.to_le_bytes());
    out.push(o.completed as u8);
    out.push(o.injected as u8);
    out.push(match o.correct {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    });
    out.extend_from_slice(&o.restarts.to_le_bytes());
    match &o.first_detection {
        None => out.push(0),
        Some((class, site)) => {
            out.push(1 + fault_class_ordinal(*class));
            push_string(out, site);
        }
    }
    match o.last_resume {
        None => out.push(0),
        Some(ResumeFrom::Scratch) => out.push(1),
        Some(ResumeFrom::SysCkpt(k)) => {
            out.push(2);
            out.extend_from_slice(&k.to_le_bytes());
        }
        Some(ResumeFrom::UserCkpt(k)) => {
            out.push(3);
            out.extend_from_slice(&k.to_le_bytes());
        }
    }
    out.push(o.pass as u8);
    out.extend_from_slice(&(o.mismatches.len() as u32).to_le_bytes());
    for m in &o.mismatches {
        push_string(out, m);
    }
    let wall_nanos = u64::try_from(o.wall.as_nanos()).unwrap_or(u64::MAX);
    out.extend_from_slice(&wall_nanos.to_le_bytes());
    // v3: the observability counters, in MetricsSnapshot field order.
    for v in [
        o.metrics.compare_ticks,
        o.metrics.compare_bytes,
        o.metrics.sync_ticks,
        o.metrics.sync_events,
        o.metrics.sys_ckpt_ticks,
        o.metrics.sys_ckpt_bytes,
        o.metrics.sys_ckpts,
        o.metrics.user_ckpt_ticks,
        o.metrics.user_ckpt_bytes,
        o.metrics.user_ckpts,
        o.metrics.exec_ticks,
        o.metrics.execs,
        o.metrics.rollback_ticks,
        o.metrics.rollbacks,
    ] {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn bool_from(b: u8, what: &str) -> Result<bool> {
    match b {
        0 => Ok(false),
        1 => Ok(true),
        other => Err(SedarError::Checkpoint(format!(
            "{what}: bad bool byte {other}"
        ))),
    }
}

/// Decode one outcome record from `r`.
pub fn decode_outcome(r: &mut ByteReader<'_>) -> Result<TaskOutcome> {
    let what = r.what;
    let bad = |field: &str, v: u64| {
        SedarError::Checkpoint(format!("{what}: bad {field} ordinal {v}"))
    };
    let index = r.u64()? as usize;
    let scenario_id = r.u32()?;
    let app_ord = r.u8()? as u64;
    let app = CampaignApp::from_ordinal(app_ord).ok_or_else(|| bad("app", app_ord))?;
    let strat_ord = r.u8()? as u64;
    let strategy = strategy_from_ordinal(strat_ord).ok_or_else(|| bad("strategy", strat_ord))?;
    let coll_ord = r.u8()? as u64;
    let collectives =
        collective_from_ordinal(coll_ord).ok_or_else(|| bad("collectives", coll_ord))?;
    let val_ord = r.u8()? as u64;
    let validation = validation_from_ordinal(val_ord).ok_or_else(|| bad("validation", val_ord))?;
    let nf_ord = r.u8()? as u64;
    let netfault = netfault_from_ordinal(nf_ord).ok_or_else(|| bad("netfault", nf_ord))?;
    let faults = r.u32()?;
    let completed = bool_from(r.u8()?, what)?;
    let injected = bool_from(r.u8()?, what)?;
    let correct = match r.u8()? {
        0 => None,
        1 => Some(false),
        2 => Some(true),
        other => return Err(bad("correct", other as u64)),
    };
    let restarts = r.u32()?;
    let first_detection = match r.u8()? {
        0 => None,
        tag => {
            let class = fault_class_from_ordinal(tag - 1)
                .ok_or_else(|| bad("fault class", tag as u64))?;
            Some((class, r.string()?))
        }
    };
    let last_resume = match r.u8()? {
        0 => None,
        1 => Some(ResumeFrom::Scratch),
        2 => Some(ResumeFrom::SysCkpt(r.u64()?)),
        3 => Some(ResumeFrom::UserCkpt(r.u64()?)),
        other => return Err(bad("resume", other as u64)),
    };
    let pass = bool_from(r.u8()?, what)?;
    let n_mismatches = r.u32()?;
    if n_mismatches > 1 << 16 {
        return Err(SedarError::Checkpoint(format!(
            "{what}: implausible mismatch count {n_mismatches}"
        )));
    }
    let mut mismatches = Vec::with_capacity(n_mismatches as usize);
    for _ in 0..n_mismatches {
        mismatches.push(r.string()?);
    }
    let wall = std::time::Duration::from_nanos(r.u64()?);
    let metrics = crate::metrics::MetricsSnapshot {
        compare_ticks: r.u64()?,
        compare_bytes: r.u64()?,
        sync_ticks: r.u64()?,
        sync_events: r.u64()?,
        sys_ckpt_ticks: r.u64()?,
        sys_ckpt_bytes: r.u64()?,
        sys_ckpts: r.u64()?,
        user_ckpt_ticks: r.u64()?,
        user_ckpt_bytes: r.u64()?,
        user_ckpts: r.u64()?,
        exec_ticks: r.u64()?,
        execs: r.u64()?,
        rollback_ticks: r.u64()?,
        rollbacks: r.u64()?,
    };
    Ok(TaskOutcome {
        index,
        scenario_id,
        app,
        strategy,
        collectives,
        validation,
        netfault,
        faults,
        completed,
        restarts,
        injected,
        correct,
        first_detection,
        last_resume,
        pass,
        mismatches,
        wall,
        metrics,
    })
}

/// Serialize a shard's outcomes to `path` (atomically, via the snapshot
/// frame's write-then-rename).
pub fn write_artifact(path: &Path, meta: &ShardMeta, outcomes: &[TaskOutcome]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut payload = Vec::with_capacity(64 + outcomes.len() * 64);
    payload.extend_from_slice(MAGIC);
    payload.extend_from_slice(&VERSION.to_le_bytes());
    payload.extend_from_slice(&meta.seed.to_le_bytes());
    payload.extend_from_slice(&meta.shard_index.to_le_bytes());
    payload.extend_from_slice(&meta.shard_count.to_le_bytes());
    payload.extend_from_slice(&meta.total_tasks.to_le_bytes());
    payload.extend_from_slice(&meta.spec_hash.to_le_bytes());
    payload.extend_from_slice(&(outcomes.len() as u64).to_le_bytes());
    for o in outcomes {
        encode_outcome(o, &mut payload);
    }
    write_frame(path, &payload, Codec::Raw)?;
    // The frame write is atomic (write + rename), but the rename itself
    // lives in the directory: sync it so a crash immediately after cannot
    // lose the artifact's name.
    super::sync_parent_dir(path)
}

/// Read a shard artifact back, verifying frame CRC, magic and version.
pub fn read_artifact(path: &Path) -> Result<(ShardMeta, Vec<TaskOutcome>)> {
    let payload = read_frame(path)?;
    let mut r = ByteReader::new(&payload, "shard artifact");
    if r.bytes(4)? != MAGIC {
        return Err(SedarError::Checkpoint(format!(
            "{}: not a shard artifact (bad magic)",
            path.display()
        )));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(SedarError::Checkpoint(format!(
            "{}: unsupported shard artifact version {version} (this build reads \
             version {VERSION}) — regenerate the shard with this binary",
            path.display()
        )));
    }
    let meta = ShardMeta {
        seed: r.u64()?,
        shard_index: r.u32()?,
        shard_count: r.u32()?,
        total_tasks: r.u64()?,
        spec_hash: r.u64()?,
    };
    let n = r.u64()?;
    // A shard can never hold more outcomes than the sweep has tasks, and
    // every record is ≥ 32 bytes — both bounds are cheap to check before
    // trusting `n` with an allocation.
    if n > meta.total_tasks || n as usize > r.remaining() / 32 + 1 {
        return Err(SedarError::Checkpoint(format!(
            "{}: implausible outcome count {n}",
            path.display()
        )));
    }
    let mut outcomes = Vec::with_capacity(n as usize);
    for _ in 0..n {
        outcomes.push(decode_outcome(&mut r)?);
    }
    if r.remaining() != 0 {
        return Err(SedarError::Checkpoint(format!(
            "{}: {} trailing byte(s) after last record",
            path.display(),
            r.remaining()
        )));
    }
    Ok((meta, outcomes))
}

/// Render a shard header's identity fields for merge diagnostics.
fn describe_meta(m: &ShardMeta) -> String {
    format!(
        "seed={} shard={}/{} tasks={} fingerprint={:#018x}",
        m.seed,
        m.shard_index + 1,
        m.shard_count,
        m.total_tasks,
        m.spec_hash
    )
}

/// Combine shard artifacts into one outcome list in canonical task order.
///
/// Rejects shards from different sweeps (mismatched seed or total-task
/// count) and overlapping slices (duplicate task indices — see
/// [`crate::campaign::aggregate::merge`]'s policy). Returns
/// `(seed, total_tasks, outcomes)`; the caller decides whether a partial
/// union (fewer outcomes than `total_tasks`) is acceptable.
pub fn merge_artifacts(
    shards: Vec<(ShardMeta, Vec<TaskOutcome>)>,
) -> Result<(u64, u64, Vec<TaskOutcome>)> {
    let first = shards
        .first()
        .map(|(m, _)| *m)
        .ok_or_else(|| SedarError::Config("merge: no shard artifacts given".into()))?;
    for (m, _) in &shards {
        if m.seed != first.seed {
            return Err(SedarError::Config(format!(
                "merge: shard seeds differ ({} vs {}) — artifacts from different sweeps",
                first.seed, m.seed
            )));
        }
        if m.total_tasks != first.total_tasks {
            return Err(SedarError::Config(format!(
                "merge: shard task totals differ ({} vs {}) — artifacts from different \
                 filters or specs",
                first.total_tasks, m.total_tasks
            )));
        }
        if m.spec_hash != first.spec_hash {
            // Decode both headers into the error so the operator can see
            // *which* identity component disagrees without a hex dump:
            // same seed + same task total but different fingerprints means
            // a different --filter set (the netfault axis included).
            return Err(SedarError::Config(format!(
                "merge: shard spec fingerprints differ — artifacts were produced \
                 under different --filter sets and cannot be combined\n  first: {}\n  other: {}",
                describe_meta(&first),
                describe_meta(m),
            )));
        }
    }
    let outcomes = crate::campaign::aggregate::merge(
        shards.into_iter().map(|(_, outcomes)| outcomes).collect(),
    )?;
    Ok((first.seed, first.total_tasks, outcomes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(index: usize) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: 7,
            app: CampaignApp::Sw,
            strategy: crate::config::Strategy::UserCkpt,
            collectives: crate::config::CollectiveImpl::Native,
            validation: crate::detect::ValidationMode::Sha256,
            netfault: crate::faultnet::NetFaultMode::Corrupt,
            faults: 2,
            completed: true,
            restarts: 1,
            injected: true,
            correct: Some(true),
            first_detection: Some((FaultClass::Tdc, "GATHER|rank1".into())),
            last_resume: Some(ResumeFrom::UserCkpt(3)),
            pass: false,
            mismatches: vec!["ошибка №1 — 错误".into(), String::new()],
            wall: std::time::Duration::from_micros(1234),
            metrics: crate::metrics::MetricsSnapshot {
                compare_ticks: 1,
                compare_bytes: 2,
                sync_ticks: 3,
                sync_events: 4,
                sys_ckpt_ticks: 5,
                sys_ckpt_bytes: 6,
                sys_ckpts: 7,
                user_ckpt_ticks: 8,
                user_ckpt_bytes: 9,
                user_ckpts: 10,
                exec_ticks: 11,
                execs: 12,
                rollback_ticks: 13,
                rollbacks: 14,
            },
        }
    }

    #[test]
    fn record_roundtrip() {
        let mut buf = Vec::new();
        encode_outcome(&sample(42), &mut buf);
        let mut r = ByteReader::new(&buf, "test");
        let back = decode_outcome(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(format!("{:?}", back), format!("{:?}", sample(42)));
    }

    #[test]
    fn decode_rejects_bad_ordinals_and_truncation() {
        let mut buf = Vec::new();
        encode_outcome(&sample(1), &mut buf);
        // Truncation at every prefix must error, never panic.
        for cut in 0..buf.len() {
            let mut r = ByteReader::new(&buf[..cut], "test");
            assert!(decode_outcome(&mut r).is_err(), "prefix {cut} decoded");
        }
        // Corrupt the app ordinal (offset 12: u64 index + u32 scenario).
        let mut bad = buf.clone();
        bad[12] = 99;
        assert!(decode_outcome(&mut ByteReader::new(&bad, "test")).is_err());
    }

    #[test]
    fn fingerprint_mismatch_error_names_both_headers() {
        let a = ShardMeta {
            seed: 11,
            shard_index: 0,
            shard_count: 2,
            total_tasks: 8,
            spec_hash: 0xAAAA,
        };
        let b = ShardMeta {
            spec_hash: 0xBBBB,
            shard_index: 1,
            ..a
        };
        let err = merge_artifacts(vec![(a, vec![]), (b, vec![])])
            .unwrap_err()
            .to_string();
        for needle in ["0x000000000000aaaa", "0x000000000000bbbb", "shard=1/2", "shard=2/2"] {
            assert!(err.contains(needle), "missing {needle}: {err}");
        }
    }

    #[test]
    fn v3_artifact_is_refused_naming_both_versions() {
        // A hand-built version-3 payload (the pre-netfault format): the
        // reader must refuse it with an error naming the file's version
        // AND the version this build reads, so mixed-version fleets fail
        // fast instead of merging garbage.
        let p = std::env::temp_dir().join(format!(
            "sedar-artifact-v3-{}-{:?}.bin",
            std::process::id(),
            std::thread::current().id()
        ));
        let mut payload = Vec::new();
        payload.extend_from_slice(MAGIC);
        payload.extend_from_slice(&3u32.to_le_bytes());
        payload.extend_from_slice(&[0u8; 32]); // meta
        payload.extend_from_slice(&0u64.to_le_bytes()); // n = 0
        write_frame(&p, &payload, Codec::Raw).unwrap();
        let err = read_artifact(&p).unwrap_err().to_string();
        assert!(err.contains("version 3"), "missing file version: {err}");
        assert!(err.contains("version 4"), "missing reader version: {err}");
        std::fs::remove_file(&p).unwrap();
    }
}
