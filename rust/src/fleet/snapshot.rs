//! The WAL read side: snapshot compaction, watermark-aware replay, and the
//! streaming merge over shard WALs.
//!
//! This module is the **single** recovery path the fleet has left. Whether
//! the caller is a resuming shard ([`crate::fleet::wal::Wal::open`]), the
//! launch driver probing completeness, `sedar merge`, or the live partial
//! aggregate behind a status endpoint — everyone reads a WAL through
//! [`read_wal`], and everyone combines WALs through an
//! [`IncrementalMerger`]. There is no "artifact decoder" distinct from the
//! "journal replayer" any more; recovery *is* replay.
//!
//! Replay is **lenient** on purpose: an append-only log may legitimately
//! end mid-record (the writer was killed mid-append, or a live reader is
//! racing a writer that has not finished its current record). The valid
//! prefix is the truth; the torn tail is dropped. A tag-1 snapshot record
//! is the compaction **watermark**: when one replays completely, it
//! *resets* the accumulated state to its contents — so readers effectively
//! skip the prefix it supersedes, and a snapshot torn by a kill
//! mid-compaction simply falls back to the outcome records before it
//! (which it only ever repeated — nothing is lost).

use std::collections::BTreeMap;
use std::path::Path;

use crate::campaign::aggregate::IncrementalMerger;
use crate::campaign::shard::TaskOutcome;
use crate::error::{Result, SedarError};
use crate::util::frame::{next_record, ByteReader};

use super::wal::{decode_outcome, encode_outcome, parse_header, ShardMeta, TAG_OUTCOME, TAG_SNAPSHOT};

/// What a lenient replay of the record stream proved.
pub(crate) struct ScanState {
    /// The replayed outcome set (last watermark + records after it).
    pub known: BTreeMap<usize, TaskOutcome>,
    /// Byte length of the valid prefix — a writer resuming over this file
    /// truncates to here before appending.
    pub valid_len: usize,
    /// Outcome records seen since the last complete snapshot (seeds the
    /// writer's compaction counter on resume).
    pub since_snapshot: usize,
}

impl ScanState {
    pub fn fresh() -> ScanState {
        ScanState {
            known: BTreeMap::new(),
            valid_len: 0,
            since_snapshot: 0,
        }
    }
}

/// Encode the full known outcome set as one snapshot record body
/// (`tag 1 | count u64 | count × outcome`, ascending task index).
pub(crate) fn encode_snapshot(known: &BTreeMap<usize, TaskOutcome>) -> Vec<u8> {
    let mut body = Vec::with_capacity(16 + known.len() * 160);
    body.push(TAG_SNAPSHOT);
    body.extend_from_slice(&(known.len() as u64).to_le_bytes());
    for o in known.values() {
        encode_outcome(o, &mut body);
    }
    body
}

/// Lenient replay of the record stream following the header. A record that
/// frames (CRC-valid) but does not decode to a well-formed body ends the
/// valid prefix exactly like a torn tail: bits that pass CRC but fail the
/// schema mean the writer died mid-rethink, not that the prefix is bad.
pub(crate) fn scan_records(data: &[u8], start: usize, total_tasks: u64) -> ScanState {
    let mut st = ScanState {
        known: BTreeMap::new(),
        valid_len: start,
        since_snapshot: 0,
    };
    let mut pos = start;
    while let Some((body, end)) = next_record(data, pos) {
        if !apply_record(body, total_tasks, &mut st) {
            break;
        }
        st.valid_len = end;
        pos = end;
    }
    st
}

/// Apply one framed record body to the replay state; `false` ends the
/// valid prefix.
fn apply_record(body: &[u8], total_tasks: u64, st: &mut ScanState) -> bool {
    let mut r = ByteReader::new(body, "fleet WAL record");
    let Ok(tag) = r.u8() else { return false };
    match tag {
        TAG_OUTCOME => match decode_outcome(&mut r) {
            Ok(o) if r.remaining() == 0 => {
                // Keep-first: outcomes are pure functions of the per-task
                // seed, so a duplicated index is benign during replay; the
                // merge layer is where cross-shard overlap is a hard error.
                st.known.entry(o.index).or_insert(o);
                st.since_snapshot += 1;
                true
            }
            _ => false,
        },
        TAG_SNAPSHOT => {
            let Ok(n) = r.u64() else { return false };
            // A snapshot cannot claim more outcomes than the sweep has
            // tasks; a count above that is damage, not data.
            if n > total_tasks {
                return false;
            }
            let mut compacted = BTreeMap::new();
            for _ in 0..n {
                match decode_outcome(&mut r) {
                    Ok(o) => {
                        compacted.insert(o.index, o);
                    }
                    Err(_) => return false,
                }
            }
            if r.remaining() != 0 {
                return false;
            }
            // The watermark: this snapshot supersedes everything replayed
            // before it.
            st.known = compacted;
            st.since_snapshot = 0;
            true
        }
        _ => false,
    }
}

/// Refuse files that lead with a legacy container's raw magic before we
/// even try to frame them: pre-SDWL shard artifacts (`SDSH`) rode inside an
/// `SDCK` checkpoint frame, so that is the four bytes an operator's stale
/// `shard-N.bin` actually starts with.
pub(crate) fn refuse_foreign_container(path: &Path, data: &[u8]) -> Result<()> {
    if data.len() >= 4 && &data[..4] == b"SDCK" {
        return Err(SedarError::Checkpoint(format!(
            "{}: not a fleet WAL: this is a checkpoint-framed file (SDCK) — \
             pre-SDWL shard artifacts (SDSH) were stored this way, and the \
             SDWL v1 write-ahead log replaced the journal+artifact pair; \
             re-run the shard to produce a WAL",
            path.display()
        )));
    }
    Ok(())
}

/// Parse a WAL image: header identity plus the lenient replay state.
pub(crate) fn scan_wal(path: &Path, data: &[u8]) -> Result<(ShardMeta, ScanState)> {
    refuse_foreign_container(path, data)?;
    let Some((header, end)) = next_record(data, 0) else {
        return Err(SedarError::Checkpoint(format!(
            "{}: unreadable WAL header (torn or foreign file); delete it to \
             start the shard from scratch",
            path.display()
        )));
    };
    let meta = parse_header(header)?;
    let state = scan_records(data, end, meta.total_tasks);
    Ok((meta, state))
}

/// Read a shard WAL from disk: its sweep identity and the outcomes it
/// proves, in ascending task order.
///
/// The tail is read leniently, so this is safe to call on the WAL of a
/// **live** shard (the launch driver's partial aggregate does exactly
/// that): a racing writer at worst costs the record it is mid-way through
/// appending, never a misread.
pub fn read_wal(path: &Path) -> Result<(ShardMeta, Vec<TaskOutcome>)> {
    let data = std::fs::read(path)?;
    let (meta, state) = scan_wal(path, &data)?;
    Ok((meta, state.known.into_values().collect()))
}

/// Combine shard WAL contents into one outcome list in canonical task
/// order, enforcing that every shard belongs to the same sweep.
///
/// Returns `(seed, total_tasks, outcomes)`. The union may be *partial*
/// (fewer outcomes than `total_tasks`) — some shards still running, or not
/// passed in at all; the caller decides whether partial is acceptable
/// (`--allow-partial`) or an error. What is never acceptable is two shards
/// claiming the same task index, identity drift between shards, or the
/// same outcome index disagreeing — all typed errors from the merge.
pub fn merge_wals(shards: Vec<(ShardMeta, Vec<TaskOutcome>)>) -> Result<(u64, u64, Vec<TaskOutcome>)> {
    let first = shards
        .first()
        .map(|(m, _)| *m)
        .ok_or_else(|| SedarError::Config("merge: no shard WALs given".to_string()))?;
    let mut merger = IncrementalMerger::new(first);
    for (m, outcomes) in shards {
        merger.ingest(&m, outcomes)?;
    }
    Ok((first.seed, first.total_tasks, merger.merged()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::wal::Wal;

    fn meta(shard_index: u32) -> ShardMeta {
        ShardMeta {
            seed: 42,
            shard_index,
            shard_count: 2,
            total_tasks: 4,
            spec_hash: 0xF1E7,
        }
    }

    fn outcome(index: usize, pass: bool) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: index as u32,
            app: crate::campaign::CampaignApp::Matmul,
            strategy: crate::config::Strategy::SysCkpt,
            collectives: crate::config::CollectiveImpl::PointToPoint,
            validation: crate::detect::ValidationMode::Full,
            netfault: crate::faultnet::NetFaultMode::None,
            faults: 1,
            completed: true,
            restarts: 0,
            injected: true,
            correct: Some(pass),
            first_detection: None,
            last_resume: None,
            pass,
            mismatches: vec![],
            wall: std::time::Duration::ZERO,
            metrics: Default::default(),
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "sedar-walread-{tag}-{}-{:?}.wal",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn read_and_merge_wals_across_shards() {
        let p0 = tmp("merge-s0");
        let p1 = tmp("merge-s1");
        let _ = std::fs::remove_file(&p0);
        let _ = std::fs::remove_file(&p1);
        {
            let (mut w, _) = Wal::open(&p0, &meta(0)).unwrap();
            w.append(&outcome(2, true)).unwrap();
            w.append(&outcome(0, true)).unwrap();
            w.finalize().unwrap();
        }
        {
            let (mut w, _) = Wal::open(&p1, &meta(1)).unwrap();
            w.append(&outcome(3, false)).unwrap();
            w.append(&outcome(1, true)).unwrap();
            w.finalize().unwrap();
        }
        let s0 = read_wal(&p0).unwrap();
        let s1 = read_wal(&p1).unwrap();
        assert_eq!(s0.0, meta(0));
        assert_eq!(s1.0, meta(1));
        let (seed, total, merged) = merge_wals(vec![s0, s1]).unwrap();
        assert_eq!((seed, total), (42, 4));
        let idx: Vec<usize> = merged.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3], "merge must be in canonical task order");
        std::fs::remove_file(&p0).unwrap();
        std::fs::remove_file(&p1).unwrap();
    }

    #[test]
    fn partial_union_is_the_callers_call() {
        let p = tmp("partial");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, _) = Wal::open(&p, &meta(0)).unwrap();
            w.append(&outcome(0, true)).unwrap();
            w.finalize().unwrap();
        }
        // One live/lone shard: merge succeeds, coverage is partial — the
        // CLI's --allow-partial gate compares len() against total.
        let (_, total, merged) = merge_wals(vec![read_wal(&p).unwrap()]).unwrap();
        assert_eq!(total, 4);
        assert_eq!(merged.len(), 1);
        assert!(merge_wals(Vec::new()).is_err(), "empty merge must error");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn live_reader_tolerates_a_racing_writers_torn_tail() {
        let p = tmp("live");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, _) = Wal::open(&p, &meta(0)).unwrap();
            w.append(&outcome(0, true)).unwrap();
            w.append(&outcome(2, true)).unwrap();
        }
        // A reader racing the writer sees a prefix of the file: every
        // prefix that still frames the header must read cleanly, proving
        // the no-lock live-aggregate scrape can never misread.
        let full = std::fs::read(&p).unwrap();
        for cut in 48..=full.len() {
            std::fs::write(&p, &full[..cut]).unwrap();
            let (m, outcomes) = read_wal(&p).unwrap();
            assert_eq!(m, meta(0));
            assert!(outcomes.len() <= 2);
        }
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn merge_rejects_fingerprint_drift_naming_both_headers() {
        let a = meta(0);
        let mut b = meta(1);
        b.spec_hash = 0xBBBB;
        let err = merge_wals(vec![(a, vec![outcome(0, true)]), (b, vec![outcome(2, true)])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("--filter"), "{err}");
        assert!(err.contains("shard=1/2"), "first header not described: {err}");
        assert!(err.contains("shard=2/2"), "other header not described: {err}");
    }

    #[test]
    fn snapshot_claiming_more_than_the_sweep_ends_the_prefix() {
        let p = tmp("overclaim");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, _) = Wal::open(&p, &meta(0)).unwrap();
            w.append(&outcome(0, true)).unwrap();
        }
        // Append a CRC-valid snapshot record whose count field claims more
        // outcomes than the sweep has tasks: frames fine, but replay must
        // treat it as damage and keep only the prefix before it.
        let mut body = vec![TAG_SNAPSHOT];
        body.extend_from_slice(&(u64::MAX).to_le_bytes());
        let mut data = std::fs::read(&p).unwrap();
        crate::util::frame::frame(&body, &mut data);
        std::fs::write(&p, &data).unwrap();
        let (_, outcomes) = read_wal(&p).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].index, 0);
        std::fs::remove_file(&p).unwrap();
    }
}
